"""Noise-injected engine mode: seeded statistical acceptance tests.

The acceptance bar (ISSUE 3): the engine runs the full post-silicon noise
model end to end through the Pallas path, deterministically under a fixed
PRNG key, while NO_NOISE stays bit-exact with the digital reference across
the precision grid; its noise statistics match the analytic model and the
fakequant training path within the tolerances below.  Plus the noise-model
bugfix sweep regressions (staticmethod none(), traceable settle_fraction,
dtype-preserving disabled paths, physical-column SA offset sharing).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cim_layers as cl
from repro.core import noise_model as nm
from repro.core.hw import DEFAULT_MACRO
from repro.core.mapping import LayerSpec
from repro.core.noise_model import NO_NOISE, NoiseConfig
from repro.runtime import CIMInferenceEngine, EngineConfig

R_INS = (1, 2, 4, 8)
R_WS = (1, 2, 4)

# thermal-only operating point: static/deterministic terms zeroed, settling
# instantaneous — isolates the kT/C Gaussian for the analytic-std check
THERMAL_ONLY = NoiseConfig(sa_sigma_v=0.0, kappa_in=0.0, kappa_acc=0.0,
                           leak_v_per_us=0.0, tau0_ns=1e-4,
                           tau_per_unit_ns=0.0)


def _case(specs, seed=0, m=8, noise=NO_NOISE):
    eng = CIMInferenceEngine(specs, EngineConfig(noise=noise))
    params = eng.init_params(jax.random.PRNGKey(seed))
    x = jax.nn.relu(
        jax.random.normal(jax.random.PRNGKey(seed + 1), (m, specs[0].k)))
    return eng, params, x


# ---- NO_NOISE stays the bit-exact deployed path ---------------------------

@pytest.mark.parametrize("r_w", R_WS)
@pytest.mark.parametrize("r_in", R_INS)
def test_no_noise_grid_stays_bitexact(r_in, r_w):
    """A key passed to a NO_NOISE engine is ignored: same fused kernels,
    bit-exact with the reference, across the precision grid."""
    specs = [LayerSpec(m=8, k=72, n=16, r_in=r_in, r_w=r_w, r_out=8)]
    eng, params, x = _case(specs, seed=r_in * 10 + r_w)
    y = eng(params, x)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(eng(params, x, jax.random.PRNGKey(3))))
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(eng.reference(params, x)))


# ---- noise mode: determinism + kernel/reference lockstep ------------------

def test_noise_requires_key():
    eng, params, x = _case([LayerSpec(m=8, k=72, n=16)], noise=NoiseConfig())
    with pytest.raises(ValueError, match="requires a PRNG key"):
        eng(params, x)


def test_noise_deterministic_and_key_dependent():
    eng, params, x = _case([LayerSpec(m=8, k=144, n=16, r_in=4, r_w=2)],
                           noise=NoiseConfig())
    k1, k2 = jax.random.PRNGKey(5), jax.random.PRNGKey(6)
    np.testing.assert_array_equal(np.asarray(eng(params, x, k1)),
                                  np.asarray(eng(params, x, k1)))
    assert bool(jnp.any(eng(params, x, k1) != eng(params, x, k2)))


@pytest.mark.parametrize("spec", [
    LayerSpec(m=8, k=144, n=16, r_in=8, r_w=4, r_out=8),
    LayerSpec(m=8, k=72, n=16, r_in=2, r_w=1, r_out=6),
    # K > 1152 row tiles + N > 64 col tiles: per-tile keys must agree too
    LayerSpec(m=4, k=2304, n=80, r_in=8, r_w=4, r_out=8),
])
def test_noise_kernel_matches_reference_bitexact(spec):
    """Kernel (raw-dp Pallas) and jnp reference share the noise ADC
    epilogue and per-tile keys -> bit-exact even under noise."""
    eng, params, x = _case([spec], seed=3, m=spec.m, noise=NoiseConfig())
    key = jax.random.PRNGKey(11)
    np.testing.assert_array_equal(np.asarray(eng(params, x, key)),
                                  np.asarray(eng.reference(params, x, key)))


def test_stream_chunks_draw_independent_keys():
    """Chunked im2col streaming must not reuse one thermal key per chunk:
    with every GEMM row identical, equal chunk outputs would betray key
    reuse — chunks must fold their index into the key.  Each chunked run
    stays deterministic."""
    spec = LayerSpec(m=16, k=72, n=16, r_in=4, r_w=2)
    eng = CIMInferenceEngine([spec],
                             EngineConfig(noise=THERMAL_ONLY, stream_rows=4))
    params = eng.init_params(jax.random.PRNGKey(0))
    row = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (1, 72)))
    x = jnp.tile(row, (16, 1))                          # identical rows
    key = jax.random.PRNGKey(2)
    y = eng(params, x, key)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(eng(params, x, key)))
    chunks = np.asarray(y).reshape(4, 4, 16)
    assert not all(np.array_equal(chunks[0], c) for c in chunks[1:])


def test_monte_carlo_shape_determinism_and_guard():
    eng, params, x = _case([LayerSpec(m=8, k=72, n=16, r_in=4, r_w=2)],
                           noise=NoiseConfig())
    key = jax.random.PRNGKey(9)
    mc = eng.monte_carlo(params, x, key, 3)
    assert mc.shape == (3, 8, 16)
    np.testing.assert_array_equal(
        np.asarray(mc[1]), np.asarray(eng(params, x,
                                          jax.random.split(key, 3)[1])))
    clean, params_c, _ = _case([LayerSpec(m=8, k=72, n=16, r_in=4, r_w=2)])
    with pytest.raises(ValueError, match="noise"):
        clean.monte_carlo(params_c, x, key, 2)


def test_noise_point_sweep_shares_one_compile():
    """Noise sigma/offset terms are traced operands (NoiseConfig is a
    pytree with static enabled/calibrated): sweeping the operating point
    through `noise=` must not retrace/recompile the schedule."""
    from repro.runtime import engine as rt
    eng, params, x = _case([LayerSpec(m=8, k=144, n=16, r_in=4, r_w=2)],
                           noise=NoiseConfig())
    key = jax.random.PRNGKey(3)
    base = np.asarray(eng(params, x, key))              # warm the jit cache
    n0 = rt.TRACE_COUNT["n"]
    outs = []
    for s in (0.25, 1.0, 3.0):
        point = NoiseConfig(thermal_rms_lsb8=0.52 * s, sa_sigma_v=0.02 * s)
        outs.append(np.asarray(eng(params, x, key, noise=point)))
    assert rt.TRACE_COUNT["n"] == n0, "noise-point sweep recompiled"
    np.testing.assert_array_equal(outs[1], base)        # same point, same bits
    assert np.any(outs[0] != outs[2])                   # terms really traced
    with pytest.raises(ValueError, match="enabled"):
        eng(params, x, key, noise=NO_NOISE)             # mode switch: replan


# ---- statistical acceptance -----------------------------------------------

def test_mc_thermal_std_matches_analytic():
    """Monte-Carlo thermal std in dequantized units tracks the analytic
    sigma (thermal_sigma_dp through the act/weight scales)."""
    spec = LayerSpec(m=64, k=144, n=16, r_in=8, r_w=4, r_out=8)
    eng, params, x = _case([spec], seed=1, m=64, noise=THERMAL_ONLY)
    clean = CIMInferenceEngine([spec])
    y0 = clean(params, x)
    mc = eng.monte_carlo(params, x, jax.random.PRNGKey(2), 24)
    dev = np.asarray(mc - y0[None])                     # (T, M, N)

    from repro.core.quantization import quantize_act, quantize_weight
    aq = quantize_act(x.astype(jnp.float32), spec.r_in)
    wq = quantize_weight(params[0]["w"], spec.r_w, axis=0)
    sigma_dp = nm.thermal_sigma_dp(THERMAL_ONLY, spec.r_out,
                                   eng.plan.layers[0].g0)
    want = sigma_dp * np.asarray(aq.scale) * np.asarray(wq.scale).ravel()
    got = dev.std(axis=(0, 1))                          # per column
    ratio = got / want
    assert abs(np.median(ratio) - 1.0) < 0.12, (np.median(ratio), ratio)


def test_calibration_residue_within_2lsb_bound():
    """Fig. 19: offsets inside the 7b calibration range reduce to the
    quantization residue, bounded by 2 calibration LSBs; saturating columns
    (the 'few dysfunctional columns') may exceed it."""
    noise = NoiseConfig()
    raw = nm.sample_sa_offsets(jax.random.PRNGKey(0), 2048, noise)
    res = np.asarray(nm.calibration_residue(raw, noise))
    lsb, rng = DEFAULT_MACRO.cal_lsb_v, DEFAULT_MACRO.cal_range_v
    in_range = np.abs(np.asarray(raw)) <= rng - 2 * lsb
    assert in_range.sum() > 1000                        # test has teeth
    assert np.abs(res[in_range]).max() <= 2 * lsb
    # post-layout sigma is ~1.7x the range/2 -> some columns must saturate
    assert (np.abs(res) > 2 * lsb).any()


def test_engine_vs_fakequant_noise_stats_agree():
    """Engine MC deviations match the fakequant training path's on a small
    layer (shared thermal expression + shared physical-column offsets)."""
    noise = NoiseConfig()
    cfg_f = cl.CIMConfig(mode="fakequant", noise=noise)
    params = cl.init_cim_linear(jax.random.PRNGKey(0), 144, 16, cfg=cfg_f)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (64, 144)))
    clean = {m: cl.cim_linear_apply(params, x,
                                    cfg_f.replace(mode=m, noise=NO_NOISE))
             for m in ("fakequant", "engine")}

    def mc_std(mode, trials=16):
        cfg = cfg_f.replace(mode=mode)
        devs = [np.asarray(cl.cim_linear_apply(
            params, x, cfg, key=jax.random.PRNGKey(100 + t)) - clean[mode])
            for t in range(trials)]
        return np.stack(devs).std()

    s_fq, s_eng = mc_std("fakequant"), mc_std("engine")
    assert 0.75 < s_eng / s_fq < 1.33, (s_eng, s_fq)


# ---- physical-column SA offsets (satellite bugfix) ------------------------

def test_column_residues_shared_across_col_tiles():
    """Two col tiles mapping to the same physical column see the same
    residue: channels j and j + ch_per_tile share one comparator."""
    noise = NoiseConfig()
    for r_w, ch in ((4, 64), (2, 128), (1, 256), (3, 64)):
        assert nm.channels_per_col_tile(r_w) == ch
        res = np.asarray(nm.sample_column_residues(
            jax.random.PRNGKey(0), 2 * ch, r_w, noise))
        np.testing.assert_array_equal(res[:ch], res[ch:])
        assert np.any(res[:ch] != 0.0)


def _dup_column_params(k, n, seed=0):
    """Params whose second half of weight columns duplicates the first."""
    cfg = cl.CIMConfig(r_in=4, r_w=4)
    p = cl.init_cim_linear(jax.random.PRNGKey(seed), k, n, cfg=cfg)
    w = p["w"]
    p["w"] = jnp.concatenate([w[:, :n // 2], w[:, :n // 2]], axis=1)
    return p


@pytest.mark.parametrize("mode", ["fakequant", "engine", "sim"])
def test_same_physical_column_same_residue_end_to_end(mode):
    """With thermal off and duplicated weight columns, channels 64 apart
    (r_w=4 -> one col-tile budget) see identical static offsets, so both
    output halves are identical — training, engine AND voltage-sim paths."""
    noise = NoiseConfig(thermal_rms_lsb8=0.0)
    cfg = cl.CIMConfig(mode=mode, r_in=4, r_w=4, noise=noise)
    p = _dup_column_params(144, 128)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (8, 144)))
    y = np.asarray(cl.cim_linear_apply(p, x, cfg, key=jax.random.PRNGKey(2)))
    np.testing.assert_array_equal(y[:, :64], y[:, 64:])
    # the offsets do something: a different key moves the output
    y2 = np.asarray(cl.cim_linear_apply(p, x, cfg,
                                        key=jax.random.PRNGKey(3)))
    assert np.any(y != y2)


# ---- noise-model bugfix sweep regressions ---------------------------------

def test_noiseconfig_none_is_staticmethod():
    """Regression: NoiseConfig.none() was an instance-method-shaped
    constructor; calling it on an instance raised TypeError."""
    assert NoiseConfig.none().enabled is False
    assert NO_NOISE.none().enabled is False             # instance call works


def test_settle_fraction_traces_over_arrays():
    noise = NoiseConfig()
    units = jnp.arange(1, 33)
    s = jax.vmap(lambda u: nm.settle_fraction(u, 5.0, noise))(units)
    assert s.shape == (32,)
    assert bool(jnp.all((s > 0.0) & (s < 1.0)))
    assert bool(jnp.all(jnp.diff(s) < 0))               # tau grows with units
    sj = jax.jit(nm.settle_fraction, static_argnums=(1, 2))(units, 5.0, noise)
    np.testing.assert_allclose(np.asarray(sj), np.asarray(s))
    assert float(nm.settle_fraction(4, 5.0, NO_NOISE)) == 1.0


def test_disabled_paths_follow_dtype():
    z = nm.sample_thermal(jax.random.PRNGKey(0), (4, 4), NO_NOISE,
                          dtype=jnp.bfloat16)
    assert z.dtype == jnp.bfloat16 and float(jnp.abs(z).max()) == 0.0
    on = nm.sample_thermal(jax.random.PRNGKey(0), (4,), NoiseConfig(),
                           dtype=jnp.bfloat16)
    assert on.dtype == jnp.bfloat16
    v = jnp.ones((3, 5), jnp.bfloat16)
    e = nm.charge_injection_error(v, v, NO_NOISE)
    assert e.dtype == jnp.bfloat16 and e.shape == (3, 5)


def test_dsci_adc_noise_with_per_channel_gamma():
    """Regression: the ladder-mismatch term crashed on per-channel ABN
    gamma ((N,) x (r_out,) broadcast); the per-step draw is now shared
    across columns with per-channel magnitude."""
    from repro.core.cim_macro import dsci_adc
    v = 0.01 * jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    gamma = jnp.linspace(1.0, 8.0, 16)
    code = dsci_adc(v, r_out=8, gamma=gamma, beta_v=jnp.zeros(16),
                    sa_offset_v=jnp.zeros(16), cfg=DEFAULT_MACRO,
                    noise=NoiseConfig(), key=jax.random.PRNGKey(1))
    assert code.shape == (4, 16)
    assert bool(jnp.all((code >= 0) & (code <= 255)))


def test_charge_injection_gain_matches_recursion():
    """The closed form equals the literal per-step recursion when every
    input bit contributes the same per-bit deviation."""
    noise, cfg = NoiseConfig(), DEFAULT_MACRO
    for r_in in (1, 2, 4, 8):
        a, vbar = cfg.alpha_mb(), 0.01
        v_ideal = v_noisy = 0.0
        for _ in range(r_in):
            v_noisy = (a * v_noisy + (1 - a) * vbar
                       + noise.kappa_in * vbar - noise.kappa_acc * v_noisy)
            v_ideal = a * v_ideal + (1 - a) * vbar
        got = nm.charge_injection_gain(r_in, noise, cfg)
        want = (v_noisy - v_ideal) / v_ideal
        assert abs(got - want) < 5e-4, (r_in, got, want)
    assert nm.charge_injection_gain(8, NO_NOISE, cfg) == 0.0


# ---- reporting + model integration ---------------------------------------

def test_perf_report_echoes_noise_settings():
    specs = [LayerSpec(m=8, k=144, n=16, r_in=4, r_w=2)]
    noisy = CIMInferenceEngine(specs, EngineConfig(noise=NoiseConfig()))
    rep = noisy.perf_report()
    assert rep["noise"]["enabled"] is True
    assert rep["noise"]["thermal_rms_lsb8"] == NoiseConfig().thermal_rms_lsb8
    assert rep["layers"][0]["noise"]["sa_sigma_v"] == NoiseConfig().sa_sigma_v
    clean = CIMInferenceEngine(specs).perf_report()
    assert clean["noise"] == {"enabled": False}
    assert "noise" not in clean["layers"][0]


def test_lenet_forward_engine_noise_smoke():
    """cim.noise no longer raises in mode='engine': the whole LeNet runs
    noise-injected through one plan, deterministically."""
    from repro.models import cnn
    cfg = cl.CIMConfig(mode="engine", r_in=4, r_w=2, noise=NoiseConfig())
    params = cnn.init_lenet(jax.random.PRNGKey(0), cim=cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 28, 28, 1))
    key = jax.random.PRNGKey(2)
    y = cnn.lenet_forward(params, x, cfg, key=key)
    assert y.shape == (2, 10)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(cnn.lenet_forward(params, x, cfg, key=key)))
    y_clean = cnn.lenet_forward(params, x, cfg.replace(noise=NO_NOISE))
    assert bool(jnp.any(y != y_clean))


@pytest.mark.slow
def test_lenet_monte_carlo_noise_sweep_slow():
    """Full-scale seeded MC sweep on LeNet (scheduled CI): accuracy
    degrades monotonically-ish with noise scale, every point reproducible."""
    from repro.data.pseudo_mnist import make_dataset
    from repro.models.cnn import (init_lenet, lenet_engine,
                                  lenet_params_list)
    _, _, xte, _ = make_dataset(n_train=1, n_test=32)
    imgs = jnp.asarray(xte)[..., None]
    base = NoiseConfig()
    rms = []
    for scale in (0.25, 1.0, 4.0):
        noise = base.replace(thermal_rms_lsb8=base.thermal_rms_lsb8 * scale,
                             sa_sigma_v=base.sa_sigma_v * scale)
        cim = cl.CIMConfig(mode="engine", r_in=4, r_w=2, noise=noise)
        params = lenet_params_list(init_lenet(jax.random.PRNGKey(0),
                                              cim=cim))
        eng = lenet_engine(32, cim=cim)
        mc = eng.monte_carlo(params, imgs, jax.random.PRNGKey(1), 4)
        assert mc.shape == (4, 32, 10)
        np.testing.assert_array_equal(
            np.asarray(mc),
            np.asarray(eng.monte_carlo(params, imgs, jax.random.PRNGKey(1),
                                       4)))
        clean = lenet_engine(32, cim=cim.replace(noise=NO_NOISE))(
            params, imgs)
        rms.append(float(jnp.sqrt(jnp.mean((mc - clean[None]) ** 2))))
        assert jnp.all(jnp.isfinite(mc))
    assert rms[0] < rms[1] < rms[2], rms