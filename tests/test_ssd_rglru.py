"""Sequence-mixer correctness: chunked SSD and RG-LRU scans vs loops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:               # degrade to the deterministic stub
    from hypofallback import given, settings, st

from repro.models.mamba2 import ssd_chunked, ssd_naive
from repro.models.rglru import rglru_scan


@pytest.mark.parametrize("L,chunk", [(16, 4), (37, 8), (64, 64), (100, 16)])
def test_ssd_chunked_vs_naive(L, chunk):
    key = jax.random.PRNGKey(L)
    B, H, P, G, N = 2, 4, 8, 1, 16
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, G, N))
    Cm = jax.random.normal(ks[4], (B, L, G, N))
    y1, s1 = ssd_chunked(xh, dt, a, Bm, Cm, chunk=chunk)
    y2, s2 = ssd_naive(xh, dt, a, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_ssd_initial_state_carried():
    key = jax.random.PRNGKey(0)
    B, L, H, P, G, N = 1, 24, 2, 4, 1, 8
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, G, N))
    Cm = jax.random.normal(ks[4], (B, L, G, N))
    # full pass == two half passes with state handoff
    y_full, s_full = ssd_chunked(xh, dt, a, Bm, Cm, chunk=8)
    y1, s1 = ssd_chunked(xh[:, :12], dt[:, :12], a, Bm[:, :12], Cm[:, :12],
                         chunk=4)
    y2, s2 = ssd_chunked(xh[:, 12:], dt[:, 12:], a, Bm[:, 12:], Cm[:, 12:],
                         chunk=4, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(1, 40), st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_rglru_scan_vs_loop(L, seed):
    key = jax.random.PRNGKey(seed)
    B, W = 2, 5
    a = jax.nn.sigmoid(jax.random.normal(key, (B, L, W)))
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, L, W))
    h_scan = rglru_scan(a, b)
    h = jnp.zeros((B, W))
    outs = []
    for t in range(L):
        h = a[:, t] * h + b[:, t]
        outs.append(h)
    np.testing.assert_allclose(np.asarray(h_scan),
                               np.asarray(jnp.stack(outs, 1)),
                               rtol=1e-5, atol=1e-5)
