"""HLO analyzer on synthetic modules + perf model anchors + integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mapping import LayerSpec
from repro.launch.hlo_analysis import HLOModule, analyze
from repro.perfmodel import AcceleratorPerfModel, EnergyModel
from repro.perfmodel.macro_perf import cim_eval_time_ns, cycle_model


def test_hlo_analyzer_on_real_module():
    """Compile a tiny jitted fn and check flops counting ~ 2*M*N*K."""
    m, k, n = 64, 128, 32

    @jax.jit
    def f(a, b):
        return a @ b

    lowered = f.lower(jax.ShapeDtypeStruct((m, k), jnp.float32),
                      jax.ShapeDtypeStruct((k, n), jnp.float32))
    txt = lowered.compile().as_text()
    mod = HLOModule(txt)
    assert abs(mod.flops() - 2 * m * n * k) / (2 * m * n * k) < 0.01


def test_hlo_while_multiplier():
    """scan body flops must be multiplied by trip count."""
    k = 64

    @jax.jit
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    lowered = f.lower(jax.ShapeDtypeStruct((k, k), jnp.float32),
                      jax.ShapeDtypeStruct((k, k), jnp.float32))
    mod = HLOModule(lowered.compile().as_text())
    want = 10 * 2 * k ** 3
    assert abs(mod.flops() - want) / want < 0.05


def test_hlo_collectives_synthetic():
    txt = """
HloModule test

ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %p = f32[8,16]{1,0} parameter(0)
  ROOT %ar = f32[8,16]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
}
"""
    mod = HLOModule(txt)
    c = mod.collective_bytes()
    assert c["all-reduce"]["bytes"] == 8 * 16 * 4


_SCATTER_TXT = """
HloModule scatter_test

%assign (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] maximum(%a, %b)
}

ENTRY %main (state: f32[512,128], ids: s32[4,1], upd: f32[4,128]) -> f32[512,128] {
  %state = f32[512,128]{1,0} parameter(0)
  %ids = s32[4,1]{1,0} parameter(1)
  %upd = f32[4,128]{1,0} parameter(2)
  %g = f32[4,128]{1,0} gather(%state, %ids), offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=1, slice_sizes={1,128}
  ROOT %sc = f32[512,128]{1,0} scatter(%state, %ids, %upd), update_window_dims={1}, inserted_window_dims={0}, scatter_dims_to_operand_dims={0}, index_vector_dim=1, to_apply=%assign
}
"""


def test_hlo_scatter_gather_synthetic():
    """scatter charges its update rows, not the whole operand; gather
    charges the gathered rows.  The generic 2x-result rule would bill the
    scatter at 2 x 512x128x4 bytes (the full slot-state buffer) per decode
    step instead of the 4 updated rows."""
    mod = HLOModule(_SCATTER_TXT)
    upd_bytes = 4 * 128 * 4
    assert mod.hbm_bytes() == 2 * upd_bytes + 2 * upd_bytes


_VMEM_TXT = """
HloModule vmem_test

ENTRY %main (state: f32[512,128], ids: s32[4,1], upd: f32[4,128]) -> f32[512,128] {
  %state = f32[512,128]{1,0} parameter(0)
  %ids = s32[4,1]{1,0} parameter(1)
  %upd = f32[4,128]{1,0} parameter(2)
  %g = f32[4,128]{1,0} gather(%state, %ids), offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=1, slice_sizes={1,128}, metadata={op_name="jit(f)/vmem_kernel/gather"}
  %ds = f32[4,128]{1,0} dynamic-slice(%state, %ids, %ids), dynamic_slice_sizes={4,128}, metadata={op_name="jit(f)/vmem_kernel/dynamic-slice"}
  ROOT %dus = f32[512,128]{1,0} dynamic-update-slice(%state, %upd, %ids, %ids), metadata={op_name="jit(f)/vmem_kernel/dynamic-update-slice"}
}
"""


def test_hlo_vmem_gather_slice_dma_accounted():
    """In a vmem_kernel computation gather/dynamic-slice count once as the
    HBM->VMEM DMA read stream (they used to be silently dropped), and the
    dynamic-update-slice is folded into its paired read."""
    mod = HLOModule(_VMEM_TXT)
    row_bytes = 4 * 128 * 4
    assert mod.hbm_bytes() == 2 * row_bytes   # gather DMA + slice DMA, 1x each


def test_hlo_dynamic_slice_live_module():
    """A compiled KV-cache-style read is billed for the slice it moves,
    never the resident buffer."""
    state = jax.ShapeDtypeStruct((4096, 256), jnp.float32)

    @jax.jit
    def f(s, i):
        return jax.lax.dynamic_slice(s, (i, 0), (4, 256))

    txt = f.lower(state, jax.ShapeDtypeStruct((), jnp.int32)) \
        .compile().as_text()
    b = HLOModule(txt).hbm_bytes()
    assert 0 < b < 4096 * 256 * 4


def test_cycle_model_regimes():
    """Eq. 9/10: deep-input layers are input-dominated; wide-output layers
    output-dominated."""
    deep = cycle_model(LayerSpec(m=1, k=9 * 512, n=16, r_in=8, r_w=4,
                                 kernel=(3, 3)))
    wide = cycle_model(LayerSpec(m=1, k=9 * 4, n=512, r_in=1, r_w=4,
                                 r_out=8, kernel=(3, 3)))
    assert deep.n_in > deep.n_out
    assert wide.n_out > wide.n_in


def test_energy_anchors():
    """Calibration targets from the paper (Sec. V / Table I)."""
    em = EnergyModel()
    s8 = LayerSpec(m=1, k=1152, n=256, r_in=8, r_w=1, r_out=8, kernel=(3, 3))
    s1 = LayerSpec(m=1, k=1152, n=256, r_in=1, r_w=1, r_out=1, kernel=(3, 3))
    assert abs(em.macro_tops_per_watt(s8) / 1e3 - 1.2) < 0.15     # 1.2 POPS/W
    assert abs(em.macro_tops_per_watt(s1) / 1e3 - 8.0) < 1.0      # 8 POPS/W
    s84 = LayerSpec(m=1, k=1152, n=64, r_in=8, r_w=4, r_out=8, kernel=(3, 3))
    assert 120 < em.macro_tops_per_watt(s84, normalize_8b=True) < 180  # ~150


def test_energy_split_dpl_savings():
    """Fig. 6(c): DP energy drops when fewer units are connected."""
    em = EnergyModel()
    assert em.e_dp_pj(1, 8) < 0.3 * em.e_dp_pj(32, 8)


def test_precision_scaling_quasi_linear():
    em = EnergyModel()
    effs = []
    for r in (1, 2, 4, 8):
        s = LayerSpec(m=1, k=1152, n=256, r_in=r, r_w=1, r_out=r,
                      kernel=(3, 3))
        effs.append(em.macro_tops_per_watt(s))
    assert effs[0] > effs[1] > effs[2] > effs[3]
    assert 4 < effs[0] / effs[3] < 10   # ~6.7x from 8b -> 1b


def test_eval_time_scales_with_precision():
    assert cim_eval_time_ns(1, 1, 1) < 0.25 * cim_eval_time_ns(8, 4, 8)
