"""Checkpointing, fault tolerance, elastic restore, compression, data, optim."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.ckpt import latest_step
from repro.data.lm_data import LMDataConfig, SyntheticLM
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim import compression as gc
from repro.runtime.fault_tolerance import (FTConfig, TrainDriver,
                                           make_fault_injector)


def _tree(key):
    return {"a": jax.random.normal(key, (8, 4)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 7, tree, extra={"note": "x"})
    restored, manifest = load_checkpoint(str(tmp_path), tree)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    save_checkpoint(str(tmp_path), 1, tree)
    # a stale .tmp dir (crashed save) must be ignored
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    tree = _tree(jax.random.PRNGKey(2))
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_elastic_restore_different_template_fails(tmp_path):
    tree = _tree(jax.random.PRNGKey(3))
    save_checkpoint(str(tmp_path), 1, tree)
    bad = {"a": tree["a"]}
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), bad)


def test_fault_tolerant_driver_recovers(tmp_path):
    """Training with injected crashes completes and matches no-crash run."""
    def step_fn(state, batch):
        new = {"w": state["w"] + batch}
        return new, {"loss": float(jnp.sum(new["w"]))}

    def batch_fn(step):
        return jnp.float32(step)

    init = {"w": jnp.float32(0.0)}
    cfg = FTConfig(ckpt_dir=str(tmp_path / "ft"), ckpt_every=3,
                   max_restarts=5)
    driver = TrainDriver(cfg, step_fn, batch_fn, state_template=init)
    injector = make_fault_injector({5: 1, 8: 2})
    state, hist = driver.run(init, 10, fault_injector=injector)
    assert driver.restarts == 3
    # deterministic data + restart-from-ckpt => same final state as clean run
    assert float(state["w"]) == sum(range(10))


def test_straggler_detection(tmp_path):
    import time

    def step_fn(state, batch):
        if int(batch) == 8:
            time.sleep(0.3)
        else:
            time.sleep(0.01)
        return state, {"loss": 0.0}

    cfg = FTConfig(ckpt_dir=str(tmp_path / "st"), ckpt_every=100)
    driver = TrainDriver(cfg, step_fn, lambda s: s, state_template={})
    _, hist = driver.run({}, 10)
    assert any(h.straggler for h in hist if h.step == 8)


def test_compression_error_feedback_converges():
    """int8 EF-compressed SGD reaches the optimum of a quadratic."""
    w = jnp.array([5.0, -3.0, 2.0])
    target = jnp.array([1.0, 1.0, 1.0])
    err = gc.init_error_buffer({"w": w})

    for _ in range(300):
        g = {"w": 2 * (w - target)}
        gq, err = gc.compressed_grads(g, err)
        w = w - 0.05 * gq["w"]
    np.testing.assert_allclose(np.asarray(w), np.asarray(target), atol=1e-2)


def test_compression_roundtrip_bound():
    key = jax.random.PRNGKey(0)
    g = {"x": jax.random.normal(key, (128,)) * 10}
    err = gc.init_error_buffer(g)
    codes, scales, new_err = gc.compress(g, err)
    deq = gc.decompress(codes, scales)
    step = float(scales["x"])
    assert np.max(np.abs(np.asarray(deq["x"]) - np.asarray(g["x"]))) <= step
    # error buffer carries exactly the residual
    np.testing.assert_allclose(np.asarray(new_err["x"]),
                               np.asarray(g["x"] - deq["x"]), rtol=1e-5, atol=1e-6)


def test_data_determinism_and_sharding():
    cfg = LMDataConfig(vocab_size=128, seq_len=16, global_batch=8)
    ds = SyntheticLM(cfg)
    a1, b1 = ds.batch_at(5)
    a2, b2 = ds.batch_at(5)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    # labels are next tokens
    np.testing.assert_array_equal(a1[:, 1:], b1[:, :-1])
    # shards are disjoint deterministic streams
    s0, _ = ds.batch_at(5, shard=0, n_shards=2)
    s1, _ = ds.batch_at(5, shard=1, n_shards=2)
    assert s0.shape[0] == 4 and not np.array_equal(s0, s1)


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([4.0, -2.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    for _ in range(400):
        g = {"w": 2 * params["w"]}
        params, state, m = adamw_update(params, g, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert int(state["step"]) == 400


def test_grad_clipping():
    from repro.optim.adamw import clip_by_global_norm
    g = {"a": jnp.full((100,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 99
    from repro.optim.adamw import global_norm
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
