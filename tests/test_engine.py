"""Precision-scalable inference runtime: planning, dispatch, bit-exactness.

The acceptance bar: for every supported precision the engine's Pallas
schedule must agree *bit-exactly* with the pure-jnp digital reference under
NO_NOISE, including the multi-row-tile digital partial-sum requantization
path (K > 1152) and the column-tile path (N > 64 channels at r_w=4).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cim_layers as cl
from repro.core.mapping import LayerSpec
from repro.runtime import (CIMInferenceEngine, EngineConfig, plan_network,
                           run_network, run_network_reference)

R_INS = (1, 2, 4, 8)
R_WS = (1, 2, 4)


def _engine_case(specs, seed=0, m=8):
    eng = CIMInferenceEngine(specs)
    params = eng.init_params(jax.random.PRNGKey(seed))
    x = jax.nn.relu(
        jax.random.normal(jax.random.PRNGKey(seed + 1), (m, specs[0].k)))
    return eng, params, x


@pytest.mark.parametrize("r_w", R_WS)
@pytest.mark.parametrize("r_in", R_INS)
def test_single_layer_bitexact_precision_grid(r_in, r_w):
    specs = [LayerSpec(m=8, k=72, n=16, r_in=r_in, r_w=r_w, r_out=8)]
    eng, params, x = _engine_case(specs, seed=r_in * 10 + r_w)
    y = eng(params, x)
    y_ref = eng.reference(params, x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


@pytest.mark.parametrize("r_out", (2, 4, 6, 8))
def test_single_layer_bitexact_r_out(r_out):
    specs = [LayerSpec(m=8, k=72, n=16, r_in=4, r_w=2, r_out=r_out)]
    eng, params, x = _engine_case(specs, seed=r_out)
    np.testing.assert_array_equal(np.asarray(eng(params, x)),
                                  np.asarray(eng.reference(params, x)))


@pytest.mark.parametrize("r_in", R_INS)
def test_two_layer_network_bitexact(r_in):
    """Acceptance criterion: >=2-layer network end-to-end per r_in."""
    r_w = min(r_in, 4)
    specs = [LayerSpec(m=8, k=144, n=64, r_in=r_in, r_w=r_w, r_out=8),
             LayerSpec(m=8, k=64, n=32, r_in=r_in, r_w=r_w, r_out=8)]
    eng, params, x = _engine_case(specs, seed=r_in)
    y = eng(params, x)
    y_ref = eng.reference(params, x)
    assert y.shape == (8, 32)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


def test_multi_tile_digital_recombination():
    """K > 1152 splits into row tiles (digital partial-sum requantization);
    N > 64 at r_w=4 splits into column tiles."""
    specs = [LayerSpec(m=4, k=2304, n=80, r_in=8, r_w=4, r_out=8)]
    eng, params, x = _engine_case(specs, seed=3, m=4)
    lp = eng.plan.layers[0]
    assert len(lp.k_slices) == 2 and lp.mp.needs_digital_accum
    assert len(lp.n_slices) == 2
    np.testing.assert_array_equal(np.asarray(eng(params, x)),
                                  np.asarray(eng.reference(params, x)))


def test_mixed_precision_network_shares_variants():
    """Per-layer precisions dispatch to a deduplicated variant table."""
    specs = [LayerSpec(m=8, k=72, n=64, r_in=8, r_w=4),
             LayerSpec(m=8, k=64, n=64, r_in=2, r_w=1),
             LayerSpec(m=8, k=64, n=16, r_in=8, r_w=4)]
    eng, params, x = _engine_case(specs, seed=7)
    assert len(eng.plan.precisions) == 2        # (8,4,8) reused by layer 3
    np.testing.assert_array_equal(np.asarray(eng(params, x)),
                                  np.asarray(eng.reference(params, x)))


def test_plan_validates_layer_chain():
    with pytest.raises(ValueError, match="chain mismatch"):
        plan_network([LayerSpec(m=1, k=8, n=16), LayerSpec(m=1, k=32, n=8)])


def test_plan_counts_macro_evals():
    plan = plan_network([LayerSpec(m=1, k=2304, n=80, r_in=8, r_w=4)])
    assert plan.total_macro_evals == 4          # 2 row tiles x 2 col tiles


def test_run_network_functional_entry():
    """Module-level entry points accept a hand-built plan."""
    plan = plan_network([LayerSpec(m=8, k=40, n=16)], EngineConfig())
    params = CIMInferenceEngine(
        [LayerSpec(m=8, k=40, n=16)]).init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 40))
    y = run_network(plan, params, x)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(run_network_reference(plan, params, x)))


def test_engine_mode_matches_fakequant_layer():
    """cim_layers mode="engine" tracks the fakequant training path: same
    quantizers, same tile math; only the zero-point folding is rearranged
    (inside vs outside the ADC floor), so codes may differ by float-ulp on
    exact floor boundaries — bound the output difference by one ADC LSB in
    dequantized units."""
    cfg = cl.CIMConfig(mode="fakequant")
    p = cl.init_cim_linear(jax.random.PRNGKey(0), 144, 32, cfg=cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 144))
    y_fq = cl.cim_linear_apply(p, x, cfg)
    y_eng = cl.cim_linear_apply(p, x, cfg.replace(mode="engine"))
    assert y_eng.shape == y_fq.shape
    err = float(jnp.max(jnp.abs(y_eng - y_fq)))
    scale = float(jnp.max(jnp.abs(y_fq))) + 1e-9
    assert err <= 0.02 * scale, (err, scale)


def test_leading_batch_dims():
    specs = [LayerSpec(m=12, k=40, n=16, r_in=4, r_w=2)]
    eng = CIMInferenceEngine(specs)
    params = eng.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 40))
    y = eng(params, x)
    assert y.shape == (3, 4, 16)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(eng.reference(params, x)))


def test_perf_report_schedule():
    specs = [LayerSpec(m=32, k=576, n=64, r_in=8, r_w=4, kernel=(3, 3)),
             LayerSpec(m=32, k=64, n=32, r_in=8, r_w=4)]
    eng = CIMInferenceEngine(specs)
    rep = eng.perf_report()
    assert set(rep) == {"layers", "per_precision", "noise", "total",
                        "program"}
    assert rep["program"]["plans_built"] == 1
    assert rep["noise"] == {"enabled": False}
    assert len(rep["layers"]) == 2
    assert rep["total"]["tops_per_w"] > 0
    assert "r8x4b" in rep["per_precision"]


def test_perf_report_precision_scaling():
    """Modeled efficiency rises monotonically as precision drops (Fig. 22)."""
    def ee(r_in, r_w):
        specs = [LayerSpec(m=32, k=1152, n=64, r_in=r_in, r_w=r_w,
                           kernel=(3, 3))]
        return CIMInferenceEngine(specs).perf_report()["total"]["tops_per_w"]
    effs = [ee(8, 4), ee(4, 4), ee(2, 2), ee(1, 1)]
    assert all(a < b for a, b in zip(effs, effs[1:])), effs
