"""Compiled CIM programs: plan-once/serve-many acceptance suite (ISSUE 5).

The acceptance bar: a compiled `CIMProgram` serves repeated calls with
zero re-planning (engine.PLAN_COUNT) and zero re-tracing (engine.
TRACE_COUNT) after warmup; batch-bucketed dispatch is bit-exact with the
unbucketed engine across ragged batch sizes under NO_NOISE and under a
fixed noise key, on 1 device and (when available) an 8-device mesh; the
compile count is bounded by the bucket ladder; and the legacy entry points
(`run_network`, `CIMInferenceEngine.__call__`) keep working — backed by
the program cache — behind a single non-spammy DeprecationWarning.

Multi-device cases need fake CPU devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_program.py
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cim_layers as cl
from repro.core.mapping import LayerSpec, conv_layer_spec
from repro.core.noise_model import NoiseConfig
from repro.runtime import (BatchBuckets, CIMInferenceEngine, CIMProgram,
                           EngineConfig, ShardingConfig, compile_program,
                           program_cache_stats, program_for_plan,
                           run_network)
from repro.runtime import engine as rt
from repro.runtime.program import DEFAULT_BUCKETS

N_DEV = len(jax.devices())
RAGGED = (1, 3, 7, 17)


def _need(devices: int) -> None:
    if N_DEV < devices:
        pytest.skip(f"needs {devices} devices, jax reports {N_DEV} (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _dense_specs(m=8, k=72, n=16, r_in=4, r_w=2, layers=2):
    specs = [LayerSpec(m=m, k=k, n=n, r_in=r_in, r_w=r_w)]
    for _ in range(layers - 1):
        specs.append(LayerSpec(m=m, k=n, n=n, r_in=r_in, r_w=r_w))
    return specs


def _case(specs, seed=0, cfg=EngineConfig()):
    prog = compile_program(specs, cfg)
    params = prog.init_params(jax.random.PRNGKey(seed))
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                      (32, specs[0].k)))
    return prog, params, x


# ---- bucket ladder ---------------------------------------------------------

def test_bucket_ladder_shape():
    b = BatchBuckets()
    assert [b.bucket_for(m) for m in (1, 2, 3, 7, 8, 17)] == \
        [1, 2, 4, 8, 8, 32]
    assert b.ladder(17) == (1, 2, 4, 8, 16, 32)
    capped = BatchBuckets(min_bucket=4, max_bucket=16)
    assert capped.bucket_for(1) == 4
    assert capped.bucket_for(9) == 16
    assert capped.bucket_for(17) == 32          # cap grid: multiples of 16
    assert capped.bucket_for(33) == 48
    with pytest.raises(ValueError, match=">= 1"):
        BatchBuckets(min_bucket=0)
    with pytest.raises(ValueError, match="max_bucket"):
        BatchBuckets(min_bucket=8, max_bucket=4)
    with pytest.raises(ValueError, match=">= 1"):
        b.bucket_for(0)


# ---- program cache + planning counter --------------------------------------

def test_compile_program_is_cached_and_plans_once():
    specs = _dense_specs(k=40, n=24)
    n0 = rt.PLAN_COUNT["n"]
    p1 = compile_program(specs, EngineConfig())
    n1 = rt.PLAN_COUNT["n"]
    p2 = compile_program(specs, EngineConfig())
    p3 = compile_program(specs, EngineConfig(),
                         activations=["relu", "none"], pools=[1, 1])
    assert p1 is p2 and p1 is p3                # canonical epilogue key
    assert rt.PLAN_COUNT["n"] == n1             # no re-plan on cache hits
    assert n1 >= n0 + 0                         # (first call may have hit)
    stats = program_cache_stats()
    assert stats["programs"] >= 1 and stats["lookups"] >= 3


def test_program_hashable_and_engine_shares_it():
    specs = _dense_specs(k=48, n=16)
    prog = compile_program(specs)
    assert hash(prog) == hash(compile_program(specs))
    eng = CIMInferenceEngine(specs)
    assert eng.compile() is prog                # engine wraps the cache
    assert eng.plan is prog.plan
    assert isinstance(prog, CIMProgram)
    with pytest.raises(AttributeError, match="immutable"):
        prog.plan = None


def test_program_for_plan_backs_run_network():
    specs = _dense_specs(k=56, n=16)
    prog = compile_program(specs)
    assert program_for_plan(prog.plan) is prog
    params = prog.init_params(jax.random.PRNGKey(0))
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (4, 56)))
    calls0 = prog.stats()["run_calls"]
    y = run_network(prog.plan, params, x)
    assert prog.stats()["run_calls"] == calls0 + 1
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(prog.run(params, x)))


def test_cim_layers_engine_mode_plans_once():
    """Satellite: the per-call re-plan in _engine_forward is gone — after
    the first call at a (shape, CIMConfig), plans AND traces stay flat."""
    cfg = cl.CIMConfig(mode="engine", r_in=4, r_w=2)
    p = cl.init_cim_linear(jax.random.PRNGKey(0), 88, 24, cfg=cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 88))
    y0 = np.asarray(cl.cim_linear_apply(p, x, cfg))       # warmup
    plans0, traces0 = rt.PLAN_COUNT["n"], rt.TRACE_COUNT["n"]
    for _ in range(3):
        y = np.asarray(cl.cim_linear_apply(p, x, cfg))
    assert rt.PLAN_COUNT["n"] == plans0, "engine mode re-planned per call"
    assert rt.TRACE_COUNT["n"] == traces0, "engine mode re-traced per call"
    np.testing.assert_array_equal(y, y0)
    # a ragged batch inside the same bucket also stays flat
    np.asarray(cl.cim_linear_apply(p, x[:5], cfg))        # bucket-8 warmup?
    plans1, traces1 = rt.PLAN_COUNT["n"], rt.TRACE_COUNT["n"]
    np.asarray(cl.cim_linear_apply(p, x[:7], cfg))        # same bucket 8
    assert rt.PLAN_COUNT["n"] == plans1
    assert rt.TRACE_COUNT["n"] == traces1


def test_cim_layers_engine_conv_plans_once():
    cfg = cl.CIMConfig(mode="engine", r_in=4, r_w=2)
    p = cl.init_cim_linear(jax.random.PRNGKey(0), 3 * 3 * 4, 8, cfg=cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (4, 10, 10, 4))
    y0 = np.asarray(cl.cim_conv2d_apply(p, x, cfg))       # warmup
    plans0, traces0 = rt.PLAN_COUNT["n"], rt.TRACE_COUNT["n"]
    for _ in range(3):
        y = np.asarray(cl.cim_conv2d_apply(p, x, cfg))
    assert rt.PLAN_COUNT["n"] == plans0
    assert rt.TRACE_COUNT["n"] == traces0
    np.testing.assert_array_equal(y, y0)


def test_lenet_forward_engine_plans_once():
    from repro.models import cnn
    cfg = cl.CIMConfig(mode="engine", r_in=4, r_w=2)
    params = cnn.init_lenet(jax.random.PRNGKey(0), cim=cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 28, 28, 1))
    y0 = np.asarray(cnn.lenet_forward(params, x, cfg))    # warmup
    plans0, traces0 = rt.PLAN_COUNT["n"], rt.TRACE_COUNT["n"]
    y = np.asarray(cnn.lenet_forward(params, x, cfg))
    assert rt.PLAN_COUNT["n"] == plans0
    assert rt.TRACE_COUNT["n"] == traces0
    np.testing.assert_array_equal(y, y0)


# ---- zero re-tracing after warmup ------------------------------------------

def test_bound_program_zero_retrace_after_warmup():
    """Acceptance: repeated serves — including different ragged sizes that
    share a bucket — reuse one executable."""
    prog, params, x = _case(_dense_specs(k=64, n=16), seed=3)
    bound = prog.bind(params)
    bound.serve(x[:8])                                    # warm bucket 8
    plans0, traces0 = rt.PLAN_COUNT["n"], rt.TRACE_COUNT["n"]
    for m in (5, 6, 7, 8):
        bound.serve(x[:m])
    assert rt.PLAN_COUNT["n"] == plans0
    assert rt.TRACE_COUNT["n"] == traces0
    st = prog.stats()
    assert st["bucket_hits"] >= 4


def test_compile_count_bounded_by_ladder():
    """Satellite: every batch size 1..17 lands on a ladder rung; the
    executable count (and the trace count) is bounded by the rung count,
    not the batch-size count."""
    specs = _dense_specs(k=96, n=16, r_in=2, r_w=1)       # unique -> fresh
    prog, params, x = _case(specs, seed=5)
    bound = prog.bind(params)
    traces0 = rt.TRACE_COUNT["n"]
    for m in range(1, 18):
        y = bound.serve(x[:m])
        assert y.shape == (m, 16)
    ladder = prog.buckets.ladder(17)
    st = prog.stats()
    assert st["executables_compiled"] <= len(ladder)
    assert rt.TRACE_COUNT["n"] - traces0 <= len(ladder)
    assert st["bucket_misses"] <= len(ladder)
    assert st["bucket_hits"] == 17 - st["bucket_misses"]


# ---- bucketed serving bit-exactness ----------------------------------------

@pytest.mark.parametrize("m", RAGGED)
def test_bucketed_serve_bitexact_dense(m):
    """Acceptance: ragged batches through the bucket ladder are bit-exact
    with the unbucketed engine (exact-shape run), bound and unbound."""
    prog, params, x = _case(_dense_specs(k=72, n=20), seed=m)
    want = np.asarray(prog.run(params, x[:m]))
    np.testing.assert_array_equal(
        np.asarray(prog.serve(params, x[:m])), want)
    np.testing.assert_array_equal(
        np.asarray(prog.bind(params).serve(x[:m])), want)


@pytest.mark.parametrize("m", RAGGED)
def test_bucketed_serve_bitexact_noise_fixed_key(m):
    """Acceptance: same contract under a fixed noise key — the fixed-size
    row-block thermal draws make the padded extent invisible to live
    rows."""
    prog, params, x = _case(_dense_specs(k=144, n=16),
                            seed=m, cfg=EngineConfig(noise=NoiseConfig()))
    key = jax.random.PRNGKey(40 + m)
    want = np.asarray(prog.run(params, x[:m], key))
    bound = prog.bind(params)
    np.testing.assert_array_equal(np.asarray(bound.serve(x[:m], key)), want)
    # the oracle agrees too (kernel/reference lockstep survives bucketing)
    np.testing.assert_array_equal(
        np.asarray(bound.reference(x[:m], key)), want)


@pytest.mark.parametrize("m", (1, 3))
def test_bucketed_serve_bitexact_conv_lenet(m):
    """Conv front-end: a bucket-padded LeNet batch (padding whole images)
    is bit-exact with the exact-shape engine, clean and noisy."""
    from repro.models.cnn import lenet_engine_specs, lenet_program
    cim = cl.CIMConfig(mode="engine", r_in=4, r_w=2)
    specs, acts, pools = lenet_engine_specs(4, h=12, w=12, cim=cim)
    prog = compile_program(specs, EngineConfig(), activations=acts,
                           pools=pools)
    params = prog.init_params(jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (4, 12, 12, 1))
    want = np.asarray(prog.run(params, x[:m]))
    np.testing.assert_array_equal(
        np.asarray(prog.bind(params).serve(x[:m])), want)
    # noisy LeNet, fixed key
    nprog = compile_program(specs, EngineConfig(noise=NoiseConfig()),
                            activations=acts, pools=pools)
    key = jax.random.PRNGKey(9)
    want_n = np.asarray(nprog.run(params, x[:m], key))
    np.testing.assert_array_equal(
        np.asarray(nprog.bind(params).serve(x[:m], key)), want_n)
    assert lenet_program(4, 12, 12, 1, 10, cim) is prog


@pytest.mark.parametrize("devices", (1, 8))
def test_bucketed_serve_bitexact_sharded(devices):
    """Acceptance: bucketing composes with the multi-macro dispatch — a
    sharded program's bucketed serve matches the unsharded, unbucketed
    engine bit for bit on 1- and 8-device meshes, clean and noisy."""
    _need(devices)
    specs = [LayerSpec(m=8, k=144, n=320, r_in=4, r_w=4),   # col kind
             LayerSpec(m=8, k=320, n=16, r_in=4, r_w=4)]    # rows kind
    base = compile_program(specs, EngineConfig())
    cfg = EngineConfig(sharding=ShardingConfig(devices=devices))
    prog = compile_program(specs, cfg)
    params = base.init_params(jax.random.PRNGKey(0))
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (17, 144)))
    for m in (3, 17):
        want = np.asarray(base.run(params, x[:m]))
        np.testing.assert_array_equal(
            np.asarray(prog.bind(params).serve(x[:m])), want)
    ncfg = EngineConfig(noise=NoiseConfig())
    nbase = compile_program(specs, ncfg)
    nprog = compile_program(
        specs, ncfg.replace(sharding=ShardingConfig(devices=devices)))
    key = jax.random.PRNGKey(23)
    want = np.asarray(nbase.run(params, x[:7], key))
    np.testing.assert_array_equal(
        np.asarray(nprog.bind(params).serve(x[:7], key)), want)


def test_serve_batch_concat_pad_split():
    """Satellite: serve_batch fuses requests, serves once, splits — equal
    to serving the concatenated batch (shared activation swing), with one
    executable for the fused bucket."""
    prog, params, x = _case(_dense_specs(k=80, n=16), seed=2)
    bound = prog.bind(params)
    reqs = [x[:1], x[1:4], x[4:9]]                        # 1 + 3 + 5 = 9
    outs = bound.serve_batch(reqs)
    assert [o.shape[0] for o in outs] == [1, 3, 5]
    fused = np.asarray(bound.serve(x[:9]))
    np.testing.assert_array_equal(np.concatenate(
        [np.asarray(o) for o in outs]), fused)
    assert bound.serve_batch([]) == []
    with pytest.raises(ValueError, match="batch-major"):
        bound.serve_batch([x[:2], x[0]])                  # missing batch dim


def test_bind_leaves_weights_behind():
    """BoundProgram serves without the fp32 masters: binding is the only
    consumer of params, and the bind products carry the odd-integer code
    grid."""
    prog, params, x = _case(_dense_specs(k=40, n=12, layers=1), seed=7)
    bound = prog.bind(params)
    want = np.asarray(prog.run(params, x[:4]))
    del params
    got = np.asarray(bound.serve(x[:4]))
    np.testing.assert_array_equal(got, want)
    wqq = np.asarray(bound._binds[0]["wqq"])
    assert np.all(np.abs(wqq % 2) == 1)                   # odd-integer grid


def test_noise_override_through_serve_shares_compile():
    """Operating-point overrides stay traced operands through the program
    path: sweeping noise= through a bound serve does not retrace."""
    prog, params, x = _case(_dense_specs(k=144, n=16, layers=1), seed=9,
                            cfg=EngineConfig(noise=NoiseConfig()))
    bound = prog.bind(params)
    key = jax.random.PRNGKey(3)
    base = np.asarray(bound.serve(x[:8], key))            # warm
    t0 = rt.TRACE_COUNT["n"]
    outs = [np.asarray(bound.serve(
        x[:8], key, NoiseConfig(thermal_rms_lsb8=0.52 * s,
                                sa_sigma_v=0.02 * s)))
        for s in (0.25, 1.0, 3.0)]
    assert rt.TRACE_COUNT["n"] == t0, "noise-point sweep recompiled"
    np.testing.assert_array_equal(outs[1], base)
    assert np.any(outs[0] != outs[2])


# ---- observability ---------------------------------------------------------

def test_stats_and_perf_report_echo():
    specs = _dense_specs(k=104, n=16)                     # unique shape
    prog, params, x = _case(specs, seed=11)
    assert prog.stats()["plans_built"] == 1
    prog.bind(params).serve(x[:3])
    st = prog.stats()
    assert st["serve_calls"] == 1 and st["bucket_misses"] == 1
    rep = prog.perf_report()
    assert rep["program"]["executables_compiled"] >= 1
    assert rep["program"]["buckets"] == {"min_bucket": 1, "max_bucket": 0}
    rep2 = CIMInferenceEngine(specs).perf_report()
    assert rep2["program"] == rep["program"]              # shared program


# ---- deprecation hygiene ---------------------------------------------------

def test_legacy_entry_points_warn_once():
    """Satellite: run_network / CIMInferenceEngine.__call__ keep working,
    with a single DeprecationWarning per process pointing at
    compile_program."""
    prog, params, x = _case(_dense_specs(k=32, n=8, layers=1), seed=13)
    eng = CIMInferenceEngine(_dense_specs(k=32, n=8, layers=1))
    rt._DEPRECATION["warned"] = False
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        y1 = eng(params, x[:4])
        y2 = run_network(prog.plan, params, x[:4])
        eng(params, x[:4])
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
           and "compile_program" in str(w.message)]
    assert len(dep) == 1, [str(w.message) for w in rec]
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    # reference / monte_carlo / program paths never warn
    rt._DEPRECATION["warned"] = False
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        eng.reference(params, x[:4])
        prog.bind(params).serve(x[:4])
    assert not [w for w in rec
                if issubclass(w.category, DeprecationWarning)]
    rt._DEPRECATION["warned"] = True                      # keep suite quiet


def test_serve_rejects_empty_batch_and_bad_width():
    prog, params, x = _case(_dense_specs(k=32, n=8, layers=1), seed=17)
    with pytest.raises(ValueError, match="empty batch"):
        prog.bind(params).serve(x[:0])
    with pytest.raises(ValueError, match="input width"):
        prog.bind(params).serve(jnp.ones((4, 31)))


def test_conv_program_batch_bucket_via_cim_conv2d():
    """cim_conv2d_apply at a ragged batch rebuilds the conv spec at the
    bucket and stays bit-exact with the direct (exact-batch) program."""
    cfg = cl.CIMConfig(mode="engine", r_in=4, r_w=2)
    p = cl.init_cim_linear(jax.random.PRNGKey(0), 3 * 3 * 4, 8, cfg=cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (3, 10, 10, 4))
    spec = conv_layer_spec(batch=3, h=10, w=10, c_in=4, c_out=8,
                           kh=3, kw=3, stride=1, padding=1, r_in=4, r_w=2)
    exact = compile_program([spec], cl._engine_config(cfg))
    want = np.asarray(exact.run([p], x))
    got = np.asarray(cl.cim_conv2d_apply(p, x, cfg))
    np.testing.assert_array_equal(got, want)
    assert DEFAULT_BUCKETS.bucket_for(3) == 4             # really padded


# ---- in-flight bucket-ladder edge cases (ISSUE 6) --------------------------

def _toy_lm(capacity=8):
    from repro.runtime.scheduler import CIMDecodeLM, InflightScheduler
    model = CIMDecodeLM.toy(jax.random.PRNGKey(11), d=48, depth=2,
                            vocab=19, r_in=4, r_w=2)
    return model, InflightScheduler(model, capacity=capacity)


def test_admit_crossing_bucket_boundary_mid_decode():
    """Admitting past a ladder rung mid-decode (2 live -> 3rd admitted)
    moves dispatch to the next rung without perturbing the in-flight
    streams (still bit-exact with solo) and without re-tracing beyond
    the new rung's warmup."""
    from repro.runtime.scheduler import Request, decode_sequential
    model, sched = _toy_lm(capacity=4)
    reqs = [Request(uid=u, prompt=(u + 1, u + 2), max_new_tokens=6)
            for u in range(3)]
    # two arrive at step 0 (bucket 2); the third lands mid-decode,
    # pushing the extent across the 2 -> 4 rung boundary
    out = sched.run([(0, reqs[0]), (0, reqs[1]), (3, reqs[2])])
    for r in reqs:
        assert out[r.uid] == decode_sequential(model, r)
    seen = sched.metrics()["extents_seen"]
    assert 2 in seen and 4 in seen                 # boundary really crossed
    assert set(seen) <= set(DEFAULT_BUCKETS.ladder(4))


def test_retire_to_empty_then_readmit():
    """Draining to an idle scheduler and admitting a fresh request later
    reuses slot 0 and the bucket-1 executable; idle ticks advance the
    clock but run no fused step."""
    from repro.runtime.scheduler import Request, decode_sequential
    model, sched = _toy_lm(capacity=2)
    a = Request(uid=0, prompt=(1,), max_new_tokens=2)
    b = Request(uid=1, prompt=(2, 3), max_new_tokens=3)
    out = sched.run([(0, a), (6, b)])              # gap: drains idle first
    assert out[0] == decode_sequential(model, a)
    assert out[1] == decode_sequential(model, b)
    assert sched.finished[1].slot == 0             # slot 0 reused
    assert sched.finished[0].finished_step < sched.finished[1].admitted_step
    assert sched.clock > sched.decode_steps        # idle ticks happened


def test_executables_bounded_by_ladder_across_fuzzed_schedule():
    """Across a fuzzed admit/retire schedule the program's executable
    count stays bounded by the ladder (one per rung per trace signature),
    not by the number of distinct live extents or schedules."""
    from repro.runtime.scheduler import InflightScheduler, Request
    model, sched = _toy_lm(capacity=8)
    rng = np.random.default_rng(123)
    arrivals = []
    for uid in range(12):
        prompt = tuple(int(t) for t in
                       rng.integers(0, 19, size=int(rng.integers(1, 4))))
        arrivals.append((int(rng.integers(0, 10)),
                         Request(uid=uid, prompt=prompt,
                                 max_new_tokens=int(rng.integers(1, 6)))))
    sched.run(arrivals)
    sched2 = InflightScheduler(model, capacity=8)
    sched2.run([(s // 2, Request(uid=100 + r.uid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens))
                for s, r in arrivals])
    rungs = set(DEFAULT_BUCKETS.ladder(8))
    assert set(sched.metrics()["extents_seen"]) <= rungs
    assert set(sched2.metrics()["extents_seen"]) <= rungs
    # executable cache: at most one signature per rung for this model's
    # single (clean, bound, non-reference) serve signature
    st = model.bound.stats()
    assert st["executables_compiled"] <= len(rungs)
    assert st["bucket_misses"] <= len(rungs)
    assert st["bucket_hits"] > st["bucket_misses"]


# ---- shared-input (multi-head) fusion --------------------------------------

def test_shared_input_heads_bitexact_vs_per_head_programs():
    """Q/K/V-style fusion: N heads of one shared input compile as ONE
    program, and every head's output slice is bitwise equal to serving
    that head through its own single-head program (weight quantization,
    ABN, and the ADC epilogue are all per-output-column, so fusion
    changes no column's arithmetic)."""
    from repro.runtime import SharedInputProgram
    cfg = EngineConfig()
    d = 40
    heads = (("q", 24), ("k", 16), ("v", 16))
    for r_in, r_w in ((8, 4), (2, 1)):
        sp = SharedInputProgram.compile(d, heads, cfg, r_in=r_in, r_w=r_w)
        params = sp.init_params(jax.random.PRNGKey(3))
        bind = sp.bind(params)
        x = jax.random.normal(jax.random.PRNGKey(4), (5, d), jnp.float32)
        fused = bind.serve(x)
        assert set(fused) == {"q", "k", "v"}
        for name, n in heads:
            solo_prog = compile_program(
                (LayerSpec(m=8, k=d, n=n, r_in=r_in, r_w=r_w),), cfg,
                activations=("none",))
            solo = solo_prog.bind([params[name]]).serve(x)
            assert fused[name].shape == (5, n)
            np.testing.assert_array_equal(np.asarray(fused[name]),
                                          np.asarray(solo))


def test_shared_input_program_validation():
    from repro.runtime import SharedInputProgram
    with pytest.raises(ValueError, match="duplicate head"):
        SharedInputProgram.compile(16, (("q", 8), ("q", 8)), r_in=4, r_w=2)
    sp = SharedInputProgram.compile(16, (("a", 8), ("b", 4)), r_in=4, r_w=2)
    params = sp.init_params(jax.random.PRNGKey(0))
    assert params["a"]["w"].shape == (16, 8)
    assert params["b"]["abn_beta"].shape == (4,)
    with pytest.raises(ValueError, match="missing head params"):
        sp.bind({"a": params["a"]})
    bad = dict(params, b=dict(params["b"], w=jnp.zeros((16, 5))))
    with pytest.raises(ValueError, match="weight shape"):
        sp.bind(bad)


def test_shared_input_fusion_shares_program_cache():
    """Equal (k, heads, precision, cfg) fusions hit one cache entry, and
    the fused program is the same object the equivalent wide single-layer
    compile returns."""
    from repro.runtime import SharedInputProgram
    sp1 = SharedInputProgram.compile(24, (("g", 32), ("u", 32)),
                                     r_in=4, r_w=2)
    sp2 = SharedInputProgram.compile(24, (("gate", 32), ("up", 32)),
                                     r_in=4, r_w=2)
    assert sp1.program is sp2.program
    wide = compile_program((LayerSpec(m=8, k=24, n=64, r_in=4, r_w=2),),
                           activations=("none",))
    assert wide is sp1.program
