"""Segment-wise activation quantization: fused == solo bit-exactness
(ISSUE 6 satellites).

Three layers of the argument, each tested here:
  1. `quantize_act(segment_ids=...)` computes per-segment min/max with
     exact reductions, so a row's segment statistics equal its solo-run
     statistics bit for bit; the default path is unchanged.
  2. `BoundProgram.serve_batch(..., isolate=True)` tags each request as
     its own segment, making every fused request bit-identical to a solo
     `serve` — across the full precision grid r_in {1,2,4,8} x
     r_w {1,2,4}, clean and under one fixed noise key.
  3. The adversarial case that motivates all of it: a batchmate with a
     100x activation swing.  Legacy fusion (isolate=False) shares the
     dynamic swing and visibly corrupts the small-swing request — the
     historical xfail, asserted as an inequality so it flips loudly if
     fusion semantics drift — while isolate=True is bit-exact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypofallback import given, settings, st

from repro.core.mapping import LayerSpec
from repro.core.noise_model import NoiseConfig
from repro.core.quantization import quantize_act
from repro.runtime import EngineConfig, compile_program, request_noise_ids
from repro.runtime import engine as rt

KEY = jax.random.PRNGKey(3)
NOISE_KEY = jax.random.PRNGKey(77)


def _bound(r_in, r_w, noisy=False, k=40, n=16, depth=2):
    cfg = EngineConfig(noise=NoiseConfig()) if noisy else EngineConfig()
    specs = [LayerSpec(m=8, k=k, n=n, r_in=r_in, r_w=r_w)]
    for _ in range(depth - 1):
        specs.append(LayerSpec(m=8, k=n, n=n, r_in=r_in, r_w=r_w))
    prog = compile_program(specs, cfg)
    return prog.bind(prog.init_params(KEY))


def _requests(sizes, k=40, swing=None, seed=5):
    rng = np.random.default_rng(seed)
    xs = []
    for i, b in enumerate(sizes):
        x = jnp.asarray(np.abs(rng.normal(size=(b, k))), jnp.float32)
        if swing is not None:
            x = x * swing[i]
        xs.append(x)
    return xs


def _solo(bound, xs, key=None):
    return [bound.serve(x, key, segments=jnp.zeros(x.shape[0], jnp.int32),
                        noise_ids=(None if key is None else
                                   request_noise_ids(i, x.shape[0])))
            for i, x in enumerate(xs)]


# ---- quantize_act ----------------------------------------------------------

def test_segment_stats_equal_solo_stats():
    """Each segment's scale/zero equals the stats of quantizing that
    segment's rows alone; identical rows quantize identically with and
    without segment ids."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(6, 9)), jnp.float32)
    seg = jnp.asarray([0, 0, 1, 1, 1, 2], jnp.int32)
    aq = quantize_act(x, 4, segment_ids=seg, num_segments=3)
    assert aq.scale.shape == (6, 1) and aq.zero.shape == (6, 1)
    for s, rows in ((0, slice(0, 2)), (1, slice(2, 5)), (2, slice(5, 6))):
        solo = quantize_act(x[rows], 4)
        assert np.array_equal(aq.q[rows], solo.q), f"segment {s}"
        assert np.array_equal(np.asarray(aq.scale[rows]).ravel(),
                              np.full(rows.stop - rows.start,
                                      float(solo.scale)))
        assert np.array_equal(np.asarray(aq.zero[rows]).ravel(),
                              np.full(rows.stop - rows.start,
                                      float(solo.zero)))


def test_identical_rows_quantize_identically_with_without_segments():
    """The satellite regression: a batch of identical rows produces the
    same codes whether quantized globally or per-row-segment."""
    row = np.linspace(-2.0, 3.0, 12, dtype=np.float32)
    x = jnp.asarray(np.tile(row, (5, 1)))
    plain = quantize_act(x, 4)
    seg = quantize_act(x, 4, segment_ids=jnp.arange(5, dtype=jnp.int32))
    assert np.array_equal(plain.q, seg.q)
    assert np.array_equal(np.asarray(seg.scale).ravel(),
                          np.full(5, float(plain.scale)))
    assert np.array_equal(np.asarray(seg.zero).ravel(),
                          np.full(5, float(plain.zero)))


def test_default_path_untouched_by_segment_kwargs():
    """segment_ids=None must be byte-for-byte the legacy global path."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 7)), jnp.float32)
    a = quantize_act(x, 5)
    b = quantize_act(x, 5, segment_ids=None, num_segments=None)
    assert np.array_equal(a.q, b.q)
    assert float(a.scale) == float(b.scale)
    assert float(a.zero) == float(b.zero)


def test_explicit_scale_zero_override_segments():
    """Caller-pinned scale/zero win over segment stats (calibrated swing
    must stay honored)."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(3, 5)), jnp.float32)
    pinned = quantize_act(x, 4, scale=jnp.float32(0.125),
                          zero=jnp.float32(-1.0),
                          segment_ids=jnp.arange(3, dtype=jnp.int32))
    ref = quantize_act(x, 4, scale=jnp.float32(0.125),
                       zero=jnp.float32(-1.0))
    assert np.array_equal(pinned.q, ref.q)


# ---- fused serve_batch isolation across the precision grid -----------------

@pytest.mark.parametrize("r_in", [1, 2, 4, 8])
@pytest.mark.parametrize("r_w", [1, 2, 4])
def test_isolated_fusion_bit_exact_precision_grid(r_in, r_w):
    """serve_batch(isolate=True) == per-request solo serve, bitwise, for
    every (r_in, r_w) the macro supports, at ragged request sizes."""
    bound = _bound(r_in, r_w)
    xs = _requests([1, 2, 4, 8], swing=[1.0, 3.0, 0.2, 10.0])
    fused = bound.serve_batch(xs, isolate=True)
    solo = _solo(bound, xs)
    for i, (f, s) in enumerate(zip(fused, solo)):
        assert np.array_equal(np.asarray(f), np.asarray(s)), \
            f"request {i} (r_in={r_in}, r_w={r_w})"


@pytest.mark.parametrize("r_in,r_w", [(1, 1), (4, 2), (8, 4)])
def test_isolated_fusion_bit_exact_under_noise(r_in, r_w):
    """The same bit-exactness under one fixed noise key: thermal draws
    follow request_noise_ids identities, not batch position."""
    bound = _bound(r_in, r_w, noisy=True)
    xs = _requests([2, 1, 3], swing=[1.0, 50.0, 0.5])
    fused = bound.serve_batch(xs, NOISE_KEY, isolate=True)
    solo = _solo(bound, xs, NOISE_KEY)
    for i, (f, s) in enumerate(zip(fused, solo)):
        assert np.array_equal(np.asarray(f), np.asarray(s)), f"request {i}"


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1, 2, 3]))
def test_isolated_fusion_fuzzed_sizes_and_swings(seed, n_extra):
    """Fuzzed batchmate count / sizes / swings: isolation never depends
    on who else is in the batch."""
    rng = np.random.default_rng(seed)
    bound = _bound(4, 2)
    sizes = [1] + [int(rng.integers(1, 5)) for _ in range(n_extra)]
    swing = [float(10.0 ** rng.uniform(-2, 2)) for _ in sizes]
    xs = _requests(sizes, swing=swing, seed=seed)
    fused = bound.serve_batch(xs, isolate=True)
    for f, s in zip(fused, _solo(bound, xs)):
        assert np.array_equal(np.asarray(f), np.asarray(s))


# ---- the adversarial batchmate (xfail turned pass) -------------------------

def test_adversarial_swing_batchmate():
    """A 100x-swing batchmate: legacy fusion (isolate=False) shares swing
    statistics and corrupts the small request — the case that failed
    before segment quantization, asserted as an inequality — while
    isolate=True serves it bit-identically to solo."""
    bound = _bound(4, 2)
    xs = _requests([4, 4], swing=[1.0, 100.0])
    solo_small = bound.serve(xs[0])

    legacy = bound.serve_batch(xs, isolate=False)
    assert not np.array_equal(np.asarray(legacy[0]),
                              np.asarray(solo_small)), \
        "legacy shared-swing fusion unexpectedly matched solo — the " \
        "adversarial case this PR fixes should only pass via isolate=True"

    iso = bound.serve_batch(xs, isolate=True)
    # solo equality under the isolation contract (explicit segment ids)
    contract = _solo(bound, xs)
    assert np.array_equal(np.asarray(iso[0]), np.asarray(contract[0]))
    assert np.array_equal(np.asarray(iso[1]), np.asarray(contract[1]))
    # ...and the small request's rows equal the plain solo serve too:
    # segment grouping, not id values, is what matters
    assert np.array_equal(np.asarray(iso[0]), np.asarray(solo_small))


def test_legacy_default_preserved():
    """isolate defaults to False and stays bit-exact with serving the
    concatenated batch (the PR 5 fusion contract)."""
    bound = _bound(4, 2)
    xs = _requests([2, 3], swing=[1.0, 7.0])
    fused = bound.serve_batch(xs)
    whole = bound.serve(jnp.concatenate(xs, axis=0))
    assert np.array_equal(np.concatenate([np.asarray(f) for f in fused]),
                          np.asarray(whole))


# ---- layer-level isolate_rows ----------------------------------------------

def test_cim_layers_isolate_rows_linear_and_conv():
    """CIMConfig(isolate_rows=True) makes each leading batch row of the
    engine-mode layer entry points bit-identical to serving it alone —
    including a 100x-swing batchmate — for dense (B, S, K) and conv
    (B, H, W, C) inputs alike."""
    from repro.core import cim_layers as cl
    cfg = cl.CIMConfig(mode="engine", r_in=4, r_w=2, isolate_rows=True)
    p = cl.init_cim_linear(jax.random.PRNGKey(0), 24, 8, cfg=cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (3, 5, 24))
    x = x.at[1].multiply(100.0)
    y = cl.cim_linear_apply(p, x, cfg)
    for i in range(3):
        solo = cl.cim_linear_apply(p, x[i:i + 1], cfg)
        assert np.array_equal(np.asarray(y[i]), np.asarray(solo[0])), i
    legacy = cl.cim_linear_apply(p, x, cfg.replace(isolate_rows=False))
    assert not np.array_equal(np.asarray(y), np.asarray(legacy))

    pc = cl.init_cim_linear(jax.random.PRNGKey(2), 3 * 3 * 4, 8, cfg=cfg)
    xc = jax.random.uniform(jax.random.PRNGKey(3), (3, 8, 8, 4))
    xc = xc.at[2].multiply(50.0)
    yc = cl.cim_conv2d_apply(pc, xc, cfg)
    for i in range(3):
        solo = cl.cim_conv2d_apply(pc, xc[i:i + 1], cfg)
        assert np.array_equal(np.asarray(yc[i]), np.asarray(solo[0])), i
