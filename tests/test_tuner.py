"""Schedule autotuner acceptance suite (ISSUE 9).

Four contracts, in order of importance:

1. **Tuning never changes numerics.**  Any legal (bm, bn, bk) block
   triple and any legal shard kind produce outputs bit-identical to the
   heuristic schedule — fuzzed over shapes and the full r_in x r_w
   precision grid, clean AND under a fixed noise key, on 1 device and
   (when the mesh allows — the autotune-smoke CI job runs with 4 fake
   CPU devices) on 4.
2. **The cost model is sane.**  Monotone in M/N/K, macro-eval counts
   agree EXACTLY with perfmodel.macro_perf's layer_report, and its
   ranking of pinned shapes matches measured kernel wall-clock with
   Spearman >= 0.7.
3. **The cache degrades, never crashes.**  Corrupt / stale-schema /
   invalid-entry cache files fall back to the heuristic schedule with a
   TuneCacheWarning; a valid hit skips the search entirely
   (SEARCH_COUNT observable).
4. **One hardware table.**  EFFECTIVE_LINKS and the TPU-v5e peaks live
   in core/hw.py and are the very objects benchmarks/roofline.py and
   repro.tuner consume (values pinned by regression).

Multi-device cases skip under the plain tier-1 run (1 device):
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m pytest tests/test_tuner.py
"""
import json
import os
import sys
import time

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                  # pragma: no cover
    from hypofallback import given, settings, st

from repro.core import mapping
from repro.core.hw import DEFAULT_MACRO, EFFECTIVE_LINKS, TPU_V5E
from repro.core.mapping import LayerSpec
from repro.core.noise_model import NoiseConfig
from repro.kernels.cim_mbiw import ops
from repro.perfmodel.macro_perf import AcceleratorPerfModel, schedule_report
from repro.runtime import engine as rt
from repro.runtime.engine import EngineConfig, ShardingConfig
from repro.runtime.program import (clear_program_cache, compile_program,
                                   program_for_plan)
from repro.tuner import (SCHEMA_VERSION, ScheduleChoice, TuneCache,
                         TuneCacheWarning, cache_key, heuristic_choice,
                         layer_candidates, layer_cost, tune_layer,
                         tune_network)
from repro.tuner import search as tsearch

N_DEV = len(jax.devices())
R_INS = (1, 2, 4, 8)
R_WS = (1, 2, 4)
NOISE = NoiseConfig(enabled=True)


@pytest.fixture(scope="module", autouse=True)
def _fresh_caches():
    """Start (and leave) this module with empty program/jit caches.

    The suite compiles many one-off kernel variants (fuzzed block sizes x
    the precision grid).  Stacked on top of the executables the ~400
    earlier tier-1 tests leave in the process-wide caches, that pushes
    XLA's CPU JIT past its limits (observed SIGSEGV in backend_compile
    when this file runs last in the full suite, while the same tests pass
    standalone).  Dropping the caches at both boundaries keeps the
    process's compiled-code footprint bounded without changing any test's
    semantics — everything here re-plans/re-compiles what it needs.
    """
    clear_program_cache()
    jax.clear_caches()
    yield
    clear_program_cache()
    jax.clear_caches()


def _need(devices):
    if N_DEV < devices:
        pytest.skip(f"needs {devices} devices, jax reports {N_DEV} (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _run_pair(spec, cfg, schedule, *, noisy=False, seed=0):
    """(heuristic output, overridden-schedule output) of one layer."""
    p0 = rt.plan_network((spec,), cfg)
    pt = rt.plan_network((spec,), cfg, schedule=(schedule,))
    params = rt.init_network_params(p0, jax.random.PRNGKey(seed))
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                      (spec.m, spec.k)))
    key = jax.random.PRNGKey(7) if noisy else None
    y0 = program_for_plan(p0).run(params, x, key=key)
    yt = program_for_plan(pt).run(params, x, key=key)
    return np.asarray(y0), np.asarray(yt)


# ---------------------------------------------------------------------------
# 1. bit-exactness: tuned schedules never move a bit
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 20), st.integers(8, 320), st.integers(4, 64),
       st.sampled_from([(r_in, r_w) for r_in in R_INS for r_w in R_WS]),
       st.sampled_from(ops.BM_PALETTE), st.sampled_from(ops.BN_PALETTE),
       st.sampled_from(ops.BK_PALETTE))
def test_fuzz_blocks_bitexact(m, k, n, prec, bm, bn, bk):
    """Any palette block triple is bit-exact with the heuristic blocks,
    fuzzed over shapes and precision (clean run)."""
    r_in, r_w = prec
    spec = LayerSpec(m=m, k=k, n=n, r_in=r_in, r_w=r_w)
    y0, yt = _run_pair(spec, EngineConfig(), ((bm, bn, bk), None))
    assert (y0 == yt).all()


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 16), st.integers(8, 256), st.integers(4, 48),
       st.sampled_from([(1, 1), (4, 2), (8, 4)]),
       st.sampled_from(ops.BM_PALETTE), st.sampled_from(ops.BK_PALETTE))
def test_fuzz_blocks_bitexact_noise(m, k, n, prec, bm, bk):
    """Block overrides stay bit-exact under a fixed noise key: the
    thermal draws are keyed per global row block, not per kernel block."""
    r_in, r_w = prec
    spec = LayerSpec(m=m, k=k, n=n, r_in=r_in, r_w=r_w)
    cfg = EngineConfig(noise=NOISE)
    y0, yt = _run_pair(spec, cfg, ((bm, 64, bk), None), noisy=True)
    assert (y0 == yt).all()


@pytest.mark.parametrize("r_in", R_INS)
@pytest.mark.parametrize("r_w", R_WS)
def test_grid_bitexact(r_in, r_w):
    """The full precision grid at a deliberately off-heuristic block
    choice (small bm/bn, padded bk) — bit-exact everywhere."""
    spec = LayerSpec(m=12, k=200, n=40, r_in=r_in, r_w=r_w)
    y0, yt = _run_pair(spec, EngineConfig(), ((32, 32, 1024), None))
    assert (y0 == yt).all()


@pytest.mark.parametrize("kind", ["col", "rows"])
@pytest.mark.parametrize("noisy", [False, True])
def test_sharded_kind_override_bitexact(kind, noisy):
    """Forcing either shard kind (plus a block override) on a 4-device
    mesh is bit-exact with the auto-kind heuristic plan, clean and under
    a fixed noise key."""
    _need(4)
    spec = LayerSpec(m=16, k=300, n=320, r_in=4, r_w=2)   # 5 col tiles
    cfg = EngineConfig(sharding=ShardingConfig(devices=4),
                       noise=NOISE if noisy else rt.NO_NOISE)
    y0, yt = _run_pair(spec, cfg, ((64, 64, 128), kind), noisy=noisy)
    assert (y0 == yt).all()


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 16), st.integers(16, 256), st.integers(8, 300),
       st.sampled_from([(2, 1), (4, 2), (8, 4)]),
       st.sampled_from(["col", "rows"]))
def test_fuzz_sharded_bitexact(m, k, n, prec, kind):
    """Fuzzed shapes x precision x forced shard kind on 4 devices: every
    legal partition is bit-exact with the heuristic plan."""
    _need(4)
    r_in, r_w = prec
    spec = LayerSpec(m=m, k=k, n=n, r_in=r_in, r_w=r_w)
    cfg = EngineConfig(sharding=ShardingConfig(devices=4))
    y0, yt = _run_pair(spec, cfg, (None, kind))
    assert (y0 == yt).all()


def test_compile_program_tune_bitexact():
    """compile_program(tune=...) end to end: analytic and measure tuned
    programs serve bit-identically to tune="off", and the tuned plan's
    schedule_report echoes the chosen blocks and predicted cost."""
    clear_program_cache()
    specs = (LayerSpec(m=16, k=300, n=40, r_in=4, r_w=2),)
    p0 = compile_program(specs, EngineConfig())
    pa = compile_program(specs, EngineConfig(), tune="analytic",
                         tune_cache="")
    pm = compile_program(specs, EngineConfig(), tune="measure",
                         tune_cache="")
    params = p0.init_params(jax.random.PRNGKey(0))
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (5, 300)))
    y0 = np.asarray(p0.bind(params).serve(x))
    assert (y0 == np.asarray(pa.bind(params).serve(x))).all()
    assert (y0 == np.asarray(pm.bind(params).serve(x))).all()
    # k=300 at bk=256 pads K to 512; the palette's clamped bk=304 pads to
    # 304 — a strictly-lower-DMA win the tuner must find and the report
    # must echo
    assert pa.plan.layers[0].blocks is not None
    rep = schedule_report(pa.plan)["layers"][0]["tune"]
    assert rep["blocks"] == pa.plan.layers[0].blocks
    assert rep["predicted_s"] <= rep["heuristic_s"]
    with pytest.raises(ValueError, match="tune"):
        compile_program(specs, EngineConfig(), tune="nope")


def test_tuned_no_win_folds_to_heuristic_plan():
    """A layer whose search keeps the heuristic produces the *same* plan
    (hash-equal), so the tuned program shares the untuned executables."""
    spec = LayerSpec(m=8, k=128, n=32, r_in=4, r_w=2)
    cfg = EngineConfig()
    heur = heuristic_choice(spec, cfg)
    best, rep = tune_layer(spec, cfg, 1, cache=None)
    if best != heur:
        pytest.skip("tuner found a genuine win on this shape")
    plan_t, _ = tune_network([spec], cfg, cache_path="")
    assert plan_t == rt.plan_network((spec,), cfg)
    assert hash(plan_t) == hash(rt.plan_network((spec,), cfg))


def test_schedule_override_validation():
    """Bad overrides fail loudly at plan time, not at dispatch."""
    spec = LayerSpec(m=8, k=64, n=16, r_in=4, r_w=2)
    with pytest.raises(ValueError, match="blocks"):
        rt.plan_layer(spec, blocks=(0, 64, 64))
    with pytest.raises(ValueError, match="sharding"):
        rt.plan_layer(spec, shard_kind="col")
    with pytest.raises(ValueError, match="kind"):
        mapping.shard_layer(spec, mapping.map_layer(spec, DEFAULT_MACRO),
                            2, kind="diagonal")
    with pytest.raises(ValueError, match="schedule"):
        rt.plan_network((spec,), EngineConfig(),
                        schedule=(None, ((1, 1, 1), None)))
    with pytest.raises(ValueError, match="mode"):
        tune_network([spec], EngineConfig(), mode="psychic")


# ---------------------------------------------------------------------------
# 2. cost-model sanity
# ---------------------------------------------------------------------------

def test_cost_macro_evals_agree_with_macro_perf():
    """The cost model's eval counts equal macro_perf's layer_report
    EXACTLY across the precision grid and assorted geometries."""
    ap = AcceleratorPerfModel()
    shapes = [(8, 64, 16), (16, 300, 40), (4, 1300, 256), (32, 2048, 512)]
    for r_in in R_INS:
        for r_w in R_WS:
            for m, k, n in shapes:
                spec = LayerSpec(m=m, k=k, n=n, r_in=r_in, r_w=r_w)
                lc = layer_cost(spec, heuristic_choice(spec, EngineConfig()))
                assert lc.macro_evals == \
                    ap.layer_report(spec)["macro_evals"]
                assert lc.macro_evals_per_device == lc.macro_evals


def test_cost_sharded_evals_match_schedule_report():
    """Per-device eval counts of both shard kinds equal the counts
    schedule_report derives from the planned LayerShard."""
    spec = LayerSpec(m=16, k=300, n=320, r_in=4, r_w=2)   # 5 col tiles
    cfg = EngineConfig(sharding=ShardingConfig(devices=4))
    for kind in ("col", "rows"):
        plan = rt.plan_network((spec,), cfg, schedule=((None, kind),))
        rep = schedule_report(plan)["layers"][0]["shard"]
        lc = layer_cost(spec, ScheduleChoice(64, 64, 256, kind), devices=4)
        assert lc.macro_evals_per_device == rep["macro_evals_per_device"]


def test_cost_monotone_in_mnk():
    """Doubling any one GEMM dimension never lowers the modeled cost or
    the DMA traffic (the roofline terms are all non-decreasing)."""
    choice = ScheduleChoice(64, 64, 256)
    base = dict(m=8, k=128, n=32)
    for dim in ("m", "k", "n"):
        prev = None
        for mult in (1, 2, 4, 8):
            kw = dict(base)
            kw[dim] = base[dim] * mult
            lc = layer_cost(LayerSpec(r_in=4, r_w=2, **kw), choice)
            if prev is not None:
                assert lc.total_s >= prev.total_s, dim
                assert lc.dma_bytes >= prev.dma_bytes, dim
                assert lc.macro_evals >= prev.macro_evals, dim
            prev = lc


def _spearman(a, b):
    """Rank correlation, hand-rolled (scipy is not a dependency)."""
    def rank(v):
        order = sorted(range(len(v)), key=lambda i: v[i])
        r = [0] * len(v)
        for pos, i in enumerate(order):
            r[i] = pos
        return r
    ra, rb = rank(a), rank(b)
    n = len(a)
    d2 = sum((x - y) ** 2 for x, y in zip(ra, rb))
    return 1.0 - 6.0 * d2 / (n * (n * n - 1))


def test_cost_spearman_vs_measured():
    """The analytic ranking of pinned shapes agrees with measured kernel
    wall-clock at Spearman >= 0.7.  Interpret mode on CPU has a ~20ms
    per-dispatch floor, so the pinned shapes all sit well above it
    (>= ~9M MACs) with >= ~2x work ratios between neighbors; every shape
    is compiled before any is timed (min of 3)."""
    shapes = [(64, 1152, 128), (96, 1152, 256), (128, 1152, 512),
              (256, 1152, 512), (512, 1152, 1024)]
    predicted, cases = [], []
    for m, k, n in shapes:
        spec = LayerSpec(m=m, k=k, n=n, r_in=4, r_w=2)
        predicted.append(
            layer_cost(spec, heuristic_choice(spec, EngineConfig())).total_s)
        rng = np.random.default_rng(m + k)
        x = jax.numpy.asarray(rng.integers(0, 16, (m, k), dtype=np.int32))
        w = jax.numpy.asarray(
            2 * rng.integers(0, 2, (k, n), dtype=np.int32) + 1)
        gamma = jax.numpy.full((n,), 16.0)
        beta = jax.numpy.zeros((n,))

        def run(x=x, w=w, gamma=gamma, beta=beta):
            ops.cim_matmul(x, w, gamma, beta, r_in=4, r_out=8,
                           g0=1.0).block_until_ready()
        run()                                   # compile before timing
        cases.append(run)
    measured = []
    for run in cases:
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            run()
            best = min(best, time.perf_counter() - t0)
        measured.append(best)
    rho = _spearman(predicted, measured)
    assert rho >= 0.7, (rho, predicted, measured)


def test_candidates_heuristic_first_and_legal():
    """layer_candidates puts the heuristic first, deduplicates, and every
    candidate's blocks are positive and tile-clamped."""
    spec = LayerSpec(m=16, k=1300, n=320, r_in=4, r_w=2)
    cfg = EngineConfig()
    cands = layer_candidates(spec, cfg, 1)
    assert cands[0] == heuristic_choice(spec, cfg)
    assert len(set(cands)) == len(cands)
    mp = mapping.map_layer(spec, DEFAULT_MACRO)
    tile_n = -(-spec.n // mp.col_tiles)
    for c in cands:
        assert c.bm >= 1 and c.bn >= 1 and c.bk >= 1
        assert c.bm <= -(-spec.m // 8) * 8
        assert c.bn <= -(-tile_n // 8) * 8
        assert c.bk <= -(-mp.rows_per_tile // 8) * 8
        assert c.shard_kind is None
    # multi-device candidates carry both kinds
    kinds = {c.shard_kind for c in layer_candidates(spec, cfg, 4)}
    assert kinds == {None, "col", "rows"}


def test_search_never_worse_than_heuristic():
    """tune_layer's winner scores <= the heuristic on every zoo-ish
    geometry x precision x device point (the BENCH gate in miniature)."""
    shapes = [(8, 64, 16), (16, 300, 40), (8, 1300, 256), (32, 576, 320)]
    for r_in, r_w in ((1, 1), (4, 2), (8, 4)):
        for m, k, n in shapes:
            spec = LayerSpec(m=m, k=k, n=n, r_in=r_in, r_w=r_w)
            for d in (1, 4):
                _, rep = tune_layer(spec, EngineConfig(), d, cache=None)
                assert rep["predicted_s"] <= rep["heuristic_s"] * (1 + 1e-12)


# ---------------------------------------------------------------------------
# 3. cache round-trip and degradation
# ---------------------------------------------------------------------------

def _count():
    return tsearch.SEARCH_COUNT["n"]


def test_cache_roundtrip_hit_skips_search(tmp_path):
    """Miss -> search + write-back; second compile with the same cache is
    all hits and runs zero searches; the winner is identical."""
    path = str(tmp_path / "tune.json")
    specs = [LayerSpec(m=16, k=300, n=40, r_in=4, r_w=2),
             LayerSpec(m=16, k=40, n=24, r_in=4, r_w=2)]
    cfg = EngineConfig()
    n0 = _count()
    plan1, reps1 = tune_network(specs, cfg, cache_path=path)
    assert _count() - n0 == len(specs)
    assert os.path.exists(path)
    assert all(r["cache"] == "miss" for r in reps1)
    n1 = _count()
    plan2, reps2 = tune_network(specs, cfg, cache_path=path)
    assert _count() == n1                      # hits skip the search
    assert all(r["cache"] == "hit" for r in reps2)
    assert [r["choice"] for r in reps2] == [r["choice"] for r in reps1]
    assert plan1 == plan2
    with open(path) as fh:
        raw = json.load(fh)
    assert raw["schema"] == SCHEMA_VERSION
    assert cache_key(specs[0], 1) in raw["entries"]


def test_cache_corrupt_falls_back_heuristic(tmp_path):
    """A corrupt cache file warns and yields the heuristic plan — no
    search, no crash, no write-back growing the bad file."""
    path = str(tmp_path / "tune.json")
    with open(path, "w") as fh:
        fh.write("{ this is not json")
    spec = LayerSpec(m=16, k=300, n=40, r_in=4, r_w=2)
    n0 = _count()
    with pytest.warns(TuneCacheWarning, match="unreadable"):
        plan, reps = tune_network([spec], EngineConfig(), cache_path=path)
    assert _count() == n0
    assert reps[0]["cache"] == "invalid"
    assert plan == rt.plan_network((spec,), EngineConfig())
    with open(path) as fh:
        assert fh.read() == "{ this is not json"     # untouched


def test_cache_stale_schema_falls_back_heuristic(tmp_path):
    """A schema-version mismatch degrades exactly like corruption."""
    path = str(tmp_path / "tune.json")
    with open(path, "w") as fh:
        json.dump({"schema": SCHEMA_VERSION + 1, "entries": {}}, fh)
    spec = LayerSpec(m=16, k=300, n=40, r_in=4, r_w=2)
    with pytest.warns(TuneCacheWarning, match="schema"):
        plan, reps = tune_network([spec], EngineConfig(), cache_path=path)
    assert reps[0]["cache"] == "invalid"
    assert plan == rt.plan_network((spec,), EngineConfig())


def test_cache_invalid_entry_falls_back_heuristic(tmp_path):
    """One malformed entry degrades only its own layer (warn +
    heuristic); a valid entry in the same file still hits."""
    path = str(tmp_path / "tune.json")
    s_bad = LayerSpec(m=16, k=300, n=40, r_in=4, r_w=2)
    s_good = LayerSpec(m=16, k=40, n=24, r_in=4, r_w=2)
    entries = {
        cache_key(s_bad, 1): {"bm": -5, "bn": "x", "bk": 128,
                              "shard_kind": None},
        cache_key(s_good, 1): {"bm": 8, "bn": 24, "bk": 40,
                               "shard_kind": None},
    }
    with open(path, "w") as fh:
        json.dump({"schema": SCHEMA_VERSION, "entries": entries}, fh)
    n0 = _count()
    with pytest.warns(TuneCacheWarning, match="invalid"):
        plan, reps = tune_network([s_bad, s_good], EngineConfig(),
                                  cache_path=path)
    assert reps[0]["cache"] == "invalid"
    assert reps[1]["cache"] == "hit"
    assert reps[1]["choice"] == ScheduleChoice(8, 24, 40, None)
    assert plan.layers[1].blocks == (8, 24, 40)
    assert _count() == n0                      # neither layer searched


def test_cache_key_discriminates():
    """The key separates geometry, precision, device count and macro
    config — anything a winner depends on."""
    s = LayerSpec(m=8, k=64, n=16, r_in=4, r_w=2)
    base = cache_key(s, 1)
    assert base != cache_key(LayerSpec(m=8, k=64, n=32, r_in=4, r_w=2), 1)
    assert base != cache_key(LayerSpec(m=8, k=64, n=16, r_in=8, r_w=2), 1)
    assert base != cache_key(s, 4)
    import dataclasses as dc
    small = dc.replace(DEFAULT_MACRO, n_rows=576)
    assert base != cache_key(s, 1, small)


def test_cache_bitexact_through_compile_program(tmp_path):
    """The integrated path with a real cache file: first compile misses
    and tunes, a second process-equivalent compile hits — both serve
    bit-identically to the untuned program."""
    clear_program_cache()
    path = str(tmp_path / "tune.json")
    specs = (LayerSpec(m=16, k=300, n=40, r_in=4, r_w=2),)
    p0 = compile_program(specs, EngineConfig())
    p1 = compile_program(specs, EngineConfig(), tune="analytic",
                         tune_cache=path)
    clear_program_cache()                      # force a re-tune from disk
    p2 = compile_program(specs, EngineConfig(), tune="analytic",
                         tune_cache=path)
    assert p1.plan == p2.plan
    params = p0.init_params(jax.random.PRNGKey(0))
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (4, 300)))
    y0 = np.asarray(p0.bind(params).serve(x))
    assert (y0 == np.asarray(p2.bind(params).serve(x))).all()


# ---------------------------------------------------------------------------
# 4. one hardware table
# ---------------------------------------------------------------------------

def test_hw_constants_pinned():
    """The shared hardware table's values (regression pin after the move
    of EFFECTIVE_LINKS out of benchmarks/roofline.py)."""
    assert EFFECTIVE_LINKS == 3.0
    assert TPU_V5E.peak_bf16_flops == 197e12
    assert TPU_V5E.hbm_bw == 819e9
    assert TPU_V5E.ici_bw_per_link == 50e9


def test_roofline_and_tuner_share_hw_table():
    """benchmarks/roofline.py and repro.tuner.cost import the same
    objects from core/hw — one source of truth, not copied constants."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    import benchmarks.roofline as rl
    from repro.core import hw
    from repro.tuner import cost as tc
    assert rl.EFFECTIVE_LINKS is hw.EFFECTIVE_LINKS
    assert rl.TPU_V5E is hw.TPU_V5E
    assert tc.EFFECTIVE_LINKS is hw.EFFECTIVE_LINKS
    assert tc.TPU_V5E is hw.TPU_V5E
