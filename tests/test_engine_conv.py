"""Conv front-end of the precision-scalable inference runtime.

The acceptance bar: engine conv (im2col streaming -> Pallas kernel
variants) must agree *bit-exactly* under NO_NOISE with a digital conv
reference built on `jax.lax.conv_general_dilated` — NOT on im2col — for
every supported (r_in, r_w) x stride x padding operating point, including
the K > 1152 multi-row-tile conv requantization path.  Per row tile the
reference zero-masks the weights outside the tile's K slice, so the direct
convolution computes exactly that tile's partial dot product; codes then go
through the shared ADC floor epilogue.

Property-based tests run under `hypothesis` when installed and under the
deterministic `tests/hypofallback.py` stub otherwise; one test pins the
stub path explicitly so it stays exercised either way.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypofallback import given, settings, st

import hypofallback

from repro.core import abn as abn_lib
from repro.core import cim_layers as cl
from repro.core.mapping import LayerSpec, conv_layer_spec, resolve_padding
from repro.core.quantization import quantize_act, quantize_weight
from repro.kernels.cim_mbiw.ref import _adc_epilogue, cim_matmul_ref
from repro.models import cnn
from repro.runtime import CIMInferenceEngine, EngineConfig, im2col_patches

R_INS = (1, 2, 4, 8)
R_WS = (1, 2, 4)
STRIDES = (1, 2)
PADDINGS = ("SAME", "VALID")


# ---------------------------------------------------------------------------
# digital conv reference (lax.conv_general_dilated, masked-weight row tiles)
# ---------------------------------------------------------------------------

def _gamma(params, cfg: EngineConfig):
    return abn_lib.abn_gamma(
        abn_lib.ABNParams(params["abn_log_gamma"], params["abn_beta"]),
        gamma_bits=cfg.gamma_bits, max_gamma=cfg.max_gamma)


def conv_layer_oracle(lp, params, x, cfg: EngineConfig):
    """One conv layer through lax.conv_general_dilated + the ADC epilogue.

    Activation quantization matches the engine (scale/zero from the patch
    matrix); the padded image is quantized with that same scale so padding
    pixels carry the padding-zero code, then each row tile's partial dp is
    a direct convolution with the weights outside the tile zero-masked."""
    g, spec = lp.spec.conv, lp.spec
    patches = im2col_patches(x.astype(jnp.float32), g)
    aq = quantize_act(patches.reshape(-1, spec.k), spec.r_in)
    wq = quantize_weight(params["w"], spec.r_w, axis=0)
    gamma = _gamma(params, cfg)
    beta = params["abn_beta"]
    xp = jnp.pad(x.astype(jnp.float32),
                 ((0, 0), g.padding[0], g.padding[1], (0, 0)))
    q_img = quantize_act(xp, spec.r_in, scale=aq.scale, zero=aq.zero).q
    mid = 2.0 ** (spec.r_out - 1)
    cols = []
    for (ns, nsz) in lp.n_slices:
        ne = ns + nsz
        acc = jnp.zeros((x.shape[0] * g.out_h * g.out_w, nsz), jnp.float32)
        for (ks, ksz) in lp.k_slices:
            ke = ks + ksz
            w_mask = jnp.zeros_like(wq.q).at[ks:ke].set(wq.q[ks:ke])
            w_hwio = w_mask[:, ns:ne].reshape(g.kh, g.kw, g.c_in, nsz)
            dp = jax.lax.conv_general_dilated(
                q_img, w_hwio, (g.stride, g.stride), [(0, 0), (0, 0)],
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            dp = dp.reshape(-1, nsz)
            zp_dp = (aq.zero / aq.scale) * jnp.sum(wq.q[ks:ke, ns:ne], axis=0)
            beta_eff = beta[ns:ne] + gamma[ns:ne] * lp.g0 * zp_dp
            codes = _adc_epilogue(dp, gamma[ns:ne], beta_eff, lp.g0,
                                  spec.r_out)
            acc = acc + (codes.astype(jnp.float32) + 0.5 - mid
                         - beta[None, ns:ne]) / (gamma[None, ns:ne] * lp.g0)
        cols.append(acc)
    y = jnp.concatenate(cols, -1) * aq.scale * wq.scale.reshape(-1)
    if lp.activation == "relu":
        y = jax.nn.relu(y)
    y = y.reshape(x.shape[0], g.out_h, g.out_w, g.c_out)
    if lp.pool > 1:
        y = jax.lax.reduce_window(
            y, -jnp.inf, jax.lax.max, (1, lp.pool, lp.pool, 1),
            (1, lp.pool, lp.pool, 1), "VALID")
    return y


def dense_layer_oracle(lp, params, x, cfg: EngineConfig):
    """Dense layer through the pure-jnp matmul oracle (mirrors the engine's
    tile schedule; flattens NHWC input like the engine's conv -> dense)."""
    spec = lp.spec
    x2 = x.reshape(x.shape[0], -1)
    aq = quantize_act(x2, spec.r_in)
    wq = quantize_weight(params["w"], spec.r_w, axis=0)
    gamma = _gamma(params, cfg)
    beta = params["abn_beta"]
    mid = 2.0 ** (spec.r_out - 1)
    cols = []
    for (ns, nsz) in lp.n_slices:
        ne = ns + nsz
        acc = jnp.zeros((x2.shape[0], nsz), jnp.float32)
        for (ks, ksz) in lp.k_slices:
            ke = ks + ksz
            zp_dp = (aq.zero / aq.scale) * jnp.sum(wq.q[ks:ke, ns:ne], axis=0)
            beta_eff = beta[ns:ne] + gamma[ns:ne] * lp.g0 * zp_dp
            codes = cim_matmul_ref(aq.q[:, ks:ke], wq.q[ks:ke, ns:ne],
                                   gamma[ns:ne], beta_eff, g0=lp.g0,
                                   r_out=spec.r_out)
            acc = acc + (codes.astype(jnp.float32) + 0.5 - mid
                         - beta[None, ns:ne]) / (gamma[None, ns:ne] * lp.g0)
        cols.append(acc)
    y = jnp.concatenate(cols, -1) * aq.scale * wq.scale.reshape(-1)
    if lp.activation == "relu":
        y = jax.nn.relu(y)
    return y


def _network_oracle(plan, params, x):
    xc = x.astype(jnp.float32)
    for lp, p in zip(plan.layers, params):
        fn = conv_layer_oracle if lp.spec.conv is not None \
            else dense_layer_oracle
        xc = fn(lp, p, xc, plan.cfg)
    return xc


# jit like run_network: the bit-exactness contract holds between compiled
# programs (XLA fuses the float epilogue chain identically); an eager oracle
# drifts by 1 ulp on the dequant multiplies.
network_oracle = jax.jit(_network_oracle, static_argnames=("plan",))


def _conv_case(r_in, r_w, stride, padding, *, h=8, w=7, c_in=3, c_out=8,
               kh=3, kw=3, batch=2, seed=0, cfg=None, activation="none"):
    spec = conv_layer_spec(batch, h, w, c_in, c_out, kh=kh, kw=kw,
                           stride=stride, padding=padding,
                           r_in=r_in, r_w=r_w, r_out=8)
    cfg = cfg if cfg is not None else EngineConfig()
    eng = CIMInferenceEngine([spec], cfg, activations=[activation])
    params = eng.init_params(jax.random.PRNGKey(seed))
    x = jax.nn.relu(jax.random.normal(
        jax.random.PRNGKey(seed + 1), (batch, h, w, c_in)))
    return eng, params, x


def _assert_conv_bitexact(r_in, r_w, stride, padding, **kw):
    eng, params, x = _conv_case(r_in, r_w, stride, padding, **kw)
    y = eng(params, x)
    y_oracle = network_oracle(eng.plan, params, x)
    assert y.shape == y_oracle.shape
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_oracle))
    return eng


# ---------------------------------------------------------------------------
# property-based precision grid (hypothesis or the deterministic fallback)
# ---------------------------------------------------------------------------

@given(st.sampled_from(R_INS), st.sampled_from(R_WS),
       st.sampled_from(STRIDES), st.sampled_from(PADDINGS))
@settings(max_examples=8, deadline=None)
def test_property_conv_precision_grid(r_in, r_w, stride, padding):
    """Engine conv == lax.conv_general_dilated digital reference, bit-exact
    under NO_NOISE, across r_in x r_w x stride x padding."""
    _assert_conv_bitexact(r_in, r_w, stride, padding,
                          seed=r_in * 100 + r_w * 10 + stride)


@given(st.integers(4, 9), st.integers(4, 9), st.sampled_from((1, 2, 3, 5)))
@settings(max_examples=6, deadline=None)
def test_property_conv_geometry(h, w, c_in):
    """Random (possibly non-square) geometry at a fixed operating point."""
    _assert_conv_bitexact(4, 2, 1, "SAME", h=h, w=w, c_in=c_in,
                          seed=h * 10 + w + c_in)


@hypofallback.given(hypofallback.st.sampled_from(R_INS),
                    hypofallback.st.sampled_from(STRIDES))
@hypofallback.settings(max_examples=4)
def test_property_conv_grid_stub_path(r_in, stride):
    """Pins the tests/hypofallback.py stub: its deterministic draws must
    drive the same property even when real hypothesis is installed."""
    _assert_conv_bitexact(r_in, min(r_in, 4), stride, "VALID",
                          seed=r_in + stride)


# ---------------------------------------------------------------------------
# im2col edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", STRIDES)
def test_conv_1x1_kernel(stride):
    eng = _assert_conv_bitexact(4, 2, stride, "VALID", kh=1, kw=1,
                                c_in=5, seed=stride)
    assert eng.plan.layers[0].spec.k == 5


@pytest.mark.parametrize("c_in", (1, 2, 3))
def test_conv_cin_below_macro_granule(c_in):
    """C_in below the macro's 4-channel minimum unit still maps (the unit
    is padded with inactive rows: utilization < 1)."""
    eng = _assert_conv_bitexact(4, 2, 1, "SAME", c_in=c_in, seed=c_in)
    lp = eng.plan.layers[0]
    assert lp.mp.units_per_tile == 1
    assert lp.mp.utilization < 1.0


def test_conv_multi_row_tile_requantization():
    """K = 3*3*152 = 1368 > 1152: the conv splits into row tiles whose
    partial ADC codes recombine digitally — the K slice boundary falls
    inside a patch position, which the masked-weight conv reference must
    reproduce exactly."""
    eng = _assert_conv_bitexact(8, 4, 1, "SAME", h=4, w=4, c_in=152,
                                c_out=8, seed=5)
    lp = eng.plan.layers[0]
    assert len(lp.k_slices) == 2
    assert lp.mp.needs_digital_accum


def test_conv_non_square_input_and_kernel():
    _assert_conv_bitexact(4, 2, 1, "SAME", h=9, w=5, kh=3, kw=2, seed=9)


def test_conv_stream_rows_bit_invariant():
    """im2col streaming: chunking the patch rows through the kernel must
    not change a single bit (quantization stays global)."""
    eng, params, x = _conv_case(4, 2, 1, "SAME", seed=11)
    eng_s, _, _ = _conv_case(4, 2, 1, "SAME", seed=11,
                             cfg=EngineConfig(stream_rows=16))
    assert eng_s.cfg.stream_rows == 16
    y = eng(params, x)
    y_s = eng_s(params, x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_s))
    np.testing.assert_array_equal(
        np.asarray(y_s), np.asarray(network_oracle(eng.plan, params, x)))


def test_conv_relu_and_pool_epilogues():
    spec = conv_layer_spec(2, 8, 8, 3, 8, kh=3, kw=3, padding=1,
                           r_in=4, r_w=2)
    eng = CIMInferenceEngine([spec], activations=["relu"], pools=[2])
    params = eng.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    y = eng(params, x)
    assert y.shape == (2, 4, 4, 8)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(network_oracle(eng.plan, params, x)))


# ---------------------------------------------------------------------------
# conv_layer_spec geometry validation
# ---------------------------------------------------------------------------

def test_conv_layer_spec_propagates_stride_padding():
    s = conv_layer_spec(4, 28, 28, 16, 32, stride=2, padding="SAME")
    assert (s.conv.out_h, s.conv.out_w) == (14, 14)
    assert s.m == 4 * 14 * 14
    v = conv_layer_spec(4, 28, 28, 16, 32, stride=2, padding="VALID")
    assert (v.conv.out_h, v.conv.out_w) == (13, 13)
    i = conv_layer_spec(4, 28, 28, 16, 32, stride=1, padding=1)
    assert (i.conv.out_h, i.conv.out_w) == (28, 28)
    assert i.op == "conv" and i.conv.padding == ((1, 1), (1, 1))
    assert LayerSpec(m=1, k=8, n=8).op == "dense"


def test_conv_layer_spec_validates():
    with pytest.raises(ValueError, match="stride"):
        conv_layer_spec(1, 8, 8, 4, 8, stride=0)
    with pytest.raises(ValueError, match="padding"):
        conv_layer_spec(1, 8, 8, 4, 8, padding=-1)
    with pytest.raises(ValueError, match="padding"):
        conv_layer_spec(1, 8, 8, 4, 8, padding="HALF")
    with pytest.raises(ValueError, match="does not fit"):
        conv_layer_spec(1, 4, 4, 4, 8, kh=7, kw=7, padding="VALID")
    with pytest.raises(ValueError, match="dims must be >= 1"):
        conv_layer_spec(1, 8, 8, 0, 8)
    assert resolve_padding("SAME", 3, 3, 7, 7, 2) == ((1, 1), (1, 1))


def test_plan_rejects_bad_cnn_chains():
    from repro.runtime import plan_network
    conv = conv_layer_spec(2, 8, 8, 3, 8, padding=1)
    with pytest.raises(ValueError, match="chain mismatch"):
        plan_network([conv, LayerSpec(m=2, k=100, n=4)])       # 512 != 100
    with pytest.raises(ValueError, match="chain mismatch"):
        plan_network([LayerSpec(m=2, k=16, n=192), conv])      # dense -> conv
    with pytest.raises(ValueError, match="pooling epilogue"):
        plan_network([LayerSpec(m=2, k=16, n=8)], pools=[2])


def test_lenet_macro_evals_pinned():
    """Hand-computed schedule for LeNet at batch 2, r_w=4:
    conv1 (K=9, N=16) -> 1x1 tiles; conv2 (K=144, N=32) -> 1x1;
    fc1 (K=1568 -> 2 row tiles, N=128 -> 2 col tiles) -> 4; fc2 -> 1."""
    eng = cnn.lenet_engine(batch=2)
    assert [lp.macro_evals for lp in eng.plan.layers] == [1, 1, 4, 1]
    assert eng.plan.total_macro_evals == 7
    rep = eng.perf_report()
    # per-conv-layer macro_evals scale with the stride/padding-correct
    # output map: M = batch*out_h*out_w
    assert [lay["macro_evals"] for lay in rep["layers"]] == \
        [2 * 28 * 28, 2 * 14 * 14, 2 * 4, 2]
    assert rep["layers"][0]["op"] == "conv"
    assert rep["layers"][0]["conv"]["macro_evals_per_image"] == 28 * 28
    assert rep["layers"][2]["op"] == "dense"
    assert rep["total"]["macro_evals"] == 7


# ---------------------------------------------------------------------------
# end-to-end LeNet
# ---------------------------------------------------------------------------

def _lenet_bitexact(r_in, r_w, batch=2, seed=0):
    cfg = cl.CIMConfig(r_in=r_in, r_w=r_w)
    params = cnn.init_lenet(jax.random.PRNGKey(seed), cim=cfg)
    eng = cnn.lenet_engine(batch, cim=cfg)
    plist = cnn.lenet_params_list(params)
    x = jax.nn.relu(jax.random.normal(
        jax.random.PRNGKey(seed + 1), (batch, 28, 28, 1)))
    y = eng(plist, x)
    assert y.shape == (batch, 10)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(network_oracle(eng.plan, plist, x)))
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(eng.reference(plist, x)))


def test_lenet_engine_bitexact_smoke():
    """PR-level acceptance: the paper's 4b LeNet operating point, end to
    end through one engine plan (conv1 -> pool -> conv2 -> pool -> fc1 ->
    fc2, fc1 exercising K=1568 > 1152 row tiling)."""
    _lenet_bitexact(4, 2)


@pytest.mark.slow
@pytest.mark.parametrize("r_w", R_WS)
@pytest.mark.parametrize("r_in", R_INS)
def test_lenet_engine_bitexact_full_grid(r_in, r_w):
    """Scheduled CI sweep: full LeNet bit-exactness over the whole
    (r_in, r_w) grid.  CONV_GRID_R_IN shards the matrix job."""
    shard = os.environ.get("CONV_GRID_R_IN")
    if shard and int(shard) != r_in:
        pytest.skip(f"sharded out (CONV_GRID_R_IN={shard})")
    _lenet_bitexact(r_in, r_w, seed=r_in * 10 + r_w)


def test_lenet_engine_matches_fakequant_on_pseudo_mnist():
    """Regression: engine-mode LeNet logits track the fakequant training
    path within quantization tolerance on pseudo_mnist (the two paths share
    quantizers and tile schedule; only the zero-point folding differs, so
    codes may move by one ADC LSB at exact floor boundaries)."""
    from repro.data.pseudo_mnist import make_dataset
    _, _, xte, _ = make_dataset(n_train=1, n_test=16)
    x = jnp.asarray(xte)[..., None]

    # 8b: 256 activation levels — no dynamic-scale tie flips, the paths
    # agree at float precision end to end
    cfg8 = cl.CIMConfig(mode="fakequant", r_in=8, r_w=4)
    p8 = cnn.init_lenet(jax.random.PRNGKey(0), cim=cfg8)
    y_fq = cnn.lenet_forward(p8, x, cfg8)
    y_eng = cnn.lenet_forward(p8, x, cfg8.replace(mode="engine"))
    err = float(jnp.max(jnp.abs(y_eng - y_fq)))
    assert err <= 1e-4 * float(jnp.max(jnp.abs(y_fq))), err

    # 4b (the paper's LeNet point): pseudo_mnist's discrete pixels make
    # intermediate activations tie-heavy, so 1-ulp dequant differences can
    # flip clustered codes at exact rounding boundaries — bounded by the
    # quantization step in aggregate, with identical predictions
    cfg4 = cl.CIMConfig(mode="fakequant", r_in=4, r_w=2)
    p4 = cnn.init_lenet(jax.random.PRNGKey(0), cim=cfg4)
    y_fq4 = cnn.lenet_forward(p4, x, cfg4)
    y_eng4 = cnn.lenet_forward(p4, x, cfg4.replace(mode="engine"))
    assert y_eng4.shape == y_fq4.shape == (16, 10)
    mean_rel = float(jnp.mean(jnp.abs(y_eng4 - y_fq4))
                     / (jnp.mean(jnp.abs(y_fq4)) + 1e-9))
    assert mean_rel <= 0.05, mean_rel
    agree = float(jnp.mean(jnp.argmax(y_eng4, -1) == jnp.argmax(y_fq4, -1)))
    assert agree == 1.0


def test_cim_conv2d_apply_engine_mode():
    """cim_conv2d_apply(mode="engine") routes through the native conv plan
    (no im2col detour) and tracks fakequant at float precision."""
    cfg = cl.CIMConfig(mode="fakequant", r_in=4, r_w=2)
    p = cl.init_cim_linear(jax.random.PRNGKey(0), 3 * 3 * 4, 8, cfg=cfg)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (2, 9, 6, 4)))
    for stride, padding in ((1, 1), (2, "SAME"), (1, "VALID")):
        y_fq = cl.cim_conv2d_apply(p, x, cfg, stride=stride, padding=padding)
        y_eng = cl.cim_conv2d_apply(p, x, cfg.replace(mode="engine"),
                                    stride=stride, padding=padding)
        assert y_eng.shape == y_fq.shape
        np.testing.assert_allclose(np.asarray(y_eng), np.asarray(y_fq),
                                   rtol=1e-4, atol=1e-5)


def test_engine_conv_noise_mode():
    """Noise-injected conv through the native engine plan: a key is
    required, and a fixed key is deterministic (per-tile fold_in keys)."""
    from repro.core.noise_model import NO_NOISE, NoiseConfig
    cfg = cl.CIMConfig(mode="engine", noise=NoiseConfig())
    p = cl.init_cim_linear(jax.random.PRNGKey(0), 3 * 3 * 4, 8, cfg=cfg)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (2, 6, 6, 4)))
    with pytest.raises(ValueError, match="requires a PRNG key"):
        cl.cim_conv2d_apply(p, x, cfg)
    key = jax.random.PRNGKey(2)
    y = cl.cim_conv2d_apply(p, x, cfg, key=key)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(cl.cim_conv2d_apply(p, x, cfg, key=key)))
    y_clean = cl.cim_conv2d_apply(p, x, cfg.replace(noise=NO_NOISE))
    assert y.shape == y_clean.shape == (2, 6, 6, 8)
    assert bool(jnp.any(y != y_clean))
