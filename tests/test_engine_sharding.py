"""Sharded multi-macro engine dispatch: bit-exactness acceptance suite.

The acceptance bar (ISSUE 4): an engine sharded across a D-device mesh
(col tiles when the layer offers >= D of them, GEMM rows otherwise) must be
*bit-exact* with the plain single-device engine — across the precision
grid, under NO_NOISE and under a fixed noise key, through uneven
col-tile/device-count splits, and in the mesh-of-1 degenerate case.  The
pure-jnp reference (which always executes serially) doubles as the oracle
for the sharded kernel path.

Multi-device cases need fake CPU devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_engine_sharding.py
Under the plain tier-1 run (1 device) those cases skip; the dedicated CI
job runs them on 8 fake devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mapping
from repro.core.mapping import LayerSpec
from repro.core.noise_model import NoiseConfig
from repro.models.cnn import lenet_engine_specs
from repro.runtime import CIMInferenceEngine, EngineConfig, ShardingConfig

N_DEV = len(jax.devices())
R_INS = (1, 2, 4, 8)
R_WS = (1, 2, 4)
MESHES = (2, 8)             # >= 2 mesh shapes for the multi-device cases


def _need(devices: int) -> None:
    if N_DEV < devices:
        pytest.skip(f"needs {devices} devices, jax reports {N_DEV} (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _sharded_pair(specs, devices, *, noise=None, seed=0, stream_rows=0,
                  activations=None, pools=None):
    """(single-device engine, sharded engine) over identical specs."""
    base = EngineConfig(stream_rows=stream_rows)
    if noise is not None:
        base = base.replace(noise=noise)
    eng1 = CIMInferenceEngine(specs, base, activations=activations,
                              pools=pools)
    engd = CIMInferenceEngine(
        specs, base.replace(sharding=ShardingConfig(devices=devices)),
        activations=activations, pools=pools)
    params = eng1.init_params(jax.random.PRNGKey(seed))
    return eng1, engd, params


# ---- shard planning (no devices needed) -----------------------------------

def test_shard_layer_kind_selection():
    """Col tiles shard when there is at least one per device; otherwise the
    GEMM-row dimension M shards (weights replicated)."""
    spec = LayerSpec(m=24, k=144, n=320, r_in=4, r_w=4)   # 5 col tiles
    mp = mapping.map_layer(spec)
    assert mp.col_tiles == 5
    col = mapping.shard_layer(spec, mp, 2)
    assert col.kind == "col" and col.tiles_per_device == 3
    assert col.efficiency == pytest.approx(5 / 6)
    rows = mapping.shard_layer(spec, mp, 8)               # 5 < 8 -> rows
    assert rows.kind == "rows" and rows.rows_per_device == 3
    assert rows.efficiency == pytest.approx(24 / 24)
    with pytest.raises(ValueError, match="devices"):
        mapping.shard_layer(spec, mp, 0)


def test_split_even_slices_uniform():
    """Even col tiles are uniform (SPMD requirement); the covered extent
    may pad past n."""
    sl = mapping.split_even_slices(130, 3)
    assert sl == [(0, 44), (44, 44), (88, 44)]
    assert mapping.split_even_slices(64, 1) == [(0, 64)]


def test_plan_carries_shard_and_uniform_tiles():
    cfg = EngineConfig(sharding=ShardingConfig(devices=1))
    eng = CIMInferenceEngine([LayerSpec(m=4, k=72, n=130, r_in=4, r_w=2)],
                             cfg)
    lp = eng.plan.layers[0]
    assert lp.shard is not None and lp.shard.devices == 1
    sizes = {sz for _, sz in lp.n_slices}
    assert len(sizes) == 1                  # uniform
    assert lp.n_pad >= lp.spec.n
    assert CIMInferenceEngine(
        [LayerSpec(m=4, k=72, n=130, r_in=4, r_w=2)]).plan.layers[0].shard \
        is None


def test_perf_report_shard_columns():
    specs = [LayerSpec(m=8, k=144, n=80, r_in=4, r_w=4),
             LayerSpec(m=8, k=80, n=32, r_in=4, r_w=4)]
    rep = CIMInferenceEngine(
        specs, EngineConfig(sharding=ShardingConfig(devices=1))
    ).perf_report()
    assert rep["sharding"]["devices"] == 1
    assert rep["layers"][0]["shard"]["kind"] == "col"
    assert rep["layers"][0]["shard"]["parallel_efficiency"] == 1.0
    assert rep["total"]["macro_evals_per_device"] > 0
    # unit consistency: *_total and *_per_device both count full macro
    # invocations (x m), matching the per-layer macro_evals column
    assert rep["total"]["macro_evals_total"] == sum(
        l["macro_evals"] for l in rep["layers"])
    assert rep["total"]["parallel_efficiency"] == pytest.approx(
        rep["total"]["macro_evals_total"]
        / (1 * rep["total"]["macro_evals_per_device"]))
    assert 0.0 < rep["total"]["parallel_efficiency"] <= 1.0
    plain = CIMInferenceEngine(specs).perf_report()
    assert "sharding" not in plain and "shard" not in plain["layers"][0]


# ---- mesh-of-1 degenerate case (always runs) ------------------------------

def test_mesh_of_one_degenerate():
    """A 1-device ShardingConfig still routes through shard_map and stays
    bit-exact with the plain engine and the serial reference."""
    specs = [LayerSpec(m=8, k=144, n=80, r_in=4, r_w=4),
             LayerSpec(m=8, k=80, n=32, r_in=4, r_w=4)]
    eng1, engd, params = _sharded_pair(specs, 1)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (8, 144)))
    y1, yd = np.asarray(eng1(params, x)), np.asarray(engd(params, x))
    np.testing.assert_array_equal(yd, y1)
    np.testing.assert_array_equal(yd, np.asarray(engd.reference(params, x)))


def test_mesh_of_one_degenerate_noise():
    specs = [LayerSpec(m=8, k=144, n=80, r_in=4, r_w=4)]
    eng1, engd, params = _sharded_pair(specs, 1, noise=NoiseConfig())
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (8, 144)))
    key = jax.random.PRNGKey(11)
    np.testing.assert_array_equal(np.asarray(engd(params, x, key)),
                                  np.asarray(eng1(params, x, key)))


def test_sharding_wants_more_devices_than_visible():
    """Dispatch (not planning) raises when the mesh cannot be built."""
    eng = CIMInferenceEngine(
        [LayerSpec(m=4, k=72, n=16, r_in=4, r_w=2)],
        EngineConfig(sharding=ShardingConfig(devices=N_DEV + 1)))
    params = eng.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 72))
    with pytest.raises(ValueError, match="devices"):
        eng(params, x)


# ---- multi-device bit-exactness -------------------------------------------

@pytest.mark.parametrize("devices", MESHES)
@pytest.mark.parametrize("r_w", R_WS)
@pytest.mark.parametrize("r_in", R_INS)
def test_lenet_grid_sharded_bitexact(r_in, r_w, devices):
    """Acceptance: the whole LeNet plan, sharded, matches the single-device
    engine bit for bit across the full precision grid (NO_NOISE)."""
    _need(devices)
    from repro.core.cim_layers import CIMConfig
    specs, acts, pools = lenet_engine_specs(
        2, h=12, w=12, cim=CIMConfig(r_in=r_in, r_w=r_w))
    eng1, engd, params = _sharded_pair(specs, devices, activations=acts,
                                       pools=pools, seed=r_in * 10 + r_w)
    x = jax.random.uniform(jax.random.PRNGKey(2), (2, 12, 12, 1))
    np.testing.assert_array_equal(np.asarray(engd(params, x)),
                                  np.asarray(eng1(params, x)))


@pytest.mark.parametrize("devices", MESHES)
def test_lenet_sharded_noise_fixed_key(devices):
    """Acceptance: sharded noisy inference is bit-exact with the
    single-device path under a fixed key (and with the serial reference),
    and deterministic."""
    _need(devices)
    from repro.core.cim_layers import CIMConfig
    specs, acts, pools = lenet_engine_specs(
        2, h=12, w=12, cim=CIMConfig(r_in=4, r_w=2))
    eng1, engd, params = _sharded_pair(specs, devices, noise=NoiseConfig(),
                                       activations=acts, pools=pools)
    x = jax.random.uniform(jax.random.PRNGKey(2), (2, 12, 12, 1))
    key = jax.random.PRNGKey(5)
    yd = np.asarray(engd(params, x, key))
    np.testing.assert_array_equal(yd, np.asarray(eng1(params, x, key)))
    np.testing.assert_array_equal(
        yd, np.asarray(engd.reference(params, x, key)))
    np.testing.assert_array_equal(yd, np.asarray(engd(params, x, key)))
    assert np.any(yd != np.asarray(engd(params, x, jax.random.PRNGKey(6))))


@pytest.mark.parametrize("devices", MESHES)
@pytest.mark.parametrize("n", (320, 130))
def test_uneven_col_tile_device_split(n, devices):
    """Col-tile counts that do not divide the device count (5 tiles at
    n=320, 3 at n=130 — the latter also pads columns inside its uniform
    tiles) stay bit-exact, clean and noisy."""
    _need(devices)
    specs = [LayerSpec(m=8, k=144, n=n, r_in=4, r_w=4)]
    eng1, engd, params = _sharded_pair(specs, devices)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (8, 144)))
    np.testing.assert_array_equal(np.asarray(engd(params, x)),
                                  np.asarray(eng1(params, x)))
    n1, nd, paramsn = _sharded_pair(specs, devices, noise=NoiseConfig(),
                                    seed=3)
    key = jax.random.PRNGKey(9)
    np.testing.assert_array_equal(np.asarray(nd(paramsn, x, key)),
                                  np.asarray(n1(paramsn, x, key)))


@pytest.mark.parametrize("devices", MESHES)
def test_uneven_rows_kind(devices):
    """The "rows" kind with M not divisible by the device count (row
    padding) stays bit-exact, clean and noisy, incl. multi-row-tile K."""
    _need(devices)
    specs = [LayerSpec(m=5, k=2304, n=16, r_in=4, r_w=2)]   # 2 row tiles
    eng1, engd, params = _sharded_pair(specs, devices)
    assert engd.plan.layers[0].shard.kind == "rows"
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (5, 2304)))
    np.testing.assert_array_equal(np.asarray(engd(params, x)),
                                  np.asarray(eng1(params, x)))
    n1, nd, paramsn = _sharded_pair(specs, devices, noise=NoiseConfig(),
                                    seed=4)
    key = jax.random.PRNGKey(13)
    np.testing.assert_array_equal(np.asarray(nd(paramsn, x, key)),
                                  np.asarray(n1(paramsn, x, key)))


def test_stream_chunking_bit_invariant_under_noise():
    """The per-(row tile, col tile) thermal fields span all GEMM rows, so
    the stream_rows chunking — the mechanism row sharding reuses — never
    changes a bit even in noise mode (stronger than the PR 3 contract,
    which only promised distribution invariance)."""
    specs = [LayerSpec(m=16, k=72, n=16, r_in=4, r_w=2)]
    key = jax.random.PRNGKey(2)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (16, 72)))
    outs = []
    for stream_rows in (0, 4, 7):
        eng = CIMInferenceEngine(
            specs, EngineConfig(noise=NoiseConfig(), stream_rows=stream_rows))
        params = eng.init_params(jax.random.PRNGKey(0))
        outs.append(np.asarray(eng(params, x, key)))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


@pytest.mark.parametrize("devices", MESHES)
def test_sharded_streaming_composition(devices):
    """stream_rows chunking composes with both shard kinds bit-exactly."""
    _need(devices)
    specs = [LayerSpec(m=12, k=144, n=320, r_in=4, r_w=4),  # col kind
             LayerSpec(m=12, k=320, n=16, r_in=4, r_w=4)]   # rows kind
    eng1, engd, params = _sharded_pair(specs, devices, stream_rows=5,
                                       noise=NoiseConfig())
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (12, 144)))
    key = jax.random.PRNGKey(21)
    np.testing.assert_array_equal(np.asarray(engd(params, x, key)),
                                  np.asarray(eng1(params, x, key)))


@pytest.mark.parametrize("devices", MESHES)
def test_cim_layers_engine_mode_sharded(devices):
    """CIMConfig.sharding threads through cim_linear_apply's engine mode."""
    _need(devices)
    from repro.core import cim_layers as cl
    from repro.runtime import ShardingConfig as SC
    cfg = cl.CIMConfig(mode="engine", r_in=4, r_w=4)
    p = cl.init_cim_linear(jax.random.PRNGKey(0), 144, 320, cfg=cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 144))
    y1 = np.asarray(cl.cim_linear_apply(p, x, cfg))
    yd = np.asarray(cl.cim_linear_apply(
        p, x, cfg.replace(sharding=SC(devices=devices))))
    np.testing.assert_array_equal(yd, y1)
