"""CIM layer behaviour: fidelity scaling, adaptive swing, modes, mapping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:               # degrade to the deterministic stub
    from hypofallback import given, settings, st

from repro.core import cim_layers as cl
from repro.core.hw import DEFAULT_MACRO
from repro.core.mapping import LayerSpec, conv_layer_spec, map_layer, split_k_slices
from repro.core.noise_model import NoiseConfig


def _rel_err(cfg, K=512, N=32, seed=0):
    p = cl.init_cim_linear(jax.random.PRNGKey(seed), K, N, cfg=cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (64, K))
    y = cl.cim_linear_apply(p, x, cfg)
    y_ref = x @ p["w"]
    return float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))


def test_bypass_exact():
    assert _rel_err(cl.CIMConfig(mode="bypass")) < 1e-6


def test_fakequant_distribution_aware_beats_unity_gamma():
    """The paper's central claim, in layer form."""
    cfg = cl.CIMConfig(mode="fakequant", max_gamma=2.0**16)
    p = cl.init_cim_linear(jax.random.PRNGKey(0), 512, 32, cfg=cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 512))
    y_ref = x @ p["w"]
    err_da = float(jnp.linalg.norm(cl.cim_linear_apply(p, x, cfg) - y_ref))
    p_unity = {**p, "abn_log_gamma": jnp.zeros_like(p["abn_log_gamma"])}
    err_unity = float(jnp.linalg.norm(cl.cim_linear_apply(p_unity, x, cfg)
                                      - y_ref))
    assert err_da < 0.15 * err_unity


def test_adaptive_swing_beats_fixed():
    """Serial-split swing adaptation recovers precision at small fan-in."""
    K = 72   # two units out of 32
    adaptive = cl.CIMConfig(mode="fakequant", adaptive_swing=True)
    fixed = cl.CIMConfig(mode="fakequant", adaptive_swing=False)
    # same gamma for both: isolate the swing effect
    p = cl.init_cim_linear(jax.random.PRNGKey(2), K, 16, cfg=adaptive)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(3), (128, K)))
    y_ref = x @ p["w"]
    e_ad = float(jnp.linalg.norm(cl.cim_linear_apply(p, x, adaptive) - y_ref))
    e_fx = float(jnp.linalg.norm(cl.cim_linear_apply(p, x, fixed) - y_ref))
    assert e_ad < e_fx


def test_higher_rout_more_accurate():
    errs = [_rel_err(cl.CIMConfig(mode="fakequant", r_out=r,
                                  max_gamma=2.0**16)) for r in (2, 4, 8)]
    assert errs[0] > errs[1] > errs[2]


def test_sim_matches_fakequant_statistics():
    """Voltage sim and fakequant paths agree closely (same math modulo
    float rounding at code boundaries)."""
    cfg_f = cl.CIMConfig(mode="fakequant")
    cfg_s = cl.CIMConfig(mode="sim")
    p = cl.init_cim_linear(jax.random.PRNGKey(4), 144, 8, cfg=cfg_f)
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 144))
    yf = cl.cim_linear_apply(p, x, cfg_f)
    ys = cl.cim_linear_apply(p, x, cfg_s)
    assert float(jnp.linalg.norm(yf - ys) / jnp.linalg.norm(yf)) < 0.1


def test_noise_injection_changes_output():
    cfg = cl.CIMConfig(mode="fakequant", noise=NoiseConfig())
    p = cl.init_cim_linear(jax.random.PRNGKey(6), 256, 16, cfg=cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (8, 256))
    y1 = cl.cim_linear_apply(p, x, cfg, key=jax.random.PRNGKey(1))
    y2 = cl.cim_linear_apply(p, x, cfg, key=jax.random.PRNGKey(2))
    assert float(jnp.max(jnp.abs(y1 - y2))) > 0


def test_conv_via_im2col():
    cfg = cl.CIMConfig(mode="bypass")
    key = jax.random.PRNGKey(8)
    p = cl.init_cim_linear(key, 3 * 3 * 4, 8)
    x = jax.random.normal(key, (2, 10, 10, 4))
    y = cl.cim_conv2d_apply(p, x, cfg)
    assert y.shape == (2, 10, 10, 8)
    # against lax.conv direct
    w = p["w"].reshape(3, 3, 4, 8)
    want = jax.lax.conv_general_dilated(
        x, w, (1, 1), [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---- mapping properties ----------------------------------------------------

@given(st.integers(1, 40000), st.integers(1, 4096), st.integers(1, 4),
       st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_mapping_invariants(k, n, r_w, r_in):
    spec = LayerSpec(m=1, k=k, n=n, r_in=r_in, r_w=r_w)
    mp = map_layer(spec)
    assert 1 <= mp.rows_per_tile <= DEFAULT_MACRO.n_rows
    assert mp.rows_per_tile * mp.row_tiles >= k
    assert mp.n_dp >= mp.rows_per_tile
    assert 0 < mp.utilization <= 1.0
    ch_per_tile = 64 * max(1, 4 // r_w)
    assert mp.col_tiles * ch_per_tile >= n
    # split_k covers exactly
    slices = split_k_slices(k, mp.row_tiles)
    assert sum(sz for _, sz in slices) == k
    assert all(sz <= DEFAULT_MACRO.n_rows for _, sz in slices)


def test_conv_layer_spec():
    spec = conv_layer_spec(batch=4, h=28, w=28, c_in=16, c_out=32)
    assert spec.k == 9 * 16
    assert spec.m == 4 * 28 * 28
