"""Unit + property tests of the exact digital-equivalent macro model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:               # degrade to the deterministic stub
    from hypofallback import given, settings, st

from repro.core import digital_ref as dr
from repro.core.hw import DEFAULT_MACRO


@given(st.integers(1, 4), st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_plane_roundtrip(r_w, seed):
    rng = np.random.default_rng(seed)
    full = 2**r_w - 1
    w = rng.integers(-full, full + 1, size=(13, 7))
    w_odd = dr.quantize_weight_odd(jnp.asarray(w), r_w)
    planes = dr.encode_weight_planes(w_odd, r_w)
    assert planes.shape == (r_w, 13, 7)
    assert set(np.unique(np.asarray(planes))) <= {-1, 1}
    back = dr.decode_weight_planes(planes)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w_odd))
    # odd grid: all values odd, within range
    w_np = np.asarray(w_odd)
    assert np.all(np.abs(w_np) <= full)
    assert np.all(w_np % 2 != 0)


@given(st.integers(1, 8), st.integers(1, 4), st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_serial_equals_direct(r_in, r_w, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 2**r_in, size=(5, 24)), jnp.int32)
    w = dr.quantize_weight_odd(
        jnp.asarray(rng.integers(-(2**r_w), 2**r_w, size=(24, 6))), r_w)
    planes = dr.encode_weight_planes(w, r_w)
    d1 = dr.bitplane_dot(x, planes)
    d2 = dr.bitplane_dot_serial(x, planes, r_in)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_adc_floor_formula():
    dp = jnp.array([-1000, -1, 0, 1, 1000], jnp.int32)
    code = dr.dsci_adc_code(dp, r_in=8, r_w=4, r_out=8, n_dp=1152,
                            gamma=4.0, beta_codes=0.0)
    g = dr.adc_gain_factor(8, 4, 8, 1152)
    expect = np.clip(np.floor(128 + 4.0 * g * np.asarray(dp)), 0, 255)
    np.testing.assert_array_equal(np.asarray(code), expect.astype(np.int32))


def test_adc_clipping_range():
    dp = jnp.array([-10**9, 10**9], jnp.int32)
    code = dr.dsci_adc_code(dp, r_in=8, r_w=4, r_out=6, n_dp=36, gamma=32.0)
    assert int(code[0]) == 0 and int(code[1]) == 63


@given(st.integers(1, 8), st.integers(2, 8), st.sampled_from([1., 2., 8., 32.]))
@settings(max_examples=20, deadline=None)
def test_dequant_inverse_within_lsb(r_in, r_out, gamma):
    rng = np.random.default_rng(int(gamma) + r_in + r_out)
    n_dp = 144
    g = dr.adc_gain_factor(r_in, 2, r_out, n_dp)
    # dp small enough not to clip
    half_range = (2**(r_out - 1) - 1) / (gamma * g)
    dp = jnp.asarray(rng.integers(-half_range * 0.9, half_range * 0.9,
                                  size=(64,)), jnp.int32)
    code = dr.dsci_adc_code(dp, r_in=r_in, r_w=2, r_out=r_out, n_dp=n_dp,
                            gamma=gamma)
    dp_hat = dr.dequantize_code(code, r_in=r_in, r_w=2, r_out=r_out,
                                n_dp=n_dp, gamma=gamma)
    # quantization error bounded by one code step
    assert np.max(np.abs(np.asarray(dp_hat) - np.asarray(dp))) <= \
        1.0 / (gamma * g)


def test_swing_adaptive_gain_grows_at_low_cin():
    """The paper's core claim: fewer connected units -> larger code gain."""
    g_small = dr.adc_gain_factor(8, 4, 8, 36,
                                 DEFAULT_MACRO.swing_efficiency(1),
                                 DEFAULT_MACRO.alpha_adc())
    g_full = dr.adc_gain_factor(8, 4, 8, 1152,
                                DEFAULT_MACRO.swing_efficiency(32),
                                DEFAULT_MACRO.alpha_adc())
    assert g_small > 10 * g_full
