import os
import sys

# smoke tests and benches must see 1 device (dryrun sets its own flags)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# the hypothesis fallback stub lives next to the tests
sys.path.insert(0, os.path.dirname(__file__))
