"""Per-arch smoke tests (assignment requirement): reduced config, one
forward + one train step on CPU, output shapes + no NaNs; plus
train-vs-decode consistency for one arch per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_config, get_smoke_config
from repro.core.cim_layers import CIMConfig
from repro.launch.steps import init_train_state, make_train_step
from repro.models import transformer as tf
from repro.optim import AdamWConfig

B, S = 2, 24


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["encoder_frames"] = jax.random.normal(
            key, (B, 32, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", all_archs())
def test_forward_shapes_finite(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = tf.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, _, aux = tf.forward(
        cfg, params, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"),
        encoder_frames=batch.get("encoder_frames"))
    s_out = S + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, s_out, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", all_archs())
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    state = init_train_state(cfg, key)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    state, metrics = step(state, _batch(cfg, key))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    for leaf in jax.tree.leaves(state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf))), "NaN in updated params"


@pytest.mark.parametrize("arch", all_archs())
def test_full_config_instantiable(arch):
    """FULL configs are exercised via eval_shape only (no allocation)."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    assert n_params > 1e8, f"{arch}: suspiciously small {n_params}"


@pytest.mark.parametrize("arch", ["granite_8b", "mixtral_8x22b",
                                  "mamba2_1_3b", "recurrentgemma_2b",
                                  "whisper_medium"])
def test_train_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(2)
    params = tf.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    kwargs = {}
    cache_kwargs = {}
    if cfg.family == "audio":
        frames = jax.random.normal(key, (1, 16, cfg.d_model), jnp.bfloat16)
        kwargs["encoder_frames"] = frames
    full, _, _ = tf.forward(cfg, params, toks, **kwargs)
    cache = tf.init_cache(cfg, 1, max_len=16)
    outs = []
    for t in range(8):
        step_kwargs = dict(kwargs) if (cfg.family == "audio" and t == 0) else {}
        lg, cache, _ = tf.forward(cfg, params, toks[:, t:t + 1], cache=cache,
                                  **step_kwargs)
        outs.append(lg[:, 0])
    err = np.max(np.abs(np.asarray(full, np.float32)
                        - np.asarray(jnp.stack(outs, 1), np.float32)))
    assert err < 0.1, f"{arch}: train/decode divergence {err}"


def test_cim_fakequant_transformer():
    """The paper's technique on a transformer: forward+grad, finite."""
    cfg = get_smoke_config("granite_8b")
    cfg = cfg.replace(cim=CIMConfig(mode="fakequant", max_gamma=2.0**16))
    key = jax.random.PRNGKey(3)
    state = init_train_state(cfg, key)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    state, metrics = step(state, _batch(cfg, key))
    assert np.isfinite(float(metrics["loss"]))
