"""Per-kernel sweep: Pallas cim_mbiw vs the pure-jnp oracle (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import digital_ref as dr
from repro.core.hw import DEFAULT_MACRO
from repro.kernels.cim_mbiw import ops
from repro.kernels.cim_mbiw.ref import cim_matmul_ref, cim_matmul_ref_serial


def _rand_case(m, k, n, r_in, r_w, seed):
    kx, kw, kg, kb = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.randint(kx, (m, k), 0, 2**r_in).astype(jnp.int32)
    w = dr.quantize_weight_odd(
        jax.random.randint(kw, (k, n), -(2**r_w - 1), 2**r_w), r_w)
    gamma = 2.0 ** jax.random.randint(kg, (n,), 0, 6).astype(jnp.float32)
    beta = jax.random.randint(kb, (n,), -16, 16).astype(jnp.float32)
    return x, w, gamma, beta


SHAPES = [
    (8, 36, 4, 1, 1, 1), (16, 144, 16, 4, 2, 4), (32, 256, 64, 8, 4, 8),
    (100, 1152, 64, 8, 4, 8), (17, 300, 33, 5, 3, 6), (64, 1000, 40, 8, 4, 4),
    (1, 128, 1, 8, 4, 8), (256, 512, 128, 7, 2, 8),
]


@pytest.mark.parametrize("m,k,n,r_in,r_w,r_out", SHAPES)
def test_kernel_matches_oracle(m, k, n, r_in, r_w, r_out):
    x, w, gamma, beta = _rand_case(m, k, n, r_in, r_w, seed=m + k + n)
    cfg = DEFAULT_MACRO
    units = cfg.units_for_rows(min(k, cfg.n_rows))
    g0 = dr.adc_gain_factor(r_in, r_w, r_out, units * cfg.rows_per_unit,
                            cfg.swing_efficiency(units), cfg.alpha_adc())
    got = ops.cim_matmul(x, w, gamma, beta, r_in=r_in, r_out=r_out, g0=g0)
    want = cim_matmul_ref(x, w, gamma, beta, g0=g0, r_out=r_out)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_block_shapes():
    """Different BlockSpec tilings give identical results."""
    x, w, gamma, beta = _rand_case(64, 512, 64, 8, 4, seed=0)
    g0 = dr.adc_gain_factor(8, 4, 8, 512)
    a = ops.cim_matmul(x, w, gamma, beta, r_in=8, r_out=8, g0=g0,
                       bm=128, bn=128, bk=128)
    b = ops.cim_matmul(x, w, gamma, beta, r_in=8, r_out=8, g0=g0,
                       bm=256, bn=256, bk=512)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_row_tiled_layer_matches_fakequant_layer():
    """kernels.ops.cim_linear (Pallas path) == core fakequant dp_hat path."""
    from repro.core import cim_layers as cl
    key = jax.random.PRNGKey(5)
    k_dim, n = 2000, 32
    x, w, gamma, beta = _rand_case(16, k_dim, n, 8, 4, seed=11)
    dp_hat = ops.cim_linear(x, w, gamma, beta, r_in=8, r_w=4, r_out=8)
    # reference: per-tile dequantized sum, same math as cim_layers
    cfg = DEFAULT_MACRO
    units = cfg.units_for_rows(min(k_dim, cfg.n_rows))
    g0 = dr.adc_gain_factor(8, 4, 8, units * cfg.rows_per_unit,
                            cfg.swing_efficiency(units), cfg.alpha_adc())
    want = jnp.zeros((16, n))
    for t in range((k_dim + 1151) // 1152):
        ks, ke = t * 1152, min((t + 1) * 1152, k_dim)
        codes = cim_matmul_ref(x[:, ks:ke], w[ks:ke], gamma, beta,
                               g0=g0, r_out=8)
        want = want + (codes.astype(jnp.float32) + 0.5 - 128 - beta) \
            / (gamma * g0)
    np.testing.assert_allclose(np.asarray(dp_hat), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("r_w", (1, 2, 4))
@pytest.mark.parametrize("r_in", (1, 2, 4, 8))
def test_precision_variant_matches_serial_oracle(r_in, r_w):
    """Dispatch variant == direct oracle == literal per-precision serial
    walk (bit-serial <=2b / nibble-serial >=3b input planes, 2^b weight
    column combination)."""
    r_out = 8
    x, w, gamma, beta = _rand_case(8, 72, 16, r_in, r_w, seed=r_in + 2 * r_w)
    cfg = DEFAULT_MACRO
    units = cfg.units_for_rows(72)
    g0 = dr.adc_gain_factor(r_in, r_w, r_out, units * cfg.rows_per_unit,
                            cfg.swing_efficiency(units), cfg.alpha_adc())
    fn = ops.kernel_variant(ops.KernelPrecision(r_in, r_w, r_out),
                            bm=128, bn=128, bk=128)
    got = fn(x, w, gamma, beta, g0)
    want = cim_matmul_ref(x, w, gamma, beta, g0=g0, r_out=r_out)
    serial = cim_matmul_ref_serial(x, w, gamma, beta, r_in=r_in, r_w=r_w,
                                   r_out=r_out, g0=g0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(serial), np.asarray(want))


def test_kernel_variant_cache_dedup():
    """Variants are shared across r_w (weights pre-decoded) and across
    r_in values with the same plane layout."""
    a = ops.kernel_variant(ops.KernelPrecision(8, 1, 8))
    b = ops.kernel_variant(ops.KernelPrecision(8, 4, 8))
    c = ops.kernel_variant(ops.KernelPrecision(5, 4, 8))   # also 2x4b planes
    d = ops.kernel_variant(ops.KernelPrecision(4, 4, 8))   # 1 plane
    e = ops.kernel_variant(ops.KernelPrecision(8, 4, 4))   # other epilogue
    assert a is b is c
    assert d is not a and e is not a


def test_split_planes():
    x = jnp.array([[0, 1, 15, 16, 255, 128]], jnp.int32)
    planes, n = ops.split_planes(x, 8)
    assert n == 2
    lo = np.asarray(planes[:, :6], np.int32)
    hi = np.asarray(planes[:, 6:], np.int32)
    np.testing.assert_array_equal(lo + 16 * hi, np.asarray(x))
    planes7, n7 = ops.split_planes(jnp.array([[127]], jnp.int32), 7)
    assert n7 == 1
