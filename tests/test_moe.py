"""MoE dispatch correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cim_layers import BYPASS
from repro.models.moe import _moe_local, init_moe, moe_block


def _dense_reference(x, probs, top_idx, w_gate, w_up, w_down, act=jax.nn.silu):
    """Every token through its experts, no capacity drops."""
    t, d = x.shape
    e = w_up.shape[0]
    out = jnp.zeros((t, d), jnp.float32)
    for ei in range(e):
        h = act(x @ w_gate[ei]) * (x @ w_up[ei])
        y = h @ w_down[ei]
        for k in range(top_idx.shape[1]):
            m = (top_idx[:, k] == ei).astype(jnp.float32)
            out = out + y * (m * probs[:, k])[:, None]
    return out


def test_moe_local_matches_dense_with_ample_capacity():
    key = jax.random.PRNGKey(0)
    t, d, f, e, k = 64, 16, 32, 4, 2
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (t, d))
    w_gate = 0.3 * jax.random.normal(ks[1], (e, d, f))
    w_up = 0.3 * jax.random.normal(ks[2], (e, d, f))
    w_down = 0.3 * jax.random.normal(ks[3], (e, f, d))
    logits = jax.random.normal(ks[4], (t, e))
    probs_full = jax.nn.softmax(logits, -1)
    top_p, top_idx = jax.lax.top_k(probs_full, k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)

    got = _moe_local(x, top_p, top_idx, w_gate, w_up, w_down,
                     jnp.zeros((e, d)), jnp.zeros((e, d)),
                     n_experts=e, top_k=k, capacity_factor=8.0,
                     cim=BYPASS, act="silu", psum_axis=None)
    want = _dense_reference(x, top_p, top_idx, w_gate, w_up, w_down)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    """With capacity ~0, output must be ~0 (all dropped), not NaN."""
    key = jax.random.PRNGKey(1)
    t, d, f, e = 32, 8, 16, 4
    x = jax.random.normal(key, (t, d))
    params = init_moe(key, d, f, e)
    out, aux = moe_block(params, x[None], n_experts=e, top_k=2,
                         capacity_factor=0.01, cim=BYPASS)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(jnp.abs(out).mean()) < float(jnp.abs(x).mean())


def test_moe_grads_flow():
    key = jax.random.PRNGKey(2)
    params = init_moe(key, 8, 16, 4)
    x = jax.random.normal(key, (2, 8, 8))

    def loss(p):
        out, aux = moe_block(p, x, n_experts=4, top_k=2,
                             capacity_factor=2.0, cim=BYPASS)
        return jnp.mean(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    for name in ("w_gate", "w_up", "w_down", "router"):
        assert float(jnp.linalg.norm(g[name])) > 0, name
