"""In-flight batching scheduler: per-request numerical isolation (ISSUE 6).

The acceptance bar: continuous batched decode (`InflightScheduler`) must
produce, for EVERY request of EVERY admit/retire schedule, the token
stream bit-identical to decoding that request entirely alone
(`decode_sequential`) — clean and under one fixed noise key, on 1 device
and on an 8-device fake mesh — with zero re-traces and zero re-plans
after warmup, and the fused dispatch extents bounded by the BatchBuckets
ladder.  Schedules (arrival orders, prompt/generation lengths, slot
capacities) are property-fuzzed via hypothesis (or the deterministic
hypofallback stand-in when hypothesis is not installed).

Multi-device cases need fake CPU devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_scheduler.py
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypofallback import given, settings, st

from repro.core.noise_model import NoiseConfig
from repro.runtime import engine as rt
from repro.runtime.scheduler import (CIMDecodeLM, InflightScheduler, Request,
                                     SlotMap, decode_sequential)

N_DEV = len(jax.devices())


def _need(devices: int) -> None:
    if N_DEV < devices:
        pytest.skip(f"needs {devices} devices, jax reports {N_DEV} (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


KEY = jax.random.PRNGKey(7)
NOISE_KEY = jax.random.PRNGKey(123)
_MODELS = {}


def _model(noisy: bool = False, devices: int = 0) -> CIMDecodeLM:
    # module-cached: the compiled program (and its executables) are shared
    # across every fuzz case, so post-warmup cases pay only dispatch
    k = (noisy, devices)
    if k not in _MODELS:
        cfg = rt.EngineConfig(noise=NoiseConfig()) if noisy \
            else rt.EngineConfig()
        if devices:
            cfg = cfg.replace(
                sharding=rt.ShardingConfig(devices=devices))
        _MODELS[k] = CIMDecodeLM.toy(KEY, d=48, depth=2, vocab=23,
                                     r_in=4, r_w=2, cfg=cfg)
    return _MODELS[k]


_SOLO = {}


def _solo(model, req: Request, noisy: bool):
    # sequential-decode oracle, cached on everything the stream depends on
    k = (id(model), req.uid, req.prompt, req.max_new_tokens, req.point,
         noisy)
    if k not in _SOLO:
        _SOLO[k] = decode_sequential(model, req,
                                     NOISE_KEY if noisy else None)
    return _SOLO[k]


def _schedule(seed: int, n_req: int, capacity: int, points=("",)):
    """A deterministic fuzzed schedule: requests with random prompts,
    generation budgets, arrival times, and (when more than one point is
    offered) operating-point tags (same seed -> same schedule)."""
    rng = np.random.default_rng(seed)
    arrivals = []
    for uid in range(n_req):
        prompt = tuple(int(t) for t in
                       rng.integers(0, 23, size=int(rng.integers(1, 5))))
        req = Request(uid=uid, prompt=prompt,
                      max_new_tokens=int(rng.integers(1, 6)),
                      point=points[int(rng.integers(0, len(points)))])
        arrivals.append((int(rng.integers(0, 7)), req))
    return arrivals


def _check_schedule(noisy: bool, seed: int, n_req: int, capacity: int,
                    devices: int = 0, model=None, points=("",)):
    model = model or _model(noisy, devices)
    arrivals = _schedule(seed, n_req, capacity, points)
    sched = InflightScheduler(model, capacity=capacity,
                              key=NOISE_KEY if noisy else None)
    fused = sched.run(arrivals)
    assert set(fused) == {r.uid for _, r in arrivals}
    for _, req in arrivals:
        assert fused[req.uid] == _solo(model, req, noisy), \
            f"uid={req.uid} diverged from solo decode (seed={seed})"
    # fused dispatch only ever ran at ladder rungs
    ladder = set(model.bound.program.buckets.ladder(capacity))
    assert set(sched.metrics()["extents_seen"]) <= ladder


# ---- slot map --------------------------------------------------------------

def test_slotmap_lowest_free_and_extent():
    s = SlotMap(4)
    assert [s.alloc() for _ in range(3)] == [0, 1, 2]
    assert s.extent() == 3 and s.n_free == 1
    s.free(1)
    assert s.extent() == 3            # retirement moves no one
    assert s.alloc() == 1             # lowest free slot is reused first
    s.free(0), s.free(1), s.free(2)
    assert s.extent() == 0 and s.live() == ()
    with pytest.raises(KeyError):
        s.free(3)                     # not live
    [s.alloc() for _ in range(4)]
    with pytest.raises(RuntimeError, match="no free slot"):
        s.alloc()
    with pytest.raises(ValueError, match=">= 1"):
        SlotMap(0)


def test_slotmap_fuzz_alloc_free_orderings():
    """Regression for the heap rewrite: any interleaving of allocs and
    frees keeps the lowest-free-slot invariant, the live set, and
    extent() in lockstep with a brute-force model."""
    rng = np.random.default_rng(1234)
    for _ in range(200):
        cap = int(rng.integers(1, 9))
        s = SlotMap(cap)
        live = set()
        for _ in range(60):
            if live and (len(live) == cap or rng.random() < 0.45):
                victim = int(rng.choice(sorted(live)))
                s.free(victim)
                live.discard(victim)
            else:
                got = s.alloc()
                expect = min(set(range(cap)) - live)
                assert got == expect, (got, expect, sorted(live))
                live.add(got)
            assert set(s.live()) == live
            assert s.extent() == (max(live) + 1 if live else 0)
            assert s.n_free == cap - len(live)


def test_request_validation():
    with pytest.raises(ValueError, match="non-empty prompt"):
        Request(uid=0, prompt=(), max_new_tokens=1)
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(uid=0, prompt=(1,), max_new_tokens=0)
    with pytest.raises(ValueError, match="PRNG key"):
        InflightScheduler(_model(noisy=True), capacity=2)


# ---- the isolation property ------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6), st.sampled_from([2, 3, 4]))
def test_fused_decode_equals_sequential_clean(seed, n_req, capacity):
    """Any admit/retire schedule, clean: every request's fused token
    stream is bit-identical to its solo sequential decode."""
    _check_schedule(False, seed, n_req, capacity)


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 5), st.sampled_from([2, 4]))
def test_fused_decode_equals_sequential_noise(seed, n_req, capacity):
    """Any admit/retire schedule, one fixed noise key: identity-keyed
    thermal draws keep every fused request bit-identical to solo."""
    _check_schedule(True, seed, n_req, capacity)


@pytest.mark.parametrize("noisy", [False, True])
def test_fused_decode_equals_sequential_8dev(noisy):
    """The isolation property holds across the sharded 8-macro mesh."""
    _need(8)
    _check_schedule(noisy, seed=42, n_req=5, capacity=4, devices=8)


# ---- recompile bound -------------------------------------------------------

def test_zero_postwarmup_recompiles_across_schedules():
    """After one warmup schedule, new schedules (different arrivals,
    lengths, retirements) trigger zero re-traces and zero re-plans — the
    bucket ladder bounds the executable set."""
    model = _model(False)
    InflightScheduler(model, capacity=4).run(_schedule(1, 5, 4))  # warmup
    t0, p0 = rt.TRACE_COUNT["n"], rt.PLAN_COUNT["n"]
    for seed in (2, 3, 4):
        sched = InflightScheduler(model, capacity=4)
        sched.run(_schedule(seed, 6, 4))
    assert rt.TRACE_COUNT["n"] == t0, "post-warmup retrace"
    assert rt.PLAN_COUNT["n"] == p0, "post-warmup replan"


def test_one_token_request_admit_and_retire_same_step():
    """A max_new_tokens=1 request retires at admission (prefill already
    produced its only token) and never joins a fused step."""
    model = _model(False)
    req = Request(uid=9, prompt=(3, 1), max_new_tokens=1)
    sched = InflightScheduler(model, capacity=2)
    out = sched.run([(0, req)])
    assert out[9] == _solo(model, req, False)
    assert len(out[9]) == 1
    rec = sched.finished[9]
    assert rec.admitted_step == rec.finished_step


def test_queueing_beyond_capacity_preserves_isolation():
    """More requests than slots: the overflow queues, admits as slots
    free, and still matches solo decode exactly."""
    model = _model(False)
    reqs = [Request(uid=u, prompt=(u % 23, (2 * u) % 23),
                    max_new_tokens=1 + u % 4) for u in range(7)]
    sched = InflightScheduler(model, capacity=2)
    out = sched.run([(0, r) for r in reqs])
    for r in reqs:
        assert out[r.uid] == _solo(model, r, False)
    assert max(sched.metrics()["extents_seen"]) <= 2


# ---- decode attention kernel -----------------------------------------------

def test_ring_decode_attention_bit_exact():
    """The Pallas ring-decode attention kernel must equal the jitted
    digital reference bit for bit at ragged ring states (partially
    written rings via the additive bias)."""
    import jax.numpy as jnp
    from repro.kernels.flash_attn.ops import (ring_decode_attention,
                                              ring_decode_attention_ref)
    rng = np.random.default_rng(0)
    for r, l, h, hd in ((1, 4, 2, 8), (5, 16, 4, 12), (8, 16, 1, 16)):
        q = jnp.asarray(rng.standard_normal((r, h, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((r, l, h, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((r, l, h, hd)), jnp.float32)
        valid = rng.integers(1, l + 1, size=r)
        bias = jnp.asarray(
            np.where(np.arange(l)[None, :] < valid[:, None], 0.0, -1e9),
            jnp.float32)
        out = ring_decode_attention(q, k, v, bias)
        ref = ring_decode_attention_ref(q, k, v, bias)
        assert bool(jnp.all(out == ref)), (r, l, h, hd)


# ---- mixed operating points (ISSUE 10) -------------------------------------

_POINTS = ("", "throughput", "quality")


def _mixed_model(noisy: bool = False, devices: int = 0) -> CIMDecodeLM:
    # a precision ladder over the SAME weights: per-projection mixed
    # assignment for "quality", uniform low precision for "throughput"
    k = ("mixed", noisy, devices)
    if k not in _MODELS:
        cfg = rt.EngineConfig(noise=NoiseConfig()) if noisy \
            else rt.EngineConfig()
        if devices:
            cfg = cfg.replace(
                sharding=rt.ShardingConfig(devices=devices))
        _MODELS[k] = CIMDecodeLM.toy(
            KEY, d=48, depth=2, vocab=23, r_in=4, r_w=2, cfg=cfg,
            points={"throughput": (2, 1),
                    "quality": ((4, 2), (4, 4), (2, 2), (4, 2))})
    return _MODELS[k]


def test_point_validation():
    model = _mixed_model(False)
    assert model.points == ("", "quality", "throughput")
    with pytest.raises(ValueError, match="unknown operating point"):
        model.blocks_for("no-such-point")
    with pytest.raises(ValueError, match="unknown operating point"):
        InflightScheduler(model, capacity=2).submit(
            Request(uid=0, prompt=(1,), max_new_tokens=1, point="nope"))
    with pytest.raises(ValueError, match="str tag"):
        Request(uid=0, prompt=(1,), max_new_tokens=1, point=3)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6), st.sampled_from([2, 3, 4]))
def test_mixed_points_fused_equals_sequential_clean(seed, n_req, capacity):
    """Any schedule mixing base/quality/throughput requests: every fused
    request is bit-identical to its solo decode at the same point."""
    _check_schedule(False, seed, n_req, capacity,
                    model=_mixed_model(False), points=_POINTS)


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5), st.sampled_from([2, 4]))
def test_mixed_points_fused_equals_sequential_noise(seed, n_req, capacity):
    """Mixed-point schedules under one fixed noise key: per-request
    isolation holds whatever point a batchmate decodes at."""
    _check_schedule(True, seed, n_req, capacity,
                    model=_mixed_model(True), points=_POINTS)


@pytest.mark.parametrize("noisy", [False, True])
def test_mixed_points_8dev(noisy):
    """Mixed-point isolation across the sharded 8-macro mesh."""
    _need(8)
    _check_schedule(noisy, seed=99, n_req=4, capacity=3, devices=8,
                    model=_mixed_model(noisy, 8), points=_POINTS)


def test_mixed_points_zero_postwarmup_recompiles():
    """After one schedule covering every operating point, further mixed
    schedules trigger zero re-traces/re-plans: the point axis enlarges
    the executable set but the bucket ladder still bounds it."""
    model = _mixed_model(False)
    for seed in (12, 13):                                 # warmup pass
        InflightScheduler(model, capacity=4).run(
            _schedule(seed, 6, 4, _POINTS))
    t0, p0 = rt.TRACE_COUNT["n"], rt.PLAN_COUNT["n"]
    for seed in (12, 13):                                 # measured pass
        InflightScheduler(model, capacity=4).run(
            _schedule(seed, 6, 4, _POINTS))
    assert rt.TRACE_COUNT["n"] == t0, "post-warmup retrace"
    assert rt.PLAN_COUNT["n"] == p0, "post-warmup replan"


def test_mixed_points_metrics_and_report():
    """tokens_by_point accounts every finished request's stream, and
    point_report echoes the operating point next to its projected
    efficiency."""
    model = _mixed_model(False)
    reqs = [Request(uid=u, prompt=(u % 23, 1), max_new_tokens=2,
                    point=_POINTS[u % 3]) for u in range(6)]
    sched = InflightScheduler(model, capacity=4)
    out = sched.run([(0, r) for r in reqs])
    m = sched.metrics()
    for p in _POINTS:
        want = sum(len(out[r.uid]) for r in reqs if r.point == p)
        assert m["tokens_by_point"][p] == want
    rep = sched.point_report("throughput")
    assert rep["operating_point"]["name"] == "throughput"
    assert rep["operating_point"]["tops_per_w"] > 0
