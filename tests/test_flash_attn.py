"""Flash attention Pallas kernels vs the jnp oracle (fwd + bwd), including
the context-parallel shard_map path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.ops import flash_attention, flash_attention_sharded
from repro.kernels.flash_attn.ref import attention_ref


def _ref(q, k, v, causal, window=0):
    return jnp.swapaxes(
        attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                      jnp.swapaxes(v, 1, 2), causal=causal, window=window),
        1, 2)


CASES = [
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 100, 100, 4, 4, 32, True, 0),     # ragged: padding path
    (2, 64, 64, 8, 1, 64, True, 16),      # MQA + sliding window
    (1, 256, 256, 2, 2, 128, False, 0),   # non-causal (encoder)
    (1, 96, 192, 3, 1, 32, False, 0),     # cross-shaped Sq != Sk
]


@pytest.mark.parametrize("b,sq,sk,h,g,d,causal,window", CASES)
def test_flash_fwd_matches_oracle(b, sq, sk, h, g, d, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(sq + h), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, sk, g, d))
    v = jax.random.normal(ks[2], (b, sk, g, d))
    out = flash_attention(q, k, v, causal, window, 64, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(
        _ref(q, k, v, causal, window)), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
def test_flash_bwd_matches_oracle(causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 96, 4, 32))
    k = jax.random.normal(ks[1], (1, 96, 2, 32))
    v = jax.random.normal(ks[2], (1, 96, 2, 32))
    g1 = jax.grad(lambda q, k, v: jnp.sum(
        jnp.sin(flash_attention(q, k, v, causal, window, 32, 32))),
        argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: jnp.sum(
        jnp.sin(_ref(q, k, v, causal, window))), argnums=(0, 1, 2))(q, k, v)
    for a, b2 in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b2),
                                   atol=5e-5, rtol=5e-5)


def test_flash_dtype_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.bfloat16)
    out = flash_attention(q, k, v, True, 0, 32, 32)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(_ref(q, k, v, True),
                                                np.float32),
        atol=2e-2, rtol=2e-2)


def test_flash_sharded_falls_back_without_mesh():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    out = flash_attention_sharded(q, k, v, True, 0, 64, 64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(_ref(q, k, v, True)),
                               atol=2e-5, rtol=2e-5)
