"""End-to-end integration: losses must DROP (not just run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cim_layers import CIMConfig
from repro.data.lm_data import LMDataConfig, SyntheticLM
from repro.data.pseudo_mnist import make_dataset
from repro.launch.steps import init_train_state, make_train_step
from repro.models.cnn import init_mlp, mlp_forward
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _train_lm(arch, cim_mode, steps=25):
    cfg = get_smoke_config(arch).replace(
        cim=CIMConfig(mode=cim_mode, max_gamma=2.0**16))
    data = SyntheticLM(LMDataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8))
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3),
                                   total_steps=steps, warmup=2),
                   donate_argnums=(0,))
    losses = []
    for s in range(steps):
        toks, labels = data.batch_at(s)
        state, m = step(state, {"tokens": jnp.asarray(toks),
                                "labels": jnp.asarray(labels)})
        losses.append(float(m["loss"]))
    return losses


def test_lm_training_loss_drops_bypass():
    losses = _train_lm("olmo_1b", "bypass")
    assert losses[-1] < losses[0] - 0.15


@pytest.mark.slow
def test_lm_training_loss_drops_fakequant():
    losses = _train_lm("granite_8b", "fakequant")
    assert losses[-1] < losses[0] - 0.1


def test_mlp_cim_fakequant_learns_pseudo_mnist():
    xtr, ytr, xte, yte = make_dataset(n_train=1024, n_test=256, seed=0)
    cim = CIMConfig(mode="fakequant")
    params = init_mlp(jax.random.PRNGKey(0), dims=(784, 128, 10), cim=cim)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=2e-3, weight_decay=0.0)

    @jax.jit
    def step(params, opt, xb, yb):
        def loss(p):
            logits = mlp_forward(p, xb, cim)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, yb[:, None], 1))
        l, g = jax.value_and_grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, ocfg)
        return params, opt, l

    xs = jnp.asarray(xtr.reshape(-1, 784))
    ys = jnp.asarray(ytr)
    for epoch in range(6):
        for i in range(0, len(xs), 128):
            params, opt, l = step(params, opt, xs[i:i + 128], ys[i:i + 128])
    logits = mlp_forward(params, jnp.asarray(xte.reshape(-1, 784)), cim)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yte)))
    assert acc > 0.8, f"CIM-fakequant MLP only reached {acc:.2f}"
