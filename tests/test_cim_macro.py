"""Voltage-domain behavioural macro vs the exact digital reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cim_macro as cm
from repro.core import digital_ref as dr
from repro.core.hw import DEFAULT_MACRO
from repro.core.noise_model import NO_NOISE, NoiseConfig
from repro.core import noise_model as nm
from repro.core.calibration import calibrate_sar, residual_offsets


@pytest.mark.parametrize("r_in,r_w,r_out,k,gamma", [
    (8, 4, 8, 144, 1.0), (8, 4, 8, 1152, 4.0), (4, 2, 6, 300, 2.0),
    (1, 1, 1, 36, 1.0), (8, 1, 8, 72, 16.0), (2, 3, 5, 500, 8.0),
])
def test_voltage_sim_matches_digital_ref(r_in, r_w, r_out, k, gamma):
    key = jax.random.PRNGKey(k + r_in)
    x = jax.random.randint(key, (6, k), 0, 2**r_in).astype(jnp.int32)
    w = dr.quantize_weight_odd(
        jax.random.randint(jax.random.PRNGKey(1), (k, 8),
                           -(2**r_w - 1), 2**r_w), r_w)
    planes = dr.encode_weight_planes(w, r_w)
    beta_codes = jnp.arange(8, dtype=jnp.float32) - 4.0
    ref = dr.cim_matmul_ref(x, planes, r_in=r_in, r_out=r_out, gamma=gamma,
                            beta_codes=beta_codes)
    lsb_v = DEFAULT_MACRO.alpha_adc() * DEFAULT_MACRO.vddh / 2**(r_out - 1)
    sim = cm.cim_macro_forward(x, planes, r_in=r_in, r_out=r_out, gamma=gamma,
                               beta_v=beta_codes * lsb_v / gamma,
                               noise=NO_NOISE)
    diff = np.abs(np.asarray(ref) - np.asarray(sim))
    assert diff.max() <= 1, f"max code diff {diff.max()}"


def test_noise_perturbs_but_bounded():
    key = jax.random.PRNGKey(0)
    k = 288
    x = jax.random.randint(key, (8, k), 0, 256).astype(jnp.int32)
    w = dr.quantize_weight_odd(
        jax.random.randint(jax.random.PRNGKey(1), (k, 16), -15, 16), 4)
    planes = dr.encode_weight_planes(w, 4)
    clean = cm.cim_macro_forward(x, planes, r_in=8, r_out=8, gamma=8.0,
                                 noise=NO_NOISE)
    noisy = cm.cim_macro_forward(x, planes, r_in=8, r_out=8, gamma=8.0,
                                 noise=NoiseConfig(), key=jax.random.PRNGKey(7))
    diff = np.abs(np.asarray(clean).astype(int) - np.asarray(noisy))
    assert diff.max() > 0          # noise does something
    assert np.mean(diff) < 24      # but stays within a few gamma-scaled LSBs


def test_calibration_reduces_offset():
    """Fig. 19: calibration brings the spatial deviation down ~10x."""
    key = jax.random.PRNGKey(3)
    noise = NoiseConfig()
    raw = nm.sample_sa_offsets(key, 256, noise)
    res = residual_offsets(raw)
    assert float(jnp.std(res)) < 0.25 * float(jnp.std(raw))
    # residual bounded by the calibration LSB for in-range offsets
    in_range = jnp.abs(raw) < DEFAULT_MACRO.cal_range_v
    assert float(jnp.max(jnp.abs(jnp.where(in_range, res, 0.0)))) \
        <= DEFAULT_MACRO.cal_lsb_v
    # Fig. 14c / 19: the vast majority of columns end within ~1 ADC LSB
    lsb8 = DEFAULT_MACRO.vddh / 2**8
    assert float(jnp.mean(jnp.abs(res) < lsb8)) > 0.85


def test_calibration_saturates_out_of_range():
    big = jnp.array([0.5, -0.5])   # way beyond the calibration range
    comp = calibrate_sar(big)
    assert float(jnp.max(jnp.abs(comp))) <= DEFAULT_MACRO.cal_range_v + 1e-9


def test_settle_fraction_monotonic():
    n = NoiseConfig()
    f1 = nm.settle_fraction(1, 5.0, n)
    f32 = nm.settle_fraction(32, 5.0, n)
    assert 0.9 < f32 < f1 <= 1.0


def test_swing_efficiency_improves_with_split():
    """Fig. 6(b): serial-split restores swing at low C_in."""
    cfg = DEFAULT_MACRO
    # baseline keeps all 1152 rows connected -> small alpha regardless
    swing_base = 36 * cfg.alpha_eff_baseline()
    swing_split = 36 * cfg.alpha_eff(1)
    assert swing_split > 5 * swing_base
