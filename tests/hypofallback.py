"""Minimal stand-in for `hypothesis` when the package is not installed.

Only the surface the test suite actually uses: `given` over positional
strategies, `settings(max_examples=..., deadline=...)`, and the
`st.integers` / `st.sampled_from` strategies.  Draws are deterministic
(seeded per test from the strategy arguments) so failures reproduce; each
test runs `max_examples` sampled cases plus the strategy endpoints.

Usage in test modules:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from hypofallback import given, settings, st
"""
from __future__ import annotations

import zlib
from typing import Any, Callable, List

import numpy as np


class _Strategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any],
                 endpoints: List[Any]):
        self._draw = draw
        self.endpoints = endpoints

    def draw(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)


def _integers(lo: int, hi: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)), [lo, hi])


def _sampled_from(items) -> _Strategy:
    items = list(items)
    return _Strategy(lambda rng: items[int(rng.integers(len(items)))],
                     [items[0], items[-1]])


class st:  # noqa: N801 - mimics `hypothesis.strategies as st`
    integers = staticmethod(_integers)
    sampled_from = staticmethod(_sampled_from)


def given(*strategies: _Strategy):
    def deco(fn):
        # no functools.wraps: __wrapped__ would make pytest inspect the
        # original signature and demand fixtures for the drawn arguments
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", 10))
            # crc32, not hash(): str hashing is salted per process and
            # would make the drawn examples unreproducible across runs
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            # endpoints first: the corner cases hypothesis shrinks toward
            fn(*args, *(s.endpoints[0] for s in strategies), **kwargs)
            fn(*args, *(s.endpoints[-1] for s in strategies), **kwargs)
            for _ in range(max(n - 2, 0)):
                fn(*args, *(s.draw(rng) for s in strategies), **kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis_fallback = True
        return wrapper
    return deco


def settings(max_examples: int = 10, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
