"""cimcheck static verification: clean on HEAD + golden seeded violations.

Each pass must (a) report nothing on the repo's real programs and
quantizers, and (b) catch a deliberately-seeded instance of exactly the
bug class it exists for: a `rounding_barrier` stripped from a copy of the
ADC epilogue (the pre-PR-7 pattern), a duplicated noise id in a fused
batch, and an executable cache key that drops the segment flag.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (CimcheckError, Report, Severity, Suppression,
                            barriers, check_program, lint_callable,
                            noise_keys, parse_suppressions, plan_checks,
                            recompile, verify_program)
from repro.core.mapping import LayerSpec
from repro.core.noise_model import NoiseConfig
from repro.core.quantization import (adc_quantize, quantize_act,
                                     quantize_weight, rounding_barrier,
                                     ste_floor)
from repro.runtime.engine import EngineConfig, plan_network
from repro.runtime.program import (EXEC_KEY_FIELDS, NOISE_ID_STRIDE,
                                   compile_program, executable_key,
                                   request_noise_ids)

_X = jnp.ones((8,), jnp.float32)
_G = jnp.full((8,), 1.5, jnp.float32)
_B = jnp.zeros((8,), jnp.float32)


def _dense_program(r_in=4, r_w=2, **cfg_kw):
    specs = [LayerSpec(m=8, k=64, n=32, r_in=r_in, r_w=r_w)]
    return compile_program(specs, EngineConfig(**cfg_kw))


# ---------------------------------------------------------------------------
# barrier lint
# ---------------------------------------------------------------------------

def test_barrier_lint_clean_on_real_quantizers():
    w = jnp.ones((8, 4), jnp.float32)
    assert lint_callable(
        lambda dp, g, b: adc_quantize(dp, r_out=8, gain=g, beta_codes=b),
        _X, _G, _B).codes() == []
    assert lint_callable(lambda v: quantize_act(v, 4), _X).codes() == []
    assert lint_callable(lambda v: quantize_weight(v, 2), w).codes() == []


def test_barrier_lint_fires_on_stripped_barrier():
    """Seeded violation: the ADC epilogue with its barrier stripped (the
    exact pre-PR-7 pattern) must produce NB001."""
    def bad_epilogue(dp, gain, beta):
        mid = 2.0 ** 7
        return jnp.floor(mid + gain * dp + beta)      # no rounding_barrier

    codes = lint_callable(bad_epilogue, _X, _G, _B).codes()
    assert codes == ["NB001"]

    def good_epilogue(dp, gain, beta):
        mid = 2.0 ** 7
        return jnp.floor(mid + rounding_barrier(gain * dp) + beta)

    assert lint_callable(good_epilogue, _X, _G, _B).codes() == []


def test_barrier_lint_fires_on_constant_divide():
    """NB002: div by a trace-time non-pow2 constant on a rounding path."""
    codes = lint_callable(lambda v: jnp.round(v / 255.0), _X).codes()
    assert codes == ["NB002"]
    # powers of two divide exactly: no finding
    assert lint_callable(lambda v: jnp.round(v / 256.0), _X).codes() == []
    # traced divisors are an FMA boundary, not the reciprocal bug class
    assert lint_callable(
        lambda v, s: jnp.round(v / s), _X, jnp.float32(3.0)).codes() == []


def test_barrier_lint_descends_into_ste_floor():
    """The sink lives inside ste_floor's custom_jvp scope; the caller's
    unbarriered product must still be reached."""
    codes = lint_callable(lambda dp, g: ste_floor(g * dp + 8.0),
                          _X, _G).codes()
    assert codes == ["NB001"]
    assert lint_callable(
        lambda dp, g: ste_floor(rounding_barrier(g * dp) + 8.0),
        _X, _G).codes() == []


def test_barrier_lint_through_jit_boundary():
    bad = jax.jit(lambda dp, g: jnp.floor(g * dp))
    assert lint_callable(bad, _X, _G).codes() == ["NB001"]


def test_hlo_cross_check_flags_reciprocal_rewrite():
    """NB101: XLA's divide->reciprocal-multiply rewrite is visible in the
    scheduled module's op_name metadata when it lands on a floor path."""
    text = jax.jit(lambda x: jnp.floor(x / 3.0)).lower(_X) \
        .compile().as_text()
    assert [f.code for f in barriers.lint_hlo_text(text)] == ["NB101"]
    # a divide *after* the floor (the dequantize path) must not fire
    text2 = jax.jit(lambda x: jnp.floor(x * 2.0) / 3.0).lower(_X) \
        .compile().as_text()
    assert barriers.lint_hlo_text(text2) == []


# ---------------------------------------------------------------------------
# noise-key injectivity
# ---------------------------------------------------------------------------

def _plan():
    return plan_network([LayerSpec(m=8, k=64, n=32, r_in=4, r_w=2)],
                        EngineConfig())


def test_noise_chains_clean_on_plan():
    plan = _plan()
    assert noise_keys.check_injectivity(plan, 8) == []
    chains = noise_keys.enumerate_fold_tuples(plan, 300)
    # 300 rows span 3 NOISE_ROW_BLOCK blocks per (layer, row, col) tile
    assert len(chains) == len(set(chains))
    assert any(len(c) == 2 for c in chains)       # residue draws
    assert any(c[-1] == 2 for c in chains if len(c) == 5)


def test_duplicate_noise_id_detected():
    """Seeded violation: one noise id appears twice in a fused batch."""
    plan = _plan()
    findings = noise_keys.check_injectivity(
        plan, 4, noise_ids=[100, 101, 100, 102])
    assert {f.code for f in findings} == {"NK001", "NK002"}
    # unique ids are clean
    assert noise_keys.check_injectivity(
        plan, 4, noise_ids=[100, 101, 102, 103]) == []
    # identical ids with distinct sub-counters (conv im2col rows) are fine
    assert noise_keys.check_noise_ids([7, 7], row_sub=[0, 1]) == []


def test_request_range_overlap_and_overflow():
    ok = noise_keys.check_request_ranges([(0, 64), (1, 64), (2046, 64)])
    assert ok == []
    codes = [f.code for f in noise_keys.check_request_ranges(
        [(2048, 4)])]
    assert codes == ["NK004"]                     # int32 wrap class
    over = noise_keys.check_request_ranges([(0, NOISE_ID_STRIDE + 1)])
    assert "NK003" in [f.code for f in over]      # bleeds into request 1


def test_request_noise_ids_validates_int32():
    """Satellite fix: request_index >= 2048 used to wrap int32 silently."""
    ids = request_noise_ids(2047, 4)
    assert int(ids[0]) == 2047 * NOISE_ID_STRIDE
    assert ids.dtype == jnp.int32
    with pytest.raises(ValueError, match="overflows int32"):
        request_noise_ids(2048, 1)
    with pytest.raises(ValueError):
        request_noise_ids(-1, 4)
    with pytest.raises(ValueError):
        request_noise_ids(0, 0)
    # the range end is checked, not just the base
    with pytest.raises(ValueError, match="overflows int32"):
        request_noise_ids(2047, NOISE_ID_STRIDE + 1)


def test_scheduler_limit_warnings():
    f = noise_keys.check_scheduler_limits(max_requests=4096,
                                          max_calls_per_request=8)
    assert [x.code for x in f] == ["NK005"]
    assert all(x.severity == Severity.WARNING for x in f)
    assert noise_keys.check_scheduler_limits(
        max_requests=2048, max_calls_per_request=64) == []


# ---------------------------------------------------------------------------
# recompile hazards
# ---------------------------------------------------------------------------

def test_reachable_key_set_bounded():
    prog = _dense_program()
    rep = recompile.run(prog, max_m=1024)
    assert rep.findings == []
    keys = recompile.reachable_keys(prog.buckets, 1024, devices=1,
                                    noise_enabled=False)
    ladder = prog.buckets.ladder(1024)
    assert len(keys) == 8 * len(ladder)       # 2^3 flag combos per rung
    # a precision ladder multiplies the key set by its rung count, and
    # the default budget still covers a 4-point noise-enabled ladder
    keys3 = recompile.reachable_keys(
        prog.buckets, 1024, devices=1, noise_enabled=False,
        points=("", "quality", "throughput"))
    assert len(keys3) == 3 * len(keys)
    assert recompile.check_key_budget(
        prog.buckets, 1024, devices=1, noise_enabled=True,
        points=("", "quality", "balanced", "throughput")) == []


def test_weak_cache_key_detected():
    """Seeded violation: a key function that drops the segment flag."""
    def weak_key(kind, extent, *, noise, keyed, devices, bound,
                 reference, segmented, identity, point=""):
        # 'segmented' intentionally ignored
        return (kind, extent, noise, keyed, devices, bound, reference,
                identity, point)

    findings = recompile.check_key_sensitivity(weak_key)
    assert [f.code for f in findings] == ["RC002"]
    assert "segmented" in findings[0].message


def test_real_executable_key_is_sensitive():
    assert recompile.check_key_sensitivity() == []
    # and every declared field has a probe
    assert set(EXEC_KEY_FIELDS) <= set(recompile._FIELD_PROBES)


def test_executable_key_shape():
    k = executable_key("bucket", 8, noise=False, keyed=False, devices=1,
                       bound=True, reference=False, segmented=True,
                       identity=False)
    assert len(k) == len(EXEC_KEY_FIELDS)
    assert k[0] == "bucket" and k[1] == 8


# ---------------------------------------------------------------------------
# plan validator
# ---------------------------------------------------------------------------

def test_plan_validator_clean_on_head():
    assert plan_checks.check_plan(_plan()) == []


def test_plan_validator_flags_bad_precision():
    plan = _plan()
    lp = plan.layers[0]
    bad = dataclasses.replace(lp, spec=dataclasses.replace(lp.spec, r_in=11))
    f = plan_checks.check_layer(bad, plan.cfg.macro, 0)
    assert "PV001" in [x.code for x in f]
    bad_w = dataclasses.replace(lp, spec=dataclasses.replace(lp.spec, r_w=3))
    f = plan_checks.check_layer(bad_w, plan.cfg.macro, 0)
    assert "PV002" in [x.code for x in f]


def test_plan_validator_flags_bad_tiles():
    plan = _plan()
    lp = plan.layers[0]
    macro = plan.cfg.macro
    # row tiles with a gap
    bad = dataclasses.replace(lp, k_slices=((0, 32), (40, 24)))
    assert "PV003" in [x.code for x in
                       plan_checks.check_layer(bad, macro, 0)]
    # a row tile beyond the macro's 1152 physical rows
    big = dataclasses.replace(
        lp, spec=dataclasses.replace(lp.spec, k=2000),
        k_slices=((0, 2000),))
    assert "PV004" in [x.code for x in
                       plan_checks.check_layer(big, macro, 0)]


# ---------------------------------------------------------------------------
# integration: check_program / verify / suppressions
# ---------------------------------------------------------------------------

def test_check_program_clean_on_head_dense():
    rep = check_program(_dense_program())
    assert rep.findings == []
    assert rep.ok()


def test_check_program_clean_on_head_noise():
    rep = check_program(_dense_program(noise=NoiseConfig(enabled=True)))
    assert rep.findings == []


def test_compile_program_verify_strict():
    specs = [LayerSpec(m=8, k=32, n=16, r_in=2, r_w=1)]
    prog = compile_program(specs, EngineConfig(), verify="strict")
    assert prog is not None
    with pytest.raises(ValueError, match="unknown cimcheck mode"):
        Report().raise_if("bogus")


def test_verify_strict_raises_on_errors():
    prog = _dense_program()
    rep = check_program(prog, key_budget=1)       # force an RC001 error
    assert not rep.ok()
    with pytest.raises(CimcheckError) as ei:
        rep.raise_if("strict")
    assert "RC001" in str(ei.value)
    with pytest.raises(CimcheckError):
        verify_program(prog, "strict", key_budget=1)


def test_suppressions_waive_findings():
    prog = _dense_program()
    sups = parse_suppressions(["recompile/RC001:known ladder size"])
    rep = check_program(prog, key_budget=1, suppressions=sups)
    assert rep.ok()
    assert [f.code for f in rep.suppressed] == ["RC001"]
    assert sups[0].reason == "known ladder size"
    assert Suppression("recompile", "*").matches(rep.suppressed[0])


def test_report_json_roundtrip():
    import json
    rep = check_program(_dense_program())
    payload = json.loads(rep.to_json())
    assert payload["ok"] is True
    assert payload["findings"] == []
