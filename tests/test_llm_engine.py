"""Engine-mode LLM projections: bit-exactness vs fakequant (ISSUE 7).

The acceptance bar: a transformer decoder stack and a `moe_block` with
`CIMConfig(mode="engine")` must be *bitwise* equal to the fakequant
training reference across the precision grid r_in {1,2,4,8} x r_w {1,2,4}
— jit against jit, including capacity-dropped tokens — and, under one
fixed noise key, the engine's Pallas kernel path must be bitwise equal to
its interpret-mode oracle and fully deterministic, unsharded and on a
4-device fake mesh.  Program-cache economics ride along: E experts share
ONE compiled program (>= E-fold serve reuse in `CIMProgram.stats()`).

Multi-device cases need fake CPU devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_llm_engine.py
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cim_layers import CIMConfig, _engine_config
from repro.core.noise_model import NoiseConfig
from repro.core import mapping
from repro.models import transformer as tf
from repro.models.moe import init_moe, moe_block
from repro.runtime import engine as rt
from repro.runtime.program import DEFAULT_BUCKETS, compile_program

N_DEV = len(jax.devices())
GRID = [(r_in, r_w) for r_in in (1, 2, 4, 8) for r_w in (1, 2, 4)]
NOISE_KEY = jax.random.PRNGKey(321)


def _need(devices: int) -> None:
    if N_DEV < devices:
        pytest.skip(f"needs {devices} devices, jax reports {N_DEV} (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _moe_pair(r_in, r_w, *, cf=1.25, noise=None, sharding=None):
    """(params, x, fakequant cim, engine cim) for a small expert bank."""
    cim = CIMConfig(mode="fakequant", r_in=r_in, r_w=r_w)
    if noise is not None:
        cim = cim.replace(noise=noise)
    if sharding is not None:
        cim = cim.replace(sharding=sharding)
    params = init_moe(jax.random.PRNGKey(5), 16, 48, 4)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 8, 16), jnp.float32)
    return params, x, cim, cim.replace(mode="engine")


def _moe_out(params, x, cim, *, key=None, reference=False):
    fn = jax.jit(functools.partial(
        moe_block, n_experts=4, top_k=2, capacity_factor=1.25,
        cim=cim, reference=reference))
    out, _ = fn(params, x, key=key) if key is not None else fn(params, x)
    return np.asarray(out)


@pytest.mark.parametrize("r_in,r_w", GRID)
def test_moe_block_engine_bitexact_vs_fakequant(r_in, r_w):
    """The headline bugfix: engine mode runs the SAME quantized arithmetic
    as fakequant (no silent float fallback) — bitwise, jit vs jit."""
    params, x, cf_cim, en_cim = _moe_pair(r_in, r_w)
    a = _moe_out(params, x, cf_cim)
    b = _moe_out(params, x, en_cim)
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("r_in,r_w", [(8, 4), (2, 1)])
def test_moe_block_engine_parity_with_capacity_drops(r_in, r_w):
    """Tokens dropped at the capacity limit drop identically in both
    modes (the capacity grid is digital glue shared by both paths)."""
    params, x, cf_cim, en_cim = _moe_pair(r_in, r_w)
    run = functools.partial(moe_block, n_experts=4, top_k=2, cim=cf_cim,
                            capacity_factor=0.4)   # forces drops
    a, _ = jax.jit(run)(params, x)
    b, _ = jax.jit(functools.partial(
        moe_block, n_experts=4, top_k=2, cim=en_cim,
        capacity_factor=0.4))(params, x)
    assert bool(jnp.all(jnp.isfinite(a)))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_unknown_cim_mode_raises():
    """The regression the issue names: an unsupported mode must raise,
    never silently serve unquantized float."""
    params, x, cim, _ = _moe_pair(4, 2)
    with pytest.raises(ValueError, match="does not support CIM mode"):
        moe_block(params, x, n_experts=4, top_k=2, capacity_factor=1.25,
                  cim=cim.replace(mode="sim"))


def test_moe_engine_program_reuse_is_expertfold():
    """E experts route through ONE cached program per GEMM shape: after a
    moe_block call, the (d->f) program has >= 2E serve calls (gate+up
    banks) and the (f->d) program >= E — the plan-once/serve-many
    contract of CIMProgram.stats()."""
    params, x, _, en_cim = _moe_pair(4, 2)
    e, d, f = 4, 16, 48
    t = x.shape[0] * x.shape[1]
    cap = max(8, min(int(1.25 * 2 * t / e + 0.5), t * 2))
    spec_up = mapping.LayerSpec(m=DEFAULT_BUCKETS.bucket_for(cap), k=d, n=f,
                                r_in=4, r_w=2, r_out=en_cim.r_out)
    spec_dn = mapping.LayerSpec(m=DEFAULT_BUCKETS.bucket_for(cap), k=f, n=d,
                                r_in=4, r_w=2, r_out=en_cim.r_out)
    prog_up = compile_program([spec_up], _engine_config(en_cim))
    prog_dn = compile_program([spec_dn], _engine_config(en_cim))
    up0 = prog_up.stats()["serve_calls"]
    dn0 = prog_dn.stats()["serve_calls"]
    moe_block(params, x, n_experts=e, top_k=2, capacity_factor=1.25,
              cim=en_cim)
    # gate and up share the (d->f) spec: one program, 2E binds served
    assert prog_up.stats()["serve_calls"] - up0 >= 2 * e
    assert prog_dn.stats()["serve_calls"] - dn0 >= e
    assert prog_up.stats()["plans_built"] == 1


@pytest.mark.parametrize("r_in,r_w", GRID)
def test_olmo_decoder_stack_engine_bitexact_vs_fakequant(r_in, r_w):
    """Full dense decoder stack (QKV/O + gated MLP through compiled
    programs, attention digital): engine == fakequant bitwise at every
    grid point, jit vs jit."""
    base = get_smoke_config("olmo-1b").replace(dtype="float32")
    cfq = base.replace(cim=base.cim.replace(
        mode="fakequant", r_in=r_in, r_w=r_w))
    cen = base.replace(cim=base.cim.replace(
        mode="engine", r_in=r_in, r_w=r_w))
    params = tf.init_params(cfq, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                base.vocab_size)
    a = jax.jit(lambda p, t: tf.forward(cfq, p, t)[0])(params, tokens)
    b = jax.jit(lambda p, t: tf.forward(cen, p, t)[0])(params, tokens)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("r_in,r_w", [(8, 4), (1, 2)])
def test_phi35_moe_stack_engine_bitexact_vs_fakequant(r_in, r_w):
    """The MoE decoder stack end to end: router + capacity grouping +
    per-expert programs match fakequant bitwise."""
    base = get_smoke_config("phi3.5-moe-42b-a6.6b").replace(dtype="float32")
    cfq = base.replace(cim=base.cim.replace(
        mode="fakequant", r_in=r_in, r_w=r_w))
    cen = base.replace(cim=base.cim.replace(
        mode="engine", r_in=r_in, r_w=r_w))
    params = tf.init_params(cfq, jax.random.PRNGKey(2))
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                base.vocab_size)
    a = jax.jit(lambda p, t: tf.forward(cfq, p, t)[0])(params, tokens)
    b = jax.jit(lambda p, t: tf.forward(cen, p, t)[0])(params, tokens)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---- fixed noise key -------------------------------------------------------

def _noise_case(devices: int):
    sh = rt.ShardingConfig(devices=devices) if devices else None
    return _moe_pair(4, 2, noise=NoiseConfig(), sharding=sh)


@pytest.mark.parametrize("devices", [0, 4])
def test_moe_engine_noise_kernel_matches_reference(devices):
    """Under one fixed noise key the engine's Pallas kernel path equals
    its interpret-mode oracle bitwise, and the draws are deterministic —
    unsharded and across the 4-macro fake mesh."""
    if devices:
        _need(devices)
    params, x, _, en_cim = _noise_case(devices)
    a = _moe_out(params, x, en_cim, key=NOISE_KEY)
    b = _moe_out(params, x, en_cim, key=NOISE_KEY, reference=True)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, _moe_out(params, x, en_cim,
                                              key=NOISE_KEY))
    other = _moe_out(params, x, en_cim, key=jax.random.PRNGKey(77))
    assert np.any(a != other), "noise key had no effect"


def test_olmo_engine_noise_deterministic():
    """Noise-keyed engine decode on the dense stack: same key -> bitwise
    identical logits; different key -> different logits."""
    base = get_smoke_config("olmo-1b").replace(dtype="float32")
    cen = base.replace(cim=base.cim.replace(
        mode="engine", r_in=4, r_w=2, noise=NoiseConfig()))
    params = tf.init_params(cen, jax.random.PRNGKey(4))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (1, 8), 0,
                                base.vocab_size)
    f = jax.jit(lambda p, t, k: tf.forward(cen, p, t, key=k)[0])
    a = f(params, tokens, NOISE_KEY)
    np.testing.assert_array_equal(np.asarray(a),
                                  np.asarray(f(params, tokens, NOISE_KEY)))
    assert np.any(np.asarray(a)
                  != np.asarray(f(params, tokens, jax.random.PRNGKey(9))))
