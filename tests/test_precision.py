"""Workload-adaptive precision serving (ISSUE 10): sensitivity
calibration, the accuracy-budget planner, and the compiled ladder.

Pins the subsystem's contracts: the base point measures exactly zero
delta (the calibration is self-consistent), profiles round-trip through
the versioned on-disk cache (corrupt files degrade with one warning,
never an error), greedy assignments nest monotonically across budgets,
every compiled operating point stays bit-exact against the digital
reference, and the program-cache LRU bound evicts without breaking
already-held programs.
"""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.core.mapping import LayerSpec
from repro.precision import (DEFAULT_BUDGETS, PRECISION_CHAIN,
                             SensitivityProfile, assign, calibrate,
                             plan_ladder)
from repro.precision.sensitivity import (BASE_POINT, CALIBRATION_RUNS,
                                         LayerSensitivity,
                                         ProfileCacheWarning)
from repro.runtime import engine as rt
from repro.runtime.program import (executable_key, program_cache_stats,
                                   set_program_cache_capacity)

# a tiny chained net and a reduced sweep keep calibration to a handful of
# compiled programs per test
SPECS = (LayerSpec(m=4, k=32, n=16, r_in=8, r_w=4),
         LayerSpec(m=4, k=16, n=8, r_in=8, r_w=4))
POINTS = ((1, 1), (2, 2))          # base (8, 4) is appended by calibrate
CFG = rt.EngineConfig()


def _calibrate(**kw):
    kw.setdefault("points", POINTS)
    kw.setdefault("n_trials", 1)
    kw.setdefault("batch", 4)
    kw.setdefault("cache_path", "")
    return calibrate(SPECS, CFG, **kw)


# ---- sensitivity profiles --------------------------------------------------

def test_profile_base_zero_and_bounds():
    """The base point is its own reference: exactly zero logit-MSE delta
    and full top-1 agreement; every swept delta is finite and >= 0."""
    prof = _calibrate()
    assert prof.points[-1] == BASE_POINT
    for i in range(len(SPECS)):
        assert prof.delta(i, BASE_POINT) == 0.0
        assert prof.agreement(i, BASE_POINT) == 1.0
        for p in prof.points:
            assert prof.delta(i, p) >= 0.0
    assert prof.max_total_delta() == sum(
        prof.delta(i, prof.points[0]) for i in range(len(SPECS)))
    with pytest.raises(ValueError, match="not calibrated"):
        prof.delta(0, (3, 3))


def test_profile_cache_roundtrip(tmp_path):
    """Identical calibrations hit the on-disk cache: one measured run,
    byte-identical profile on re-load."""
    path = str(tmp_path / "profiles.json")
    n0 = CALIBRATION_RUNS["n"]
    prof = _calibrate(cache_path=path, label="roundtrip")
    assert CALIBRATION_RUNS["n"] == n0 + 1
    again = _calibrate(cache_path=path, label="roundtrip")
    assert CALIBRATION_RUNS["n"] == n0 + 1, "cache hit must not re-run"
    assert again.to_dict() == prof.to_dict()
    # a different label is a different key -> fresh calibration
    _calibrate(cache_path=path, label="other")
    assert CALIBRATION_RUNS["n"] == n0 + 2


def test_profile_cache_corrupt_degrades(tmp_path):
    """A corrupt cache file warns once, re-calibrates, and refuses to
    write — the bad file neither crashes the call nor grows."""
    path = tmp_path / "profiles.json"
    path.write_text("{not json", encoding="utf-8")
    n0 = CALIBRATION_RUNS["n"]
    with pytest.warns(ProfileCacheWarning):
        prof = _calibrate(cache_path=str(path), label="corrupt")
    assert CALIBRATION_RUNS["n"] == n0 + 1
    assert prof.layers and prof.delta(0, BASE_POINT) == 0.0
    assert path.read_text(encoding="utf-8") == "{not json"
    # schema mismatch degrades the same way
    path.write_text(json.dumps({"schema": -1, "entries": {}}),
                    encoding="utf-8")
    with pytest.warns(ProfileCacheWarning):
        _calibrate(cache_path=str(path), label="corrupt")
    assert CALIBRATION_RUNS["n"] == n0 + 2


# ---- the budget planner ----------------------------------------------------

def _fake_profile():
    # hand-built deltas: layer 0 is twice as sensitive as layer 1
    points = ((1, 1), (2, 2), (8, 4))
    return SensitivityProfile(
        base=(8, 4), points=points, n_trials=1, chained=True,
        layers=(LayerSensitivity(0, ((1, 1, 8.0, 0.5), (2, 2, 2.0, 0.9),
                                     (8, 4, 0.0, 1.0))),
                LayerSensitivity(1, ((1, 1, 4.0, 0.6), (2, 2, 1.0, 0.95),
                                     (8, 4, 0.0, 1.0)))))


def test_assign_budget_extremes():
    prof = _fake_profile()
    all_base, d0 = assign(prof, SPECS, 0.0)
    assert all_base == ((8, 4), (8, 4)) and d0 == 0.0
    cheapest, d1 = assign(prof, SPECS, 1.0)
    assert cheapest == ((1, 1), (1, 1))
    assert d1 == pytest.approx(prof.max_total_delta())
    with pytest.raises(ValueError, match=">= 0"):
        assign(prof, SPECS, -0.1)
    with pytest.raises(ValueError, match="covers 2 layers"):
        assign(prof, SPECS[:1], 0.5)


def test_assign_nests_across_budgets():
    """Stricter budgets only ever upgrade: for f1 <= f2, every layer's
    point under f1 sits at or above its point under f2 on the chain
    (the trajectory is budget-independent; only the stop moves)."""
    prof = _fake_profile()
    rank = {p: i for i, p in enumerate(prof.points)}
    prev = None
    for frac in (1.0, 0.5, 0.25, 0.1, 0.0):
        asg, delta = assign(prof, SPECS, frac)
        assert delta <= frac * prof.max_total_delta() + 1e-12
        if prev is not None:
            for a, b in zip(asg, prev):
                assert rank[a] >= rank[b], (frac, asg, prev)
        prev = asg


# ---- the compiled ladder ---------------------------------------------------

def test_plan_ladder_points_and_bit_exactness():
    """Every named point compiles, orders strictest-first, projects
    monotone efficiency, and serves bit-exactly against the digital
    reference."""
    prof = _calibrate()
    ladder = plan_ladder(prof, SPECS, CFG)
    assert ladder.names() == tuple(DEFAULT_BUDGETS)
    rep = ladder.report()
    assert (rep["throughput"]["tops_per_w"]
            >= rep["quality"]["tops_per_w"])
    for name in ladder.names():
        op = ladder.point(name)
        assert op.predicted_delta <= op.allowance + 1e-12 or \
            op.assignment == (BASE_POINT,) * len(SPECS)
        prog = ladder.program(name)
        params = prog.init_params(jax.random.PRNGKey(3))
        x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(4),
                                          (4, SPECS[0].k))) + 0.1
        out = prog.serve(params, x, point=name)
        ref = prog.serve(params, x, reference=True, point=name)
        assert bool(jnp.all(out == ref)), name
    with pytest.raises(ValueError, match="unknown operating point"):
        ladder.point("nope")


def test_ladder_specs_follow_assignment():
    prof = _calibrate()
    ladder = plan_ladder(prof, SPECS, CFG)
    for name in ladder.names():
        op = ladder.point(name)
        for spec, (ri, rw) in zip(ladder.specs_for(name), op.assignment):
            assert (spec.r_in, spec.r_w) == (ri, rw)
            assert (ri, rw) in PRECISION_CHAIN


# ---- program-cache bounds (ISSUE 10 satellite) -----------------------------

def test_executable_key_point_axis():
    base = dict(noise=False, keyed=False, devices=1, bound=True,
                reference=False, segmented=True, identity=True)
    k0 = executable_key("bucket", 4, **base)
    k1 = executable_key("bucket", 4, point="throughput", **base)
    assert k0 != k1
    assert k1 == executable_key("bucket", 4, point="throughput", **base)


def test_program_cache_lru_eviction():
    """Shrinking the LRU capacity evicts immediately (counted in stats),
    and an evicted program keeps serving wherever it is still held —
    eviction only means an equal future compile re-plans."""
    from repro.runtime.program import compile_program
    cap0 = set_program_cache_capacity(2)
    try:
        progs = [compile_program(
            (LayerSpec(m=2, k=16, n=8 + 8 * i, r_in=2, r_w=1),), CFG)
            for i in range(4)]
        stats = program_cache_stats()
        assert stats["capacity"] == 2
        assert stats["programs"] <= 2
        assert stats["evictions"] >= 2
        # the first (evicted) program still works
        p = progs[0]
        params = p.init_params(jax.random.PRNGKey(0))
        x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (2, 16)))
        assert bool(jnp.all(p.serve(params, x)
                            == p.serve(params, x, reference=True)))
    finally:
        set_program_cache_capacity(cap0)
