"""Sharding-spec rules: FSDP+TP coverage and divisibility validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import specs
from repro.launch.mesh import make_production_mesh


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 256:
        pytest.skip("needs the 512-placeholder-device dryrun environment")
    return make_production_mesh()


def test_validate_filters_missing_axes():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    out = specs._validate(P(("pod", "data"), "model"), (64, 32), FakeMesh())
    assert out == P("data", "model")


def test_validate_drops_indivisible():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    # 51865 is not divisible by 16 -> axis dropped
    out = specs._validate(P("model", None), (51865, 8), FakeMesh())
    assert out == P(None, None)
    # partial tuple: 32 % (16*16) != 0 but 32 % 16 == 0 -> keep prefix
    out = specs._validate(P(("pod", "data"),), (32,), FakeMesh())
    assert out == P("data")


def test_rules_cover_big_leaves():
    """Every >=1M-element weight leaf must get a non-trivial spec (FSDP or
    TP) — replicated big leaves are exactly the OOM bug of §Perf/P0."""
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    from repro.configs import get_config
    from repro.models import transformer as tf
    cfg = get_config("mixtral_8x22b")
    params = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    spec_tree = specs.param_specs(params, FakeMesh())
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    spec_flat = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), sp in zip(flat, spec_flat):
        # >=100M elements (~0.4 GB fp32) replicated => OOM at scale
        if np.prod(leaf.shape) >= 1e8:
            assert any(e is not None for e in sp), \
                f"big leaf replicated: {path} {leaf.shape}"
