"""Property tests: STE quantizers and ABN hardware grids."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:               # degrade to the deterministic stub
    from hypofallback import given, settings, st

from repro.core import abn as abn_lib
from repro.core.hw import DEFAULT_MACRO
from repro.core.quantization import quantize_act, quantize_weight, ste_round


@given(st.integers(1, 8), st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_act_quant_bounds(r_in, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 3, size=(64,)), jnp.float32)
    aq = quantize_act(x, r_in)
    q = np.asarray(aq.q)
    assert q.min() >= 0 and q.max() <= 2**r_in - 1
    assert np.all(q == np.round(q))
    # reconstruction error bounded by one step
    recon = q * float(aq.scale) + float(aq.zero)
    assert np.max(np.abs(recon - np.asarray(x))) <= float(aq.scale) * 0.5 + 1e-6


@given(st.integers(1, 4), st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_weight_quant_odd_grid(r_w, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1, size=(32, 8)), jnp.float32)
    wq = quantize_weight(w, r_w)
    q = np.asarray(wq.q)
    full = 2**r_w - 1
    assert np.all(np.abs(q) <= full)
    assert np.all(np.abs(q % 2) == 1)          # odd grid
    # per-channel scale reconstructs amax within one step
    recon = q * np.asarray(wq.scale)
    assert np.max(np.abs(recon - np.asarray(w))) <= np.max(np.asarray(wq.scale)) + 1e-6


def test_ste_gradient_passthrough():
    # d/dx sum(round(x)^2) under STE = 2*round(x) * 1
    g = jax.grad(lambda x: jnp.sum(ste_round(x) ** 2))(jnp.array([1.3, -0.7]))
    np.testing.assert_allclose(np.asarray(g), [2.0, -2.0], rtol=1e-6)


def test_gamma_pow2_grid():
    g = abn_lib.quantize_gamma_pow2(jnp.array([1.4, 3.1, 20.0, 100.0]))
    np.testing.assert_array_equal(np.asarray(g), [1.0, 4.0, 16.0, 32.0])


def test_gamma_bits_levels():
    gs = abn_lib.quantize_gamma_bits(jnp.linspace(1, 32, 100), 2)
    assert len(np.unique(np.asarray(gs))) <= 4


def test_beta_quant_grid():
    cfg = DEFAULT_MACRO
    b = abn_lib.quantize_beta_v(jnp.array([0.0, 0.01, 0.029, 0.5, -0.5]))
    assert float(jnp.max(b)) <= cfg.abn_offset_range_v + 1e-9
    assert float(jnp.min(b)) >= -cfg.abn_offset_range_v - 1e-9


def test_fold_batchnorm():
    key = jax.random.PRNGKey(0)
    y = jax.random.normal(key, (128, 4)) * 3 + 1
    mean, var = jnp.mean(y, 0), jnp.var(y, 0)
    scale, bias = jnp.array([2., 1., .5, 1.]), jnp.array([0., 1., -1., 2.])
    gamma, beta = abn_lib.fold_batchnorm(scale, bias, mean, var)
    want = scale * (y - mean) / jnp.sqrt(var + 1e-5) + bias
    got = gamma * y + beta
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_distribution_aware_init_centers():
    key = jax.random.PRNGKey(1)
    dp = jax.random.normal(key, (512, 8)) * 5 + 40.0
    p = abn_lib.distribution_aware_init(dp, r_out=8)
    gamma = 2.0 ** p.log_gamma
    reshaped = gamma[None, :] * np.asarray(dp) + np.asarray(p.beta)[None, :]
    assert np.abs(reshaped.mean()) < 3.0          # centred near mid 0
    assert 16 < reshaped.std() < 48               # fills ~quarter range
