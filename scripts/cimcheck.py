#!/usr/bin/env python
"""cimcheck CLI: sweep the model zoo through plan-time static verification.

Compiles every zoo workload (LeNet conv chain, the olmo-1b and
phi3.5-moe projection GEMMs) across the precision grid and runs every
`repro.analysis` pass over the resulting programs: numerics-barrier lint,
noise-key injectivity, recompile-hazard budget, plan validation.  A
noise-enabled LeNet point, (when more than one device is visible) a
sharded LeNet point, and a mixed-precision-per-layer ladder point (the
program shape the repro.precision planner emits, recompile-budgeted
across the full operating-point tag set) ride along, plus an optional
scheduled-HLO cross-check on a small dense probe.

Exit status: nonzero under --strict when any ERROR finding survives the
suppressions.  --json writes the machine-readable findings (the CI
artifact).

Usage:
  PYTHONPATH=src python scripts/cimcheck.py --strict --json findings.json
  PYTHONPATH=src python scripts/cimcheck.py --arch lenet --r-in 4 --r-w 2
  PYTHONPATH=src python scripts/cimcheck.py --suppress 'recompile/RC001'
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List, Tuple

import jax

from repro.analysis import (Report, check_program, lint_hlo_text,
                            parse_suppressions)
from repro.core import mapping
from repro.core.noise_model import NoiseConfig
from repro.runtime.engine import EngineConfig, ShardingConfig
from repro.runtime.program import compile_program

R_IN_GRID = (1, 2, 4, 8)
R_W_GRID = (1, 2, 4)
ARCHS = ("lenet", "olmo-1b", "phi3.5-moe-42b-a6.6b")

# the operating-point tags a full precision ladder serves under: RC001
# budgets the executable-key set they multiply into (recompile pass)
LADDER_POINTS = ("", "quality", "balanced", "throughput")


def _llm_specs(arch: str, r_in: int, r_w: int, m: int = 8
               ) -> List[mapping.LayerSpec]:
    """The decoder projection GEMMs of a zoo LLM as independent specs."""
    from repro.configs import get_smoke_config
    c = get_smoke_config(arch)
    hd = c.resolved_head_dim
    qkv_n = (c.n_heads + 2 * c.n_kv_heads) * hd
    shapes = [(c.d_model, qkv_n),            # fused QKV
              (c.n_heads * hd, c.d_model),   # O
              (c.d_model, 2 * c.d_ff),       # fused gate_up
              (c.d_ff, c.d_model)]           # down
    return [mapping.LayerSpec(m=m, k=k, n=n, r_in=r_in, r_w=r_w)
            for k, n in shapes]


def _programs_for(arch: str, r_in: int, r_w: int):
    """(label, program) list for one (arch, precision) sweep point."""
    out = []
    if arch == "lenet":
        from repro.models.cnn import lenet_engine_specs
        from repro.core.cim_layers import CIMConfig, _engine_config
        cim = CIMConfig(r_in=r_in, r_w=r_w)
        specs, acts, pools = lenet_engine_specs(8, cim=cim)
        cfg = _engine_config(cim)
        out.append(("lenet", compile_program(
            specs, cfg, activations=acts, pools=pools)))
    else:
        # the LLM projections are independent single-layer programs
        # (exactly how models/transformer dispatches them); check them as
        # one multi-spec plan per layer to keep the sweep bounded
        for i, spec in enumerate(_llm_specs(arch, r_in, r_w)):
            name = ("qkv", "o", "gate_up", "down")[i]
            out.append((f"{arch}/{name}",
                        compile_program([spec], EngineConfig())))
    return out


def _extra_points() -> List[Tuple[str, object, Tuple[str, ...]]]:
    """Noise-enabled, sharded, and mixed-precision-ladder points.

    Each entry is (label, program, points): `points` is the serving
    operating-point tag set the recompile pass budgets the program's
    executable keys against (("",) except for the ladder point, which
    sweeps the full `LADDER_POINTS` key multiplication)."""
    from repro.models.cnn import lenet_engine_specs
    out = []
    specs, acts, pools = lenet_engine_specs(8)
    out.append(("lenet+noise", compile_program(
        specs, EngineConfig(noise=NoiseConfig(enabled=True)),
        activations=acts, pools=pools), ("",)))
    if jax.device_count() > 1:
        out.append((f"lenet+shard{jax.device_count()}", compile_program(
            specs, EngineConfig(sharding=ShardingConfig(devices=0)),
            activations=acts, pools=pools), ("",)))
    # a mixed-precision-per-layer chain — the shape of program the
    # accuracy-budget planner (repro.precision) emits for a ladder rung:
    # every pass must stay clean per layer, and RC001 must bound the
    # executable keys across the full operating-point tag set
    mixed = [mapping.LayerSpec(m=8, k=256, n=128, r_in=8, r_w=4),
             mapping.LayerSpec(m=8, k=128, n=64, r_in=4, r_w=2),
             mapping.LayerSpec(m=8, k=64, n=32, r_in=2, r_w=2),
             mapping.LayerSpec(m=8, k=32, n=16, r_in=2, r_w=1)]
    out.append(("mixed-ladder", compile_program(
        mixed, EngineConfig(noise=NoiseConfig(enabled=True))),
        LADDER_POINTS))
    return out


def _hlo_cross_check(report: Report) -> None:
    """Compile a dense probe and run the NB101 scheduled-HLO check."""
    import jax.numpy as jnp
    from repro.runtime import engine as rt
    prog = compile_program(
        [mapping.LayerSpec(m=8, k=64, n=32, r_in=4, r_w=2)], EngineConfig())
    plan = prog.plan
    params = rt.init_network_params(plan, jax.random.PRNGKey(0))
    x = jnp.zeros((8, 64), jnp.float32)
    try:
        lowered = rt._exec_jit.lower(plan, list(params), x, None, None,
                                     None, None, None, False, False)
        text = lowered.compile().as_text()
    except Exception as e:          # pragma: no cover - backend specific
        print(f"cimcheck: HLO cross-check skipped ({e})", file=sys.stderr)
        return
    report.extend(lint_hlo_text(text, where_prefix="dense-probe"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on any unsuppressed ERROR finding")
    ap.add_argument("--json", metavar="PATH",
                    help="write machine-readable findings JSON")
    ap.add_argument("--arch", action="append", choices=ARCHS,
                    help="restrict to one or more zoo architectures")
    ap.add_argument("--r-in", type=int, action="append",
                    choices=R_IN_GRID, help="restrict the r_in grid")
    ap.add_argument("--r-w", type=int, action="append",
                    choices=R_W_GRID, help="restrict the r_w grid")
    ap.add_argument("--max-m", type=int, default=1024,
                    help="largest request extent the recompile pass "
                         "budgets for (default 1024)")
    ap.add_argument("--suppress", action="append", default=[],
                    metavar="PASS/CODE[:reason]",
                    help="waive findings (fnmatch on pass id and code)")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip the compiled-HLO cross-check probe")
    args = ap.parse_args(argv)

    sups = parse_suppressions(args.suppress)
    archs = tuple(args.arch) if args.arch else ARCHS
    r_ins = tuple(args.r_in) if args.r_in else R_IN_GRID
    r_ws = tuple(args.r_w) if args.r_w else R_W_GRID

    t0 = time.time()
    merged = Report(suppressions=sups)
    per_config = []
    points = [(arch, r_in, r_w) for arch in archs
              for r_in in r_ins for r_w in r_ws]
    for arch, r_in, r_w in points:
        for label, prog in _programs_for(arch, r_in, r_w):
            rep = check_program(prog, max_m=args.max_m, suppressions=sups)
            merged.merge(rep)
            per_config.append({
                "config": label, "r_in": r_in, "r_w": r_w,
                "findings": [f.to_dict() for f in rep.findings],
            })
            tag = "clean" if rep.ok() and not rep.findings else \
                f"{len(rep.findings)} finding(s)"
            print(f"cimcheck: {label} r_in={r_in} r_w={r_w}: {tag}")
    for label, prog, pts in _extra_points():
        rep = check_program(prog, max_m=args.max_m, suppressions=sups,
                            points=pts)
        merged.merge(rep)
        per_config.append({"config": label, "r_in": None, "r_w": None,
                           "findings": [f.to_dict() for f in rep.findings]})
        print(f"cimcheck: {label}: "
              f"{'clean' if not rep.findings else len(rep.findings)}")
    if not args.no_hlo:
        _hlo_cross_check(merged)

    for f in merged.findings:
        print("cimcheck: " + f.format(), file=sys.stderr)
    ok = merged.ok()
    dt = time.time() - t0
    print(f"cimcheck: {len(points)} grid points, "
          f"{len(merged.findings)} finding(s) "
          f"({len(merged.errors())} errors, "
          f"{len(merged.suppressed)} suppressed) in {dt:.1f}s")
    if args.json:
        payload = {
            "ok": ok,
            "configs": per_config,
            "findings": [f.to_dict() for f in merged.findings],
            "suppressed": [f.to_dict() for f in merged.suppressed],
            "elapsed_s": dt,
            "devices": jax.device_count(),
        }
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2)
        print(f"cimcheck: wrote {args.json}")
    if args.strict and not ok:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
