#!/usr/bin/env python
"""Docs gate for CI (ISSUE 4): fail on broken relative markdown links and on
missing docstrings in the public engine API.

Two checks, both dependency-free (a pydocstyle/interrogate subset — the
container must not pip-install anything):

  * link check: every non-http `[text](target)` in README.md and
    docs/ARCHITECTURE.md must resolve to an existing file relative to the
    markdown file (anchors `#...` are stripped before checking);
  * docstring check: every public module-level function/class — and every
    public method defined on those classes — of the four engine-API
    modules below must carry a non-trivial docstring.

Usage: PYTHONPATH=src python scripts/check_docs.py
"""
from __future__ import annotations

import importlib
import inspect
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MARKDOWN_FILES = ("README.md", "docs/ARCHITECTURE.md")

API_MODULES = (
    "repro.runtime.engine",
    "repro.runtime.program",
    "repro.runtime.scheduler",
    "repro.core.mapping",
    "repro.core.noise_model",
    "repro.core.cim_layers",
    "repro.models.transformer",
    "repro.models.moe",
    "repro.kernels.cim_mbiw.ops",
    "repro.analysis",
    "repro.analysis.findings",
    "repro.analysis.barriers",
    "repro.analysis.noise_keys",
    "repro.analysis.recompile",
    "repro.analysis.plan_checks",
    "repro.tuner",
    "repro.tuner.cost",
    "repro.tuner.search",
    "repro.tuner.cache",
    "repro.precision",
    "repro.precision.sensitivity",
    "repro.precision.planner",
)

# markdown inline links, skipping images; target group up to the first ')'
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def check_links() -> list:
    errors = []
    for md in MARKDOWN_FILES:
        path = os.path.join(REPO, md)
        if not os.path.exists(path):
            errors.append(f"{md}: file missing")
            continue
        base = os.path.dirname(path)
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not os.path.exists(os.path.normpath(os.path.join(base, rel))):
                errors.append(f"{md}: broken link -> {target}")
    return errors


def _missing_doc(obj) -> bool:
    doc = inspect.getdoc(obj)
    return doc is None or len(doc.strip()) < 10


def check_docstrings() -> list:
    errors = []
    for modname in API_MODULES:
        mod = importlib.import_module(modname)
        if _missing_doc(mod):
            errors.append(f"{modname}: missing module docstring")
        for name, obj in vars(mod).items():
            if name.startswith("_"):
                continue
            if not (inspect.isfunction(obj) or inspect.isclass(obj)):
                continue
            if getattr(obj, "__module__", None) != modname:
                continue                       # re-exported, owned elsewhere
            if _missing_doc(obj):
                errors.append(f"{modname}.{name}: missing docstring")
            if inspect.isclass(obj):
                for mname, meth in vars(obj).items():
                    if mname.startswith("_"):
                        continue
                    if not (inspect.isfunction(meth)
                            or isinstance(meth, (staticmethod, classmethod,
                                                 property))):
                        continue
                    target = meth.fget if isinstance(meth, property) \
                        else getattr(meth, "__func__", meth)
                    if _missing_doc(target):
                        errors.append(
                            f"{modname}.{name}.{mname}: missing docstring")
    return errors


def main() -> int:
    errors = check_links() + check_docstrings()
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if errors:
        print(f"check_docs: FAILED ({len(errors)} problems)",
              file=sys.stderr)
        return 1
    print("check_docs: links + public-API docstrings OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
