"""Analog batch-normalization (ABN): the paper's distribution-aware reshaping.

The DSCI-ADC implements y = floor(mid + gamma * g0 * dp + beta) where gamma is
realized as a reference-ladder 'zoom' and beta as a 5b charge-injection offset
on the DPL.  Hardware constraints (Sec. III.D):

  * the resistive ladder has a minimum step of VDDH/32 and the MSB split-DAC
    reaches a max gain of 16; usable gamma values are powers of two in
    [1, 32] (Figs. 13, 17, 18);
  * at train time gamma can be explored at a configurable precision
    ("gamma bits", Fig. 3b) to study the accuracy/complexity trade-off;
  * beta is a 5b code covering +/-30 mV on the DPL.

This module provides the hardware quantizers (with STE for training), the
folding of learned BN statistics into (gamma, beta), and the distribution-
aware initialisation from observed DP statistics.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO
from repro.core.quantization import ste, ste_round


def quantize_gamma_pow2(gamma: jnp.ndarray, *, max_gamma: float = 32.0,
                        min_gamma: float = 1.0) -> jnp.ndarray:
    """Snap gamma to the hardware's power-of-two ladder grid (STE)."""
    g = jnp.clip(gamma, min_gamma, max_gamma)
    log2 = jnp.log2(g)
    return ste(2.0 ** jnp.round(log2), g)


def quantize_gamma_bits(gamma: jnp.ndarray, bits: int, *,
                        max_gamma: float = 32.0) -> jnp.ndarray:
    """Gamma at a given bit precision (Fig. 3b study): 2^bits log-spaced
    levels between 1 and max_gamma (bits=0 -> fixed unity gain)."""
    if bits <= 0:
        return jnp.ones_like(gamma)
    n_levels = 2 ** bits
    g = jnp.clip(gamma, 1.0, max_gamma)
    step = jnp.log2(max_gamma) / (n_levels - 1)
    idx = jnp.round(jnp.log2(g) / step)
    return ste(2.0 ** (idx * step), g)


def quantize_beta_v(beta_v: jnp.ndarray,
                    cfg: CIMMacroConfig = DEFAULT_MACRO) -> jnp.ndarray:
    """5b ABN offset: +/-abn_offset_range_v in 2^abn_offset_bits steps."""
    n = 2 ** cfg.abn_offset_bits
    lsb = 2.0 * cfg.abn_offset_range_v / (n - 1)
    q = ste_round(jnp.clip(beta_v, -cfg.abn_offset_range_v,
                           cfg.abn_offset_range_v) / lsb) * lsb
    return q


def beta_v_to_codes(beta_v: jnp.ndarray, gamma: jnp.ndarray, r_out: int,
                    cfg: CIMMacroConfig = DEFAULT_MACRO) -> jnp.ndarray:
    """Convert a DPL-referred offset (volts) into ADC code units (Eq. 7:
    the offset is applied before the zoom, so it is scaled by gamma)."""
    lsb_v = cfg.alpha_adc() * cfg.vddh / 2.0 ** (r_out - 1)
    return gamma * beta_v / lsb_v


class ABNParams(NamedTuple):
    """Learnable per-output-channel ABN parameters (pre-hardware)."""
    log_gamma: jnp.ndarray   # (N,) gamma = 2**log_gamma  (log2 domain)
    beta: jnp.ndarray        # (N,) offset in ADC code units


def init_abn(n: int) -> ABNParams:
    return ABNParams(log_gamma=jnp.zeros((n,)), beta=jnp.zeros((n,)))


def abn_gamma(params: ABNParams, *, gamma_bits: int = -1,
              max_gamma: float = 32.0) -> jnp.ndarray:
    """Effective gamma; gamma_bits<0 keeps it continuous (no HW quant)."""
    g = 2.0 ** params.log_gamma
    if gamma_bits < 0:
        return jnp.clip(g, 2.0 ** -4, max_gamma)
    return quantize_gamma_bits(g, gamma_bits, max_gamma=min(max_gamma, 32.0))


def fold_batchnorm(bn_scale: jnp.ndarray, bn_bias: jnp.ndarray,
                   mean: jnp.ndarray, var: jnp.ndarray,
                   eps: float = 1e-5) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fold conventional BN(y) = scale*(y-mean)/sqrt(var+eps)+bias into the
    ABN affine form gamma*y + beta (both in the same units as y)."""
    inv = bn_scale / jnp.sqrt(var + eps)
    return inv, bn_bias - mean * inv


def distribution_aware_init(dp_sample: jnp.ndarray, r_out: int, *,
                            target_sigma_frac: float = 0.25) -> ABNParams:
    """Distribution-aware reshaping init: choose per-channel gamma/beta so the
    observed DP distribution fills the ADC range (the paper's Fig. 3a fix).

    dp_sample: (B, N) pre-ADC dot products in *ADC input units* (i.e. already
    multiplied by the unity-gain code gain g0); gamma scales the per-channel
    std to target_sigma_frac of the half-range, beta centres the mean."""
    half = 2.0 ** (r_out - 1)
    mu = jnp.mean(dp_sample, axis=0)
    sd = jnp.std(dp_sample, axis=0) + 1e-6
    gamma = jnp.clip(target_sigma_frac * half / sd, 1.0, 32.0)
    beta = -gamma * mu
    return ABNParams(log_gamma=jnp.log2(gamma), beta=beta)
