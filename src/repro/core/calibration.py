"""SA-offset calibration (paper Sec. III.E, Fig. 19).

The chip refreshes a 7b per-column calibration code on a rare basis: the DPL
is precharged to VDDL and a SAR-like search over the calibration unit's
binary-weighted caps converges to the code that cancels the comparator
offset.  We reproduce that search bit-by-bit: it is exactly a binary search
for -offset on the 0.47 mV grid, saturating at the +/-(2^7-1)/2 LSB range —
out-of-range offsets leave 'dysfunctional columns' (Fig. 14c) that the ABN
offset block can partly absorb.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO


def calibrate_sar(sa_offset_v: jnp.ndarray,
                  cfg: CIMMacroConfig = DEFAULT_MACRO) -> jnp.ndarray:
    """Run the 7b calibration search per column.

    sa_offset_v: (N,) true comparator offsets (volts)
    returns    : (N,) compensation voltages the calibration unit applies
    """
    lsb = cfg.cal_lsb_v
    # the differential implementation covers +/- cal_range_v on either side
    # with cal_lsb_v resolution: an effective (cal_bits+1)-bit signed search
    n_bits = cfg.cal_bits + 1
    half = float(1 << (n_bits - 1))
    # unsigned SAR over the shifted range: u_code in [0, 2^b), the applied
    # compensation is (u_code - 2^(b-1)) * lsb.  Each decision compares the
    # offset against the trial compensation level, exactly like the chip's
    # decision/update cycles applied to the calibration caps.
    u_code = jnp.zeros_like(sa_offset_v)
    for k in range(n_bits - 1, -1, -1):
        trial = u_code + float(1 << k)
        take = sa_offset_v >= (trial - half) * lsb
        u_code = jnp.where(take, trial, u_code)
    comp = (u_code - half) * lsb
    return jnp.clip(comp, -cfg.cal_range_v, cfg.cal_range_v)


def residual_offsets(sa_offset_v: jnp.ndarray,
                     cfg: CIMMacroConfig = DEFAULT_MACRO) -> jnp.ndarray:
    """Offset remaining after calibration (what computations actually see)."""
    return sa_offset_v - calibrate_sar(sa_offset_v, cfg)


def dysfunctional_columns(sa_offset_v: jnp.ndarray, r_out: int,
                          cfg: CIMMacroConfig = DEFAULT_MACRO
                          ) -> jnp.ndarray:
    """Boolean mask of columns whose residual offset exceeds 1 ADC LSB."""
    lsb_v = cfg.alpha_adc() * cfg.vddh / 2.0 ** (r_out - 1)
    return jnp.abs(residual_offsets(sa_offset_v, cfg)) > lsb_v
