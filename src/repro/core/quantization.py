"""Straight-through-estimator quantizers for CIM-aware training.

These implement the digital side of the paper's co-design loop: activations
are quantized to r_in unsigned bits with an *adaptive swing* (the scale plays
the role of the serial-split DPL configuration + signed-to-unsigned datapath
conversion), weights to the macro's odd-integer +/-1 bit-plane grid, and
outputs to r_out ADC codes through the ABN-scaled floor of Eq. (7).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def ste(fwd: jnp.ndarray, grad_of: jnp.ndarray) -> jnp.ndarray:
    """Forward `fwd`, but gradient flows as if it were `grad_of`."""
    return grad_of + jax.lax.stop_gradient(fwd - grad_of)


def ste_round(x: jnp.ndarray) -> jnp.ndarray:
    return ste(jnp.round(x), x)


def ste_floor(x: jnp.ndarray) -> jnp.ndarray:
    return ste(jnp.floor(x), x)


@jax.custom_jvp
def rounding_barrier(x: jnp.ndarray) -> jnp.ndarray:
    """Identity that pins `x` to its rounded f32 value across fusion.

    XLA is free to algebraically rewrite a value that only feeds other
    arithmetic (e.g. fold the `gamma * g0` ADC gain into a neighbouring
    division as a reciprocal multiply), and it makes that choice per
    fusion context — two jitted graphs of the same quantizer arithmetic
    can then disagree by 1 ulp.  The fakequant reference and the engine
    schedule both materialize the ADC gain through this barrier so their
    floor/dequant chains see the identical float no matter how either
    graph is fused.  Gradients pass straight through (the barrier is
    numerically the identity).
    """
    return jax.lax.optimization_barrier(x)


@rounding_barrier.defjvp
def _rounding_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return rounding_barrier(x), t


def _static_reciprocal(levels: float) -> float:
    """f32-rounded 1/levels as a trace-time Python constant.

    Dividing the dynamic-range reduction by the (static) level count must
    produce the same float in every graph that quantizes the same tensor:
    XLA CPU rewrites a static-divisor division into a reciprocal multiply
    in some fusion contexts but not others, which makes the quantizer
    scale — and everything dequantized with it — differ by 1 ulp between
    two jitted graphs of the same arithmetic.  Baking the f32 reciprocal
    in as a constant multiply keeps eager, jitted, and differently-fused
    executions bitwise identical.
    """
    return float(np.float32(1.0) / np.float32(levels))


class ActQuant(NamedTuple):
    """x ~= q * scale + zero   with q unsigned ints in [0, 2^r_in - 1]."""
    q: jnp.ndarray
    scale: jnp.ndarray
    zero: jnp.ndarray


def quantize_act(x: jnp.ndarray, r_in: int, *,
                 scale: Optional[jnp.ndarray] = None,
                 zero: Optional[jnp.ndarray] = None,
                 segment_ids: Optional[jnp.ndarray] = None,
                 num_segments: Optional[int] = None,
                 eps: float = 1e-8) -> ActQuant:
    """Unsigned asymmetric activation quantization (the datapath's
    signed-to-unsigned conversion + adaptive input swing).

    If scale/zero are None they are computed from the current tensor
    (dynamic 'swing adaptation'); both are stop-gradiented, the STE flows
    through the rounding only.

    `segment_ids` (optional, shape (x.shape[0],) int) switches the dynamic
    min/max reduction from tensor-global to *per-segment* over the leading
    axis: rows sharing a segment id share one swing, rows in different
    segments never see each other's statistics.  This is the serving-side
    isolation primitive — a fused multi-request batch quantizes each
    request exactly as if it were served alone, because min/max are exact
    reductions (a row's segment stats equal its solo-run stats bit for
    bit).  scale/zero then broadcast per row, shape (x.shape[0], 1, ...).
    The default (segment_ids=None) path is unchanged.
    """
    levels = 2.0 ** r_in - 1.0
    inv_levels = _static_reciprocal(levels)
    if segment_ids is not None and (zero is None or scale is None):
        if num_segments is None:
            num_segments = x.shape[0]
        red = tuple(range(1, x.ndim))
        row_max = jnp.max(x, axis=red) if red else x
        row_min = jnp.min(x, axis=red) if red else x
        seg_max = jax.ops.segment_max(row_max, segment_ids,
                                      num_segments=num_segments)
        seg_min = jax.ops.segment_min(row_min, segment_ids,
                                      num_segments=num_segments)
        bshape = (x.shape[0],) + (1,) * (x.ndim - 1)
        if zero is None:
            zero = jax.lax.stop_gradient(
                seg_min[segment_ids].reshape(bshape))
        if scale is None:
            rng = jax.lax.stop_gradient(
                seg_max[segment_ids].reshape(bshape) - zero)
            scale = jnp.maximum(rng, eps) * inv_levels
    if zero is None:
        zero = jax.lax.stop_gradient(jnp.min(x))
    if scale is None:
        rng = jax.lax.stop_gradient(jnp.max(x) - zero)
        scale = jnp.maximum(rng, eps) * inv_levels
    q = ste_round(jnp.clip((x - zero) / scale, 0.0, levels))
    return ActQuant(q=q, scale=scale, zero=zero)


class WeightQuant(NamedTuple):
    """w ~= q * scale, q odd ints in +/-(2^r_w - 1)  (per-out-channel scale)."""
    q: jnp.ndarray
    scale: jnp.ndarray


def quantize_weight(w: jnp.ndarray, r_w: int, *, axis: int = 0,
                    eps: float = 1e-8) -> WeightQuant:
    """Quantize to the macro's odd-integer grid (bit-planes of +/-1 signs).

    The representable values are the 2^r_w odd integers in
    [-(2^r_w - 1), 2^r_w - 1]; step 2.  Scale is per-output-channel
    (reduction over `axis`).
    """
    full = 2.0 ** r_w - 1.0
    amax = jax.lax.stop_gradient(
        jnp.max(jnp.abs(w), axis=axis, keepdims=True))
    scale = jnp.maximum(amax, eps) * _static_reciprocal(full)
    u = jnp.clip(w / scale, -full, full)
    # nearest odd integer with STE: 2*round((u-1)/2)+1
    q = 2.0 * ste_round((u - 1.0) / 2.0) + 1.0
    q = jnp.clip(q, -full, full)
    return WeightQuant(q=q, scale=scale)


def adc_quantize(dp: jnp.ndarray, *, r_out: int, gain: jnp.ndarray,
                 beta_codes: jnp.ndarray) -> jnp.ndarray:
    """Eq. (7) in code space with STE: code = floor(mid + gain*dp + beta)."""
    mid = 2.0 ** (r_out - 1)
    # the product is barriered in lockstep with the kernel/ref ADC epilogue
    # (kernels/cim_mbiw) so no fusion context can FMA-contract the floor
    # argument differently on either side of the bit-exactness contract
    code = ste_floor(mid + rounding_barrier(gain * dp) + beta_codes)
    return jnp.clip(code, 0.0, 2.0 ** r_out - 1.0) + 0.5
