"""Post-silicon equivalent noise model of the IMAGINE macro.

Every analog non-ideality the paper measures or simulates is represented here
as a differentiable (where meaningful) JAX term so it can be injected into the
CIM-aware training forward pass (paper Sec. III.E, V.A):

  * thermal / kT-C noise          -> Gaussian on the MBIW voltage
                                     (0.52 LSB_8b RMS at gamma=1, Fig. 18a)
  * StrongArm SA offset           -> per-column static Gaussian
                                     (sigma 20 mV pre-layout, x1.75 post-layout,
                                     Fig. 14b), compensated by the 7b
                                     calibration unit down to its 0.47 mV
                                     resolution / +/-2 LSB residue (Fig. 19)
  * DPL settling INL              -> first-order RC settling of the serial-
                                     split DPL (Fig. 8b,c): the DP deviation
                                     only reaches (1 - exp(-T_dp/tau)) of its
                                     final value, tau grows with the number of
                                     connected units (series TG resistance)
  * charge injection (MBIW)       -> deterministic bilinear error map on
                                     (V_in, V_acc) (Fig. 10c), +/-1 LSB_8b
  * leakage                       -> linear droop on V_acc over the input-
                                     accumulation window (Fig. 10a)

Units convention (shared with runtime/engine.py): functions suffixed `_v`
return volts; `*_dp` quantities are integer dot-product units (pre-ADC);
`*_codes` are ADC output codes in [0, 2^r_out); conversion between them
goes through the unity-gain code gain g0 (codes per dp unit) and
lsb = alpha_adc * VDDH / 2^(r_out-1) (volts per code).

`NoiseConfig` is registered as a JAX pytree: its numeric fields are
*leaves* (traced scalars inside jit), while `enabled`/`calibrated` stay
static aux data.  A jitted consumer therefore compiles once per
enabled/calibrated combination and reuses that compile across numeric
operating points — the engine's `run_network(..., noise=point)` sweeps
rely on this.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO


@dataclasses.dataclass(frozen=True)
class NoiseConfig:
    """One operating point of the equivalent noise model.

    All numeric fields are traced pytree leaves (see module docstring);
    `enabled` and `calibrated` are static flags.  Field units are noted
    inline — volts unless stated otherwise."""
    enabled: bool = True
    # thermal noise, expressed as RMS in 8b ADC LSBs at gamma=1 (measured)
    thermal_rms_lsb8: float = 0.52
    # StrongArm sense-amp offset
    sa_sigma_v: float = 0.020           # pre-layout sigma (3-sigma = 60 mV)
    sa_postlayout_mult: float = 1.75    # Fig. 14b: +75% post-layout
    calibrated: bool = True             # apply the 7b calibration unit
    # DPL settling (serial-split transmission gates)
    tau0_ns: float = 0.50               # settling tau with one unit connected
    tau_per_unit_ns: float = 0.016      # series-R growth per connected unit
    # charge injection error map (volts of error per volt of node deviation)
    kappa_in: float = 0.0024
    kappa_acc: float = 0.0016
    # leakage droop on the accumulation cap
    leak_v_per_us: float = 2.0e-4

    @staticmethod
    def none() -> "NoiseConfig":
        """The disabled operating point (same object shape as NO_NOISE)."""
        return NoiseConfig(enabled=False)

    def replace(self, **kw) -> "NoiseConfig":
        """A copy with the given fields replaced (dataclasses.replace)."""
        return dataclasses.replace(self, **kw)


NO_NOISE = NoiseConfig(enabled=False)

# numeric fields = traced pytree leaves; (enabled, calibrated) = static aux
_NOISE_LEAF_FIELDS = (
    "thermal_rms_lsb8", "sa_sigma_v", "sa_postlayout_mult", "tau0_ns",
    "tau_per_unit_ns", "kappa_in", "kappa_acc", "leak_v_per_us")


def _noise_flatten(nc: "NoiseConfig"):
    return (tuple(getattr(nc, f) for f in _NOISE_LEAF_FIELDS),
            (nc.enabled, nc.calibrated))


def _noise_unflatten(aux, leaves) -> "NoiseConfig":
    enabled, calibrated = aux
    return NoiseConfig(enabled=enabled, calibrated=calibrated,
                       **dict(zip(_NOISE_LEAF_FIELDS, leaves)))


jax.tree_util.register_pytree_node(NoiseConfig, _noise_flatten,
                                   _noise_unflatten)


def lsb8_volts(cfg: CIMMacroConfig = DEFAULT_MACRO) -> float:
    """Voltage of one 8b ADC LSB at unity gain (full scale ~ VDDH)."""
    return cfg.vddh / 2.0**8


def thermal_sigma_v(noise: NoiseConfig, cfg: CIMMacroConfig) -> float:
    """Thermal kT/C RMS on the MBIW voltage in volts (the measured
    0.52 LSB_8b at gamma=1, Fig. 18a, referred through the 8b LSB)."""
    return noise.thermal_rms_lsb8 * lsb8_volts(cfg)


def sample_thermal(key: jax.Array, shape, noise: NoiseConfig,
                   cfg: CIMMacroConfig = DEFAULT_MACRO,
                   dtype=jnp.float32) -> jnp.ndarray:
    """Gaussian thermal-noise draw in volts with the configured RMS.

    Returns zeros of `dtype` when the model is disabled (the dtype is
    honored either way — regression-tested)."""
    if not noise.enabled:
        return jnp.zeros(shape, dtype)
    return (thermal_sigma_v(noise, cfg)
            * jax.random.normal(key, shape, dtype)).astype(dtype)


def thermal_sigma_dp(noise: NoiseConfig, r_out: int, g0: float) -> float:
    """Thermal kT/C RMS referred to integer dp units through the code gain.

    The measured 0.52 LSB_8b RMS (gamma=1) maps to r_out-bit code units via
    2^(r_out-8) and to dp units via the unity-gain code gain g0.  Both the
    fakequant training path and the engine's noise epilogue draw their
    thermal term from this single expression, so the paths agree
    statistically by construction."""
    if not noise.enabled:
        return 0.0
    return noise.thermal_rms_lsb8 * 2.0 ** (r_out - 8) / g0


def sample_sa_offsets(key: jax.Array, n_cols: int, noise: NoiseConfig,
                      cfg: CIMMacroConfig = DEFAULT_MACRO) -> jnp.ndarray:
    """Per-column static SA offsets in volts (post-layout)."""
    if not noise.enabled:
        return jnp.zeros((n_cols,))
    sigma = noise.sa_sigma_v * noise.sa_postlayout_mult
    return sigma * jax.random.normal(key, (n_cols,))


def calibration_residue(offsets_v: jnp.ndarray, noise: NoiseConfig,
                        cfg: CIMMacroConfig = DEFAULT_MACRO) -> jnp.ndarray:
    """Residual offset after the 7b calibration unit (core/calibration.py
    implements the SAR search itself; this is its ideal fixed point).

    The unit covers +/- cal_range with cal_lsb resolution; offsets inside the
    range are reduced to quantization residue, outside they saturate (the
    'few dysfunctional columns' of Fig. 14c)."""
    if not noise.calibrated:
        return offsets_v
    from repro.core.calibration import residual_offsets
    return residual_offsets(offsets_v, cfg)


def settle_fraction(n_units_on, t_dp_ns: float,
                    noise: NoiseConfig) -> jnp.ndarray:
    """Fraction of the final DPL deviation reached after T_dp (Fig. 8b).

    `n_units_on` may be a python int or an array of unit counts: the
    settling curve is pure jnp so it traces/vmaps (e.g. sweeping the split
    configuration in one shot, Fig. 8c)."""
    n = jnp.asarray(n_units_on, jnp.float32)
    if not noise.enabled:
        return jnp.ones_like(n)
    tau = noise.tau0_ns + noise.tau_per_unit_ns * n
    return 1.0 - jnp.exp(-jnp.float32(t_dp_ns) / tau)


def charge_injection_error(v_in: jnp.ndarray, v_acc: jnp.ndarray,
                           noise: NoiseConfig,
                           cfg: CIMMacroConfig = DEFAULT_MACRO) -> jnp.ndarray:
    """Deterministic MBIW charge-injection error (volts), Fig. 10c.

    Error depends on both the sampled DP voltage and the previously stored
    accumulation voltage through the TG gate-source capacitances; the zero-
    error locus is the diagonal v_in ~ (kappa_acc/kappa_in) * v_acc."""
    if not noise.enabled:
        return jnp.zeros(jnp.broadcast_shapes(v_in.shape, v_acc.shape),
                         jnp.result_type(v_in, v_acc))
    mid = cfg.vddl
    return noise.kappa_in * (v_in - mid) - noise.kappa_acc * (v_acc - mid)


def leakage_droop(r_in: int, t_dp_ns: float, noise: NoiseConfig) -> float:
    """Accumulated V_acc droop (volts) over the input-serial window."""
    if not noise.enabled:
        return 0.0
    window_us = r_in * 2.0 * t_dp_ns * 1e-3
    return noise.leak_v_per_us * window_us


def channels_per_col_tile(r_w: int, cfg: CIMMacroConfig = DEFAULT_MACRO
                          ) -> int:
    """Output channels one macro col tile carries (cf. mapping.map_layer):
    one channel per 4-column block at r_w in (3, 4), more at narrow
    weights."""
    return cfg.n_blocks * max(1, cfg.cols_per_block // r_w)


def sample_column_residues(key: jax.Array, n_channels: int, r_w: int,
                           noise: NoiseConfig,
                           cfg: CIMMacroConfig = DEFAULT_MACRO
                           ) -> jnp.ndarray:
    """Calibrated SA-offset residues per *logical* output channel (volts).

    The physical offsets are static per macro column: there are exactly
    `cfg.n_cols` comparators, sampled once, and a layer with more output
    channels than one col tile carries reuses the same physical columns
    sequentially — so logical channels j and j + channels_per_col_tile see
    the *same* residue.  Channel c inside a tile owns r_w adjacent columns
    of its block; its comparator sits at column c * (n_cols / ch_per_tile).
    """
    raw = sample_sa_offsets(key, cfg.n_cols, noise, cfg)
    res = calibration_residue(raw, noise, cfg)
    ch_per_tile = channels_per_col_tile(r_w, cfg)
    c = jnp.arange(n_channels) % ch_per_tile
    return res[c * (cfg.n_cols // ch_per_tile)]


def charge_injection_gain(r_in: int, noise: NoiseConfig,
                          cfg: CIMMacroConfig = DEFAULT_MACRO) -> float:
    """Equivalent multiplicative error of the MBIW charge injection,
    referred to the final accumulated voltage (code units see it as a gain
    term on g0).

    The per-step bilinear map of `charge_injection_error` makes the
    recursion  v_{k+1} = (a - kappa_acc) v_k + (1 - a + kappa_in) u_k  with
    a = alpha_mb.  To first order in kappa, and exactly when every input
    bit contributes the same per-bit deviation, the accumulated error is
    proportional to the ideal final voltage with the constant returned
    here; the weight-parallel combination is linear, so the same constant
    refers it to the combined MBIW voltage."""
    if not noise.enabled:
        return 0.0
    a = cfg.alpha_mb()
    geo = (1.0 - a ** r_in) / (1.0 - a)
    err = (noise.kappa_in * geo
           - noise.kappa_acc * (geo - r_in * a ** (r_in - 1)))
    return err / (1.0 - a ** r_in)
