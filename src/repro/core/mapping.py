"""Layer -> macro tiling (Sec. III.A/IV): how a GEMM or conv maps onto the
1152x256 array, how many macro invocations / cycles it costs, and how the
resulting tile schedule partitions across replicated macros (devices).

Constraints reproduced from the chip:
  * rows: K_eff = kernel_h*kernel_w*C_in bitcell rows per filter column,
    allocated in serial-split units of 36 rows (3x3 x 4 channels);
    K_eff > 1152 splits into row tiles whose partial ADC codes are summed
    digitally (with requantization) — same as any weight-stationary CIM.
  * columns: each output channel occupies r_w adjacent columns inside a
    4-column block; 64 blocks -> 64 output channels per tile (r_w<=4).
  * minimum configuration: 4 input channels (one 36-row unit) in conv mode.

Multi-macro sharding (the paper's system-level scaling assumption — the
1152x256 macro is a building block replicated for the 40 TOPS/W system
numbers): column tiles of one layer are independent macro programs, so a
bank of D macros (devices) evaluates them in parallel (`shard_layer` kind
"col"); a layer with fewer col tiles than macros instead splits its
GEMM-row dimension M = batch*out_h*out_w, every macro holding the same
weights ("rows" kind — weight-stationary data parallelism).  Both choices
preserve the single-macro numerics exactly: columns and GEMM rows never
interact before the digital partial-sum recombination.

Units note: everything in this module is *integer geometry* (rows, columns,
tiles, devices) — no voltages, no code units.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple, Union

from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO

Padding = Union[int, str, Tuple[Tuple[int, int], Tuple[int, int]]]


@dataclasses.dataclass(frozen=True)
class ConvGeometry:
    """NHWC conv geometry behind a LayerSpec's im2col GEMM view.

    The engine uses it to extract patch tiles (im2col streaming) and to
    reshape the GEMM output back to (B, out_h, out_w, c_out); the perf
    model uses it for the Eq. (8)-(10) input/output bandwidth terms."""
    h: int
    w: int
    c_in: int
    c_out: int
    kh: int
    kw: int
    stride: int
    padding: Tuple[Tuple[int, int], Tuple[int, int]]   # ((top,bot),(lt,rt))
    out_h: int
    out_w: int
    batch: int

    @property
    def spatial_in(self) -> Tuple[int, int, int]:
        """Per-sample input feature shape (H, W, C_in)."""
        return (self.h, self.w, self.c_in)

    @property
    def spatial_out(self) -> Tuple[int, int, int]:
        """Per-sample output feature shape (out_h, out_w, c_out)."""
        return (self.out_h, self.out_w, self.c_out)


def resolve_padding(padding: Padding, kh: int, kw: int, h: int, w: int,
                    stride: int) -> Tuple[Tuple[int, int], Tuple[int, int]]:
    """Normalize int / "SAME" / "VALID" / explicit pairs to per-edge pads."""
    if isinstance(padding, str):
        p = padding.upper()
        if p == "VALID":
            return ((0, 0), (0, 0))
        if p == "SAME":
            pads = []
            for dim, kd in ((h, kh), (w, kw)):
                out = -(-dim // stride)
                total = max((out - 1) * stride + kd - dim, 0)
                pads.append((total // 2, total - total // 2))
            return (pads[0], pads[1])
        raise ValueError(f"padding {padding!r} not in ('SAME', 'VALID')")
    if isinstance(padding, int):
        if padding < 0:
            raise ValueError(f"padding must be >= 0, got {padding}")
        return ((padding, padding), (padding, padding))
    (pt, pb), (pl, pr) = padding
    if min(pt, pb, pl, pr) < 0:
        raise ValueError(f"padding must be >= 0, got {padding}")
    return ((int(pt), int(pb)), (int(pl), int(pr)))


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """A GEMM of shape [M, K] x [K, N] (conv layers pass K = kh*kw*C_in
    after im2col, M = batch*out_h*out_w).

    `conv` tags the spec as a convolution: the runtime engine then expects
    NHWC activations and performs the im2col itself (conv_layer_spec builds
    tagged specs); `conv is None` means a plain dense GEMM."""
    m: int
    k: int
    n: int
    r_in: int = 8
    r_w: int = 4
    r_out: int = 8
    kernel: Tuple[int, int] = (1, 1)   # (kh, kw) for conv layers
    conv: Optional[ConvGeometry] = None

    @property
    def op(self) -> str:
        """Layer kind tag: "dense" or "conv" (conv-geometry-tagged)."""
        return "dense" if self.conv is None else "conv"


@dataclasses.dataclass(frozen=True)
class MacroMapping:
    row_tiles: int          # sequential K splits (digital partial-sum adds)
    col_tiles: int          # sequential N splits (64 channels per tile)
    units_per_tile: int     # serial-split units connected per row tile
    rows_per_tile: int      # active bitcell rows per row tile
    n_dp: int               # connected rows (units * 36), sets the swing
    macro_evals: int        # row_tiles * col_tiles (per M-row batch of work)
    utilization: float      # active rows / connected rows

    @property
    def needs_digital_accum(self) -> bool:
        """True when K splits into row tiles whose partial ADC codes the
        host must sum digitally (requantization between tiles)."""
        return self.row_tiles > 1


def map_layer(spec: LayerSpec, cfg: CIMMacroConfig = DEFAULT_MACRO
              ) -> MacroMapping:
    """Map one LayerSpec onto the macro's row/column tile grid.

    Args:
      spec: the GEMM/conv layer; spec.k sets the bitcell-row demand,
        spec.n the output-channel demand, spec.r_w the columns per channel.
      cfg:  macro geometry (1152 rows x 256 cols by default).
    Returns:
      MacroMapping with the sequential row/col tile counts, the serial-split
      unit count per row tile (adaptive swing) and the utilization.
    Raises:
      ValueError when spec.r_w exceeds the macro's column budget.
    """
    if spec.r_w > cfg.max_r_w:
        raise ValueError(f"r_w={spec.r_w} > macro max {cfg.max_r_w}")
    ch_per_tile = cfg.n_blocks * (cfg.cols_per_block // max(spec.r_w, 1))
    ch_per_tile = min(ch_per_tile, cfg.n_blocks * cfg.cols_per_block)
    # one output channel per 4-col block when r_w in (3,4); two when r_w<=2
    ch_per_tile = cfg.n_blocks * max(1, cfg.cols_per_block // spec.r_w)
    col_tiles = math.ceil(spec.n / ch_per_tile)
    row_tiles = math.ceil(spec.k / cfg.n_rows)
    rows_per_tile = math.ceil(spec.k / row_tiles)
    units = cfg.units_for_rows(rows_per_tile)
    n_dp = units * cfg.rows_per_unit
    return MacroMapping(
        row_tiles=row_tiles, col_tiles=col_tiles, units_per_tile=units,
        rows_per_tile=rows_per_tile, n_dp=n_dp,
        macro_evals=row_tiles * col_tiles,
        utilization=rows_per_tile / n_dp)


def conv_layer_spec(batch: int, h: int, w: int, c_in: int, c_out: int,
                    kh: int = 3, kw: int = 3, stride: int = 1,
                    r_in: int = 8, r_w: int = 4, r_out: int = 8,
                    padding: Padding = 1) -> LayerSpec:
    """Conv-tagged LayerSpec: validates geometry and propagates stride &
    padding into out_h/out_w (and hence M = batch*out_h*out_w).

    `padding` accepts an int (symmetric), "SAME"/"VALID", or explicit
    ((top, bottom), (left, right)) pairs."""
    if min(batch, h, w, c_in, c_out, kh, kw) < 1:
        raise ValueError(
            f"conv dims must be >= 1, got batch={batch} h={h} w={w} "
            f"c_in={c_in} c_out={c_out} kh={kh} kw={kw}")
    if stride < 1:
        raise ValueError(f"stride must be >= 1, got {stride}")
    pads = resolve_padding(padding, kh, kw, h, w, stride)
    oh = (h + pads[0][0] + pads[0][1] - kh) // stride + 1
    ow = (w + pads[1][0] + pads[1][1] - kw) // stride + 1
    if oh < 1 or ow < 1:
        raise ValueError(
            f"kernel {kh}x{kw} (stride {stride}, padding {pads}) does not "
            f"fit a {h}x{w} input: out {oh}x{ow}")
    geom = ConvGeometry(h=h, w=w, c_in=c_in, c_out=c_out, kh=kh, kw=kw,
                        stride=stride, padding=pads, out_h=oh, out_w=ow,
                        batch=batch)
    return LayerSpec(m=batch * oh * ow, k=kh * kw * c_in, n=c_out,
                     r_in=r_in, r_w=r_w, r_out=r_out, kernel=(kh, kw),
                     conv=geom)


def split_k_slices(k: int, row_tiles: int) -> List[Tuple[int, int]]:
    """Even (start, size) row-tile slices of a K-dim for digital partial-sum
    accumulation.

    Args:
      k: total reduction length (bitcell rows of the layer).
      row_tiles: number of sequential macro row tiles (map_layer.row_tiles).
    Returns:
      (start, size) pairs covering [0, k); all slices have size
      ceil(k / row_tiles) except a possibly-smaller last one.
    """
    base = math.ceil(k / row_tiles)
    out, s = [], 0
    while s < k:
        size = min(base, k - s)
        out.append((s, size))
        s += size
    return out


def split_even_slices(n: int, tiles: int) -> List[Tuple[int, int]]:
    """Uniform (start, size) column-tile slices, padded to a common size.

    Sharded schedules execute col tiles SPMD across devices, which requires
    every tile to have the same shape; callers pad their column arrays to
    `tiles * size` and discard outputs at column index >= n.  The uniform
    size also makes the engine's per-tile noise draws independent of how
    many devices later execute the schedule (the bit-exactness contract of
    sharded noisy inference).

    Args:
      n: real extent (output channels of the layer).
      tiles: number of col tiles (map_layer.col_tiles).
    Returns:
      `tiles` pairs (i*size, size) with size = ceil(n / tiles); the covered
      extent tiles*size may exceed n (column padding).
    """
    size = math.ceil(n / max(tiles, 1))
    return [(i * size, size) for i in range(max(tiles, 1))]


@dataclasses.dataclass(frozen=True)
class LayerShard:
    """How one layer's tile schedule partitions across `devices` macros.

    kind "col": independent col tiles round-robin to devices in contiguous
    groups of `tiles_per_device` (the tile count is padded up to
    devices * tiles_per_device with all-zero dummy tiles when it does not
    divide evenly).  kind "rows": the layer has fewer col tiles than
    devices, so the M = batch*out_h*out_w GEMM-row dimension splits into
    `rows_per_device`-row blocks instead (stream_rows-style chunking,
    weights replicated).  `efficiency` is useful work / (devices x
    per-device work) — 1.0 when the partition divides evenly.
    """
    devices: int            # mesh axis size D (>= 1)
    kind: str               # "col" | "rows"
    tiles_per_device: int   # col tiles per device ("col" kind, else 0)
    rows_per_device: int    # GEMM rows per device ("rows" kind, else 0)
    efficiency: float       # load balance in [1/D, 1.0]


def shard_layer(spec: LayerSpec, mp: MacroMapping,
                devices: int, kind: Optional[str] = None) -> LayerShard:
    """Partition one mapped layer across a bank of `devices` macros.

    Col tiles are the natural parallel axis (they share inputs but touch
    disjoint output channels); by default a layer offering at least one col
    tile per device shards those.  Otherwise the schedule falls back to
    sharding the GEMM-row dimension M (every device runs the full tile
    schedule on an M/devices row block — bit-identical because GEMM rows
    are independent through the elementwise ADC epilogue).

    Args:
      spec: the layer (spec.m supplies the GEMM-row extent for "rows").
      mp:   its macro mapping (col_tiles decides the default kind).
      devices: number of macros/devices (>= 1).
      kind: None selects the >=D-col-tiles heuristic; an explicit "col" or
        "rows" overrides it (the schedule autotuner scores both).  Both
        overrides are always legal: "col" with fewer col tiles than
        devices pads the tile count up with all-zero dummy tiles (the
        efficiency reflects the idle devices), and "rows" merely splits
        M.  Either way the single-macro numerics are untouched — columns
        and GEMM rows never interact before the digital recombination.
    Returns:
      LayerShard; devices=1 degenerates to a single-device "col" plan with
      every tile on the one device.
    """
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    if kind is None:
        kind = "col" if mp.col_tiles >= devices else "rows"
    if kind == "col":
        tiles_per_device = max(1, math.ceil(mp.col_tiles / devices))
        eff = mp.col_tiles / (devices * tiles_per_device)
        return LayerShard(devices=devices, kind="col",
                          tiles_per_device=tiles_per_device,
                          rows_per_device=0, efficiency=eff)
    if kind != "rows":
        raise ValueError(f"shard kind must be 'col' or 'rows', got {kind!r}")
    rows_per_device = math.ceil(spec.m / devices)
    eff = spec.m / (devices * rows_per_device) if spec.m else 1.0
    return LayerShard(devices=devices, kind="rows", tiles_per_device=0,
                      rows_per_device=rows_per_device, efficiency=eff)
