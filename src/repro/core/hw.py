"""Hardware constants and configuration for the IMAGINE CIM-SRAM macro.

All values come from the paper (Kneip et al., 2024, 22nm FD-SOI CERBERUS):
  - 1152x256 DP array, 32 DP units of 36 rows (3x3 kernel x C_in=4 granule)
  - 64 analog cores of 4 columns each (1-4b weights, one output ch / core)
  - 10T1C bitcell with C_c = 0.7 fF MoM cap, 0.44 um^2
  - serial-split DPL, ADC load C_L = 40 fF/column after voltage-split DAC
  - DSCI SAR ADC: 8b SAR (C_sar = 33*C_c), 5b ABN offset (+/-30 mV),
    7b calibration (0.47 mV resolution, 4*C_c MSB)
  - V_DDL/V_DDH = 0.4/0.8 V nominal (down to 0.28/0.56 V measured)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

FF = 1e-15  # farad


@dataclasses.dataclass(frozen=True)
class CIMMacroConfig:
    """Static description of one CIM-SRAM macro instance."""

    # --- array geometry -------------------------------------------------
    n_rows: int = 1152              # DP rows (bitcells per column)
    n_cols: int = 256               # physical columns
    n_units: int = 32               # serial-split DPL units
    rows_per_unit: int = 36         # 3x3 kernel x C_in granule of 4
    cols_per_block: int = 4         # weight-bit columns per analog core
    # --- capacitances (farads) ------------------------------------------
    c_c: float = 0.7 * FF           # bitcell MoM computing cap
    c_load_adc: float = 40.0 * FF   # total non-DP load on the DPL (ADC dom.)
    c_par_per_unit: float = 2.0 * FF  # metal routing parasitics per unit
    c_sar: float = 33 * 0.7 * FF    # SAR array total capacitance
    c_par_sar: float = 2.0 * FF     # SAR parasitics
    # --- supplies --------------------------------------------------------
    vddl: float = 0.4               # analog DP supply (precharge level)
    vddh: float = 0.8               # ADC / reference supply
    # --- precision -------------------------------------------------------
    max_r_in: int = 8
    max_r_w: int = 4
    max_r_out: int = 8
    # --- ABN / calibration hardware --------------------------------------
    abn_offset_bits: int = 5        # +/-30 mV on the DPL
    abn_offset_range_v: float = 0.030
    cal_bits: int = 7               # SA-offset calibration unit
    cal_lsb_v: float = 0.47e-3      # calibration resolution
    cal_range_v: float = 0.060      # +/- range (covers the 3-sigma 60 mV
                                    # pre-layout offset; ~1.7 sigma post-
                                    # layout -> 'few dysfunctional columns')
    gamma_max_msb: int = 16         # max gain of the MSB split DAC
    gamma_max: int = 32             # max usable gain (ladder limit VDDH/32)
    # --- timing (ns), from Fig. 8 ----------------------------------------
    t_dp_ns: float = 5.0            # single-bit DP duration (serial-split)
    t_dp_cfg_ns: float = 1.0        # +/- configurability range
    t_adc_bit_ns: float = 5.0       # per SAR decision+update cycle

    @property
    def max_input_channels(self) -> int:
        """Max C_in for 3x3 kernels: 32 units * 4 channels."""
        return self.n_units * (self.rows_per_unit // 9)

    @property
    def n_blocks(self) -> int:
        return self.n_cols // self.cols_per_block

    def alpha_eff(self, n_units_on: int) -> float:
        """Eq. (4) with the serial-split DPL: both the DP capacitance and the
        routing parasitics scale with the number of connected units, while the
        ADC-side load C_L is constant."""
        if not 1 <= n_units_on <= self.n_units:
            raise ValueError(f"n_units_on={n_units_on} not in [1,{self.n_units}]")
        n_dp = n_units_on * self.rows_per_unit
        c_p = n_units_on * self.c_par_per_unit
        return self.c_c / (n_dp * self.c_c + c_p + self.c_load_adc)

    def alpha_eff_baseline(self) -> float:
        """Eq. (4) for a fixed (non-split) DPL: all rows always connected."""
        c_p = self.n_units * self.c_par_per_unit
        return self.c_c / (self.n_rows * self.c_c + c_p + self.c_load_adc)

    def swing_efficiency(self, n_units_on: int) -> float:
        """N_dp * alpha_eff: the fraction of the ideal (parasitic-free) DPL
        swing actually reached at a given split configuration.  ==1 for an
        ideal array; the paper's Fig. 6(b) 'swing improvement' is the ratio
        of this quantity between split and baseline configs."""
        n_dp = n_units_on * self.rows_per_unit
        return n_dp * self.alpha_eff(n_units_on)

    def alpha_adc(self) -> float:
        """SAR attenuation alpha_adc = C_sar / (C_sar + C_p,sar)  (Eq. 7)."""
        return self.c_sar / (self.c_sar + self.c_par_sar)

    def alpha_mb(self) -> float:
        """Multi-bit attenuation (Eq. 5): C_acc is sized to equal the
        remaining DPL load (C_mb + C_adc), giving ~1/2."""
        return 0.5

    def units_for_rows(self, n_rows_used: int) -> int:
        """Smallest number of serial-split units covering `n_rows_used`."""
        if n_rows_used < 1:
            raise ValueError("need at least one active row")
        if n_rows_used > self.n_rows:
            raise ValueError(f"{n_rows_used} rows > array height {self.n_rows}")
        return -(-n_rows_used // self.rows_per_unit)


# TPU v5e-class hardware model used by the roofline analysis (per chip).
@dataclasses.dataclass(frozen=True)
class TPUSpec:
    peak_bf16_flops: float = 197e12   # FLOP/s
    hbm_bw: float = 819e9             # byte/s
    ici_bw_per_link: float = 50e9     # byte/s per link
    hbm_bytes: float = 16e9
    vmem_bytes: float = 128 * 2**20
    mxu_dim: int = 128


DEFAULT_MACRO = CIMMacroConfig()
TPU_V5E = TPUSpec()

# ICI links per chip used by our meshes: 2D torus -> ~4 usable links, but we
# conservatively model 3 effective links for mixed AG/AR traffic patterns.
# Shared by benchmarks/roofline.py and repro.tuner (one hardware table).
EFFECTIVE_LINKS = 3.0
