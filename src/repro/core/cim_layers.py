"""CIM-quantized layers: the paper's technique as a composable JAX module.

`cim_linear_apply` is the single entry point used by every model in the repo
(MLP/LeNet for the paper's own workloads, and all 10 assigned LM
architectures).  Three execution modes:

  * "bypass"    : plain (bf16/fp32) matmul — the non-CIM baseline.
  * "fakequant" : the CIM-aware training/serving path.  Exact digital-
                  equivalent integer math (bit-plane weights, unsigned
                  activations, ABN-scaled floor ADC) with STE gradients and
                  optional post-silicon noise injection.  This is the TPU-
                  native adaptation: per-channel ABN is fused into the matmul
                  epilogue, the adaptive swing is the dynamic activation
                  scale (see DESIGN.md §3).
  * "sim"       : voltage-domain behavioural macro (core/cim_macro.py),
                  tiled per core/mapping.py.  Small workloads only; used by
                  fidelity tests and paper-figure benchmarks.
  * "engine"    : the precision-scalable inference runtime
                  (runtime/engine.py): the layer is planned into row/col
                  macro tiles and executed through the precision-
                  specialized Pallas kernel variants — the deployed
                  inference path, bit-exact with its digital reference
                  under NO_NOISE.  With cfg.noise enabled (and a key) the
                  runtime injects the post-silicon noise model through a
                  post-kernel epilogue — the fast path for Monte-Carlo
                  noise studies.

Parameters per layer: {"w": (K, N) fp32 master weights,
                       "abn_log_gamma": (N,), "abn_beta": (N,)}.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import abn as abn_lib
from repro.core import digital_ref, mapping
from repro.core import noise_model as nm
from repro.core.cim_macro import cim_macro_forward
from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO
from repro.core.noise_model import NO_NOISE, NoiseConfig
from repro.core.quantization import (ActQuant, _static_reciprocal,
                                     adc_quantize, quantize_act,
                                     quantize_weight, rounding_barrier)


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    """Per-layer CIM execution configuration."""
    mode: str = "fakequant"          # bypass | fakequant | sim
    r_in: int = 8
    r_w: int = 4
    r_out: int = 8
    adaptive_swing: bool = True      # serial-split DPL swing adaptation
    gamma_bits: int = -1             # -1: continuous gamma; >=0: HW quant
    max_gamma: float = 32.0          # resistive-ladder limit; the TPU-native
                                     # digital epilogue can exceed it (beyond-
                                     # paper mode, see DESIGN.md §3)
    noise: NoiseConfig = NO_NOISE
    macro: CIMMacroConfig = DEFAULT_MACRO
    sharding: Optional[object] = None   # runtime.engine.ShardingConfig —
                                        # multi-macro dispatch in mode
                                        # "engine" (ignored by other modes)
    isolate_rows: bool = False          # mode "engine" only: each leading
                                        # batch row is its own activation-
                                        # quantization segment, so fused
                                        # rows are bit-identical to solo
                                        # rows (serving-side isolation;
                                        # noise draws stay positional —
                                        # use runtime/scheduler.py for
                                        # full per-request noise identity)

    def replace(self, **kw) -> "CIMConfig":
        """A copy of this config with the given fields replaced."""
        return dataclasses.replace(self, **kw)


BYPASS = CIMConfig(mode="bypass")


def analytic_log_gamma_init(k: int, cfg: CIMConfig,
                            target_frac: float = 0.25) -> float:
    """Distribution-aware gamma init (no calibration data needed): scale the
    expected DP std of one macro row-tile to `target_frac` of the ADC
    half-range.  Assumes amax-scaled ~N activations/weights, for which the
    integer codes have std ~2^r_in/8 and ~2^(r_w-1)/2."""
    k_tile = -(-k // (-(-k // cfg.macro.n_rows)))   # rows per even row tile
    g0 = _code_gain(cfg, k)
    sigma_dp = (k_tile ** 0.5) * (2.0 ** cfg.r_in / 8.0) * (2.0 ** (cfg.r_w - 1) / 2.0)
    gamma = target_frac * 2.0 ** (cfg.r_out - 1) / (g0 * sigma_dp)
    import math
    gamma = min(max(gamma, 1.0), float(cfg.max_gamma))
    return math.log2(gamma)


def init_cim_linear(key: jax.Array, k: int, n: int,
                    w_init_scale: Optional[float] = None,
                    cfg: Optional[CIMConfig] = None) -> Dict:
    """Init one CIM linear: fan-in-scaled weights plus the per-output-
    column ABN gain/offset (gamma seeded analytically when `cfg` is
    given, else unity)."""
    scale = w_init_scale if w_init_scale is not None else (1.0 / k) ** 0.5
    lg = 0.0 if cfg is None else analytic_log_gamma_init(k, cfg)
    return {
        "w": scale * jax.random.normal(key, (k, n), jnp.float32),
        "abn_log_gamma": jnp.full((n,), lg, jnp.float32),
        "abn_beta": jnp.zeros((n,), jnp.float32),
    }


def _code_gain(cfg: CIMConfig, k_dim: int) -> float:
    """Unity-gain codes-per-integer-dp (Eq. 7 collapsed, digital_ref).

    K > n_rows splits into the even row tiles of mapping.map_layer, so the
    swing (and hence g0) follows rows-per-tile — keeping this path in
    lockstep with the runtime engine's per-tile ADC configuration."""
    macro = cfg.macro
    if cfg.adaptive_swing:
        row_tiles = -(-k_dim // macro.n_rows)
        rows = -(-k_dim // row_tiles)
        units = macro.units_for_rows(rows)
    else:
        units = macro.n_units          # fixed full-array swing (baseline)
    n_dp = units * macro.rows_per_unit
    swing = macro.swing_efficiency(units)
    return digital_ref.adc_gain_factor(cfg.r_in, cfg.r_w, cfg.r_out, n_dp,
                                       swing, macro.alpha_adc())


def cim_linear_apply(params: Dict, x: jnp.ndarray, cfg: CIMConfig,
                     key: Optional[jax.Array] = None) -> jnp.ndarray:
    """y ~= x @ w, executed through the configured CIM path.

    x: (..., K).  Returns (..., N) dequantized activations.
    """
    if cfg.mode == "deploy":
        # serving path: weights stored as int8 CIM codes + per-channel
        # scale (quantize_params_for_serving); the dequant fuses into the
        # matmul on TPU, so weight HBM traffic is the int8 bytes.
        wq = params["w_q"].astype(x.dtype) * params["w_scale"].astype(x.dtype)
        return x @ wq
    w = params["w"]
    if cfg.mode == "bypass":
        return x @ w.astype(x.dtype)
    if cfg.mode == "fakequant":
        return _fakequant_forward(params, x, cfg, key)
    if cfg.mode == "sim":
        return _sim_forward(params, x, cfg, key)
    if cfg.mode == "engine":
        return _engine_forward(params, x, cfg, key)
    raise ValueError(f"unknown CIM mode {cfg.mode!r}")


def quantize_params_for_serving(params, r_w: int = 4):
    """Convert every CIM-linear leaf dict {w, abn_*} into the deployed form
    {w_q int8, w_scale f32(N,), abn_*}: the macro's odd-integer weight grid
    stored in its natural int8 container (4x less weight HBM than fp32
    masters, 2x less than bf16).  Embeddings/norms stay untouched."""
    from repro.core.quantization import quantize_weight

    def convert(node):
        if isinstance(node, dict) and "router" in node:
            # MoE expert banks: (L, E, D, F) / (L, E, F, D) raw arrays
            out = dict(node)
            for k in ("w_gate", "w_up", "w_down"):
                if k in out:
                    wq = quantize_weight(out.pop(k), r_w, axis=-2)
                    out[f"{k}_q"] = wq.q.astype(jnp.int8)
                    out[f"{k}_scale"] = jnp.squeeze(wq.scale, axis=-2)
            return out
        if isinstance(node, dict) and "w" in node and "abn_log_gamma" in node:
            # works on stacked (L, K, N) leaves too: per-(layer, channel)
            # scales over the reduction axis
            wq = quantize_weight(node["w"], r_w, axis=-2)
            out = {k: v for k, v in node.items() if k != "w"}
            out["w_q"] = wq.q.astype(jnp.int8)
            out["w_scale"] = jnp.squeeze(wq.scale, axis=-2)
            return out
        if isinstance(node, dict):
            return {k: convert(v) for k, v in node.items()}
        return node

    return convert(params)


def _fakequant_forward(params: Dict, x: jnp.ndarray, cfg: CIMConfig,
                       key: Optional[jax.Array]) -> jnp.ndarray:
    w = params["w"]
    k_dim, n = w.shape
    compute_dtype = x.dtype
    # entry barrier, mirrored by _engine_forward: both modes quantize the
    # identical input float and hand the identical output float back to
    # the (identically-fused) digital glue between projections
    x32 = rounding_barrier(x.astype(jnp.float32))

    aq: ActQuant = quantize_act(x32, cfg.r_in)
    wq = quantize_weight(w, cfg.r_w, axis=0)

    gamma = abn_lib.abn_gamma(
        abn_lib.ABNParams(params["abn_log_gamma"], params["abn_beta"]),
        gamma_bits=cfg.gamma_bits, max_gamma=cfg.max_gamma)
    g0 = _code_gain(cfg, k_dim)
    mid = 2.0 ** (cfg.r_out - 1)

    if cfg.noise.enabled and key is not None:
        key, k2 = jax.random.split(key)
        # residual SA offset in code units (static per layer call): sampled
        # per *physical* macro column and gathered per logical channel, so
        # channels beyond one col tile's budget reuse the same residues —
        # matching the engine noise path (and the chip, which has exactly
        # n_cols comparators however wide the layer is)
        res_v = nm.sample_column_residues(k2, n, cfg.r_w, cfg.noise,
                                          cfg.macro)
        lsb_v = cfg.macro.alpha_adc() * cfg.macro.vddh / 2.0 ** (cfg.r_out - 1)
        # volts -> codes: static-reciprocal + barrier keeps the offset on
        # the ADC-floor path pinned (mirrors the engine's _layer_noise)
        offset_codes = rounding_barrier(gamma * res_v
                                        * _static_reciprocal(lsb_v))
    else:
        offset_codes = 0.0

    # K > n_rows splits into row tiles, each with its own ADC conversion;
    # partial codes are dequantized and summed digitally by the host —
    # exactly the macro-tiling of core/mapping.py (even split_k_slices,
    # matching the runtime engine's schedule).
    row_tiles = -(-k_dim // cfg.macro.n_rows)
    # the materialized ADC gain: floor/dequant must see the identical
    # float in every fusion context (see quantization.rounding_barrier)
    gain = rounding_barrier(gamma * g0)
    zp = aq.zero / aq.scale
    dp_hat = jnp.zeros(x32.shape[:-1] + (n,), jnp.float32)
    for ks, ksz in mapping.split_k_slices(k_dim, row_tiles):
        ke = ks + ksz
        # integer dot product (DP array + MBIW stages); exact in fp32 for
        # one macro row-tile (|dp| <= 1152*255*15 < 2^24).
        dp = aq.q[..., ks:ke] @ wq.q[ks:ke, :]
        # zero-point: x = q*s + z -> the z*colsum term is per-channel and
        # constant: folded into the ABN offset *inside* the ADC floor
        # (beta_eff = beta + gamma*g0*zp_dp), exactly the chip's
        # signed-to-unsigned conversion + beta block — and exactly the
        # engine kernel's fold, which makes this path bit-exact with
        # mode="engine" under NO_NOISE.
        zp_dp = zp * jnp.sum(wq.q[ks:ke, :], axis=0)
        if cfg.noise.enabled and key is not None:
            key, k1 = jax.random.split(key)
            # thermal noise referred to dp units through the code gain
            # (single expression shared with the engine noise epilogue)
            dp = dp + nm.thermal_sigma_dp(cfg.noise, cfg.r_out, g0) \
                * jax.random.normal(k1, dp.shape)
        beta_eff = (params["abn_beta"] + offset_codes) \
            + rounding_barrier(gain * zp_dp)
        code = adc_quantize(dp, r_out=cfg.r_out, gain=gain,
                            beta_codes=beta_eff)
        dp_hat = dp_hat + (code - mid - params["abn_beta"]) / gain

    y = rounding_barrier(dp_hat * aq.scale * wq.scale.reshape(-1))
    return y.astype(compute_dtype)


def _engine_config(cfg: CIMConfig):
    """The runtime EngineConfig mirroring a layer-level CIMConfig (the
    one mapping every engine-mode entry point shares, so equal layer
    configs hit one program-cache entry)."""
    from repro.runtime import engine as rt
    return rt.EngineConfig(macro=cfg.macro, adaptive_swing=cfg.adaptive_swing,
                           gamma_bits=cfg.gamma_bits, max_gamma=cfg.max_gamma,
                           noise=cfg.noise, sharding=cfg.sharding)


def _engine_forward(params: Dict, x: jnp.ndarray, cfg: CIMConfig,
                    key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Route the layer through the precision-scalable inference runtime.

    Inference only (no STE gradients); the layer fetches its compiled
    program from the module-level cache of runtime/program.py (keyed on
    the batch-bucketed LayerSpec + EngineConfig — planning happens once
    per distinct (shape, CIMConfig), never per call) and dispatches the
    precision-specialized Pallas kernel variant through the program's
    bucket executable.  cfg.noise propagates into the engine's
    noise-injected mode (requires `key`)."""
    # imported lazily: runtime.engine depends on this module for init
    from repro.runtime.program import DEFAULT_BUCKETS, compile_program

    k_dim, n = params["w"].shape
    lead = x.shape[:-1]
    # entry/exit barriers delimit the projection from the digital glue
    # around it: the glue between two projections then forms the same
    # isolated subgraph in an engine-mode and a fakequant-mode model, so
    # XLA fuses (and rounds) it identically in both — the stack-level
    # half of the bit-exactness contract (see _fakequant_forward)
    x2 = rounding_barrier(x.reshape((-1, k_dim)))
    bucket = DEFAULT_BUCKETS.bucket_for(x2.shape[0])
    spec = mapping.LayerSpec(m=bucket, k=k_dim, n=n, r_in=cfg.r_in,
                             r_w=cfg.r_w, r_out=cfg.r_out)
    prog = compile_program([spec], _engine_config(cfg))
    segments = None
    if cfg.isolate_rows and lead:
        # one segment per leading batch row: (B, S, K) -> B segments of
        # S rows each, so fused rows quantize exactly as served alone
        segments = jnp.repeat(jnp.arange(lead[0], dtype=jnp.int32),
                              x2.shape[0] // lead[0])
    y = rounding_barrier(prog.serve([params], x2, key, segments=segments))
    return y.reshape(lead + (n,)).astype(x.dtype)


def _sim_forward(params: Dict, x: jnp.ndarray, cfg: CIMConfig,
                 key: Optional[jax.Array]) -> jnp.ndarray:
    """Voltage-domain path: tile per mapping.py and run the behavioural
    macro.  No gradients (inference/fidelity only)."""
    w = params["w"]
    k_dim, n = w.shape
    lead = x.shape[:-1]
    x2 = x.reshape((-1, k_dim)).astype(jnp.float32)

    aq = quantize_act(x2, cfg.r_in)
    wq = quantize_weight(w, cfg.r_w, axis=0)
    planes_full = digital_ref.encode_weight_planes(
        wq.q.astype(jnp.int32), cfg.r_w)                  # (r_w, K, N)

    gamma = abn_lib.abn_gamma(
        abn_lib.ABNParams(params["abn_log_gamma"], params["abn_beta"]),
        gamma_bits=cfg.gamma_bits, max_gamma=cfg.max_gamma)
    spec = mapping.LayerSpec(m=x2.shape[0], k=k_dim, n=n, r_in=cfg.r_in,
                             r_w=cfg.r_w, r_out=cfg.r_out)
    mp = mapping.map_layer(spec, cfg.macro)
    mid = 2.0 ** (cfg.r_out - 1)
    lsb_v = cfg.macro.alpha_adc() * cfg.macro.vddh / 2.0 ** (cfg.r_out - 1)
    beta_v = params["abn_beta"] * lsb_v / gamma           # code -> volts

    # static per-physical-column SA residues, sampled once per layer and
    # shared by every row tile (the comparators don't change between
    # tiles) — same column mapping as the fakequant and engine paths
    if cfg.noise.enabled and key is not None:
        key, ksa = jax.random.split(key)
        sa_offset_v = nm.sample_column_residues(ksa, n, cfg.r_w, cfg.noise,
                                                cfg.macro)
    else:
        sa_offset_v = jnp.zeros((n,))

    dp_hat = jnp.zeros((x2.shape[0], n), jnp.float32)
    for (ks, ksz) in mapping.split_k_slices(k_dim, mp.row_tiles):
        xs = aq.q[:, ks:ks + ksz]
        ps = planes_full[:, ks:ks + ksz, :]
        if key is not None:
            key, sub = jax.random.split(key)
        else:
            sub = None
        code = cim_macro_forward(
            xs, ps, r_in=cfg.r_in, r_out=cfg.r_out, gamma=gamma,
            beta_v=beta_v, cfg=cfg.macro, noise=cfg.noise, key=sub,
            sa_offset_v=sa_offset_v)
        units = cfg.macro.units_for_rows(ksz)
        n_dp = units * cfg.macro.rows_per_unit
        g0 = digital_ref.adc_gain_factor(
            cfg.r_in, cfg.r_w, cfg.r_out, n_dp,
            cfg.macro.swing_efficiency(units), cfg.macro.alpha_adc())
        dp_hat = dp_hat + (code.astype(jnp.float32) + 0.5 - mid
                           - params["abn_beta"]) / (gamma * g0)
    y = dp_hat * aq.scale * wq.scale.reshape(-1)
    y = y + aq.zero * jnp.sum(wq.q * wq.scale, axis=0)    # zero-point term
    return y.reshape(lead + (n,)).astype(x.dtype)


def cim_conv2d_apply(params: Dict, x: jnp.ndarray, cfg: CIMConfig,
                     stride: int = 1, padding=1,
                     key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Conv2D through the CIM stack (the accelerator's stage (ii)).

    x: (B, H, W, C_in); params["w"]: (kh*kw*C_in, C_out) flattened filters.
    `padding` accepts an int, "SAME"/"VALID", or explicit per-edge pairs
    (mapping.resolve_padding).  mode="engine" plans the conv natively (the
    runtime performs the im2col streaming itself); every other mode
    materializes the patch tensor and detours through cim_linear_apply.
    """
    # lazy: runtime.engine lazily imports this module for init
    from repro.runtime.engine import im2col_patches

    k_flat, c_out = params["w"].shape
    kh = kw = int(round((k_flat // x.shape[-1]) ** 0.5))
    assert kh * kw * x.shape[-1] == k_flat, (kh, kw, x.shape, k_flat)
    b, h, w, c_in = x.shape
    spec = mapping.conv_layer_spec(
        batch=b, h=h, w=w, c_in=c_in, c_out=c_out, kh=kh, kw=kw,
        stride=stride, padding=padding,
        r_in=cfg.r_in, r_w=cfg.r_w, r_out=cfg.r_out)
    if cfg.mode == "engine":
        return _engine_conv_forward(params, x, cfg, spec, key)
    patches = im2col_patches(x, spec.conv)                # (B, OH, OW, kh*kw*C)
    return cim_linear_apply(params, patches, cfg, key)


def _engine_conv_forward(params: Dict, x: jnp.ndarray, cfg: CIMConfig,
                         spec: mapping.LayerSpec,
                         key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Route a conv layer through the runtime's native conv front-end via
    the program cache: the conv spec is rebuilt at the batch bucket, the
    compiled program is a cache hit after the first call for a given
    (geometry, CIMConfig), and dispatch pads/slices the batch through the
    bucket executable (cfg.noise propagates into the engine's
    noise-injected mode)."""
    from repro.runtime.program import DEFAULT_BUCKETS, compile_program

    g = spec.conv
    bucket = DEFAULT_BUCKETS.bucket_for(x.shape[0])
    if bucket != g.batch:
        spec = mapping.conv_layer_spec(
            batch=bucket, h=g.h, w=g.w, c_in=g.c_in, c_out=g.c_out,
            kh=g.kh, kw=g.kw, stride=g.stride, padding=g.padding,
            r_in=spec.r_in, r_w=spec.r_w, r_out=spec.r_out)
    prog = compile_program([spec], _engine_config(cfg))
    segments = None
    if cfg.isolate_rows:
        # one segment per batch image (the engine repeats ids over the
        # conv's out_h*out_w GEMM rows itself)
        segments = jnp.arange(x.shape[0], dtype=jnp.int32)
    return prog.serve([params], x, key, segments=segments).astype(x.dtype)
