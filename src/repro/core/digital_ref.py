"""Exact integer digital-equivalent of the IMAGINE macro datapath.

This is the ground-truth oracle for everything else in the repo:
  * the voltage-domain behavioural model (core/cim_macro.py) must agree with
    it to <1 ADC LSB when analog non-idealities are disabled;
  * the Pallas kernel (kernels/cim_mbiw) must agree with it bit-exactly;
  * the fake-quant training path (core/cim_layers.py) uses its forward.

Numerics
--------
Inputs  X : unsigned integers in [0, 2^r_in - 1]            (shape [..., K])
Weights   : +/-1 bit-planes S[p] in {-1,+1}, p=0..r_w-1      (shape [r_w,K,N])
            encoded value  w = sum_p 2^p * S[p]  (odd ints in +/-(2^r_w - 1))
Dot product  dp = X . w,   |dp| <= K * (2^r_in - 1) * (2^r_w - 1)

The analog chain (Eqs. 1,4,5,6,7 of the paper) maps dp to an ADC code:

    dV     = VDDL * swing * dp / (N_dp * 2^(r_in + r_w))        # DP+MBIW
    code   = floor( 2^(r_out-1)
                    + gamma * dV / (alpha_adc * VDDH / 2^(r_out-1))
                    + beta_codes )                               # Eq. (7)
    with VDDH = 2*VDDL this collapses to the pure-integer relation

    code = clip( floor( 2^(r_out-1)
                        + gamma * swing / (2*alpha_adc)
                          * dp * 2^(r_out-1) / (N_dp * 2^(r_in+r_w))
                        + beta_codes ),  0, 2^r_out - 1 )

`swing` = N_dp * alpha_eff  (swing efficiency, 1.0 for an ideal array) and
`alpha_adc` are taken from CIMMacroConfig; `n_dp` is the number of *connected*
rows after the serial-split configuration, which is what makes the operator
swing-adaptive: for a layer using fewer rows, n_dp shrinks and the same dp
produces a proportionally larger code swing.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO


# ---------------------------------------------------------------------------
# weight encoding
# ---------------------------------------------------------------------------

def encode_weight_planes(w_int: jnp.ndarray, r_w: int) -> jnp.ndarray:
    """Encode odd integers w in [-(2^r_w - 1), 2^r_w - 1] into +/-1 planes.

    Uses u = (w + (2^r_w - 1)) / 2 in [0, 2^r_w - 1]; plane p is 2*bit_p(u)-1.
    Returns int8 array of shape (r_w, *w.shape).
    """
    full = 2**r_w - 1
    u = (w_int.astype(jnp.int32) + full) // 2
    planes = [(2 * ((u >> p) & 1) - 1).astype(jnp.int8) for p in range(r_w)]
    return jnp.stack(planes, axis=0)


def decode_weight_planes(planes: jnp.ndarray) -> jnp.ndarray:
    """Inverse of encode_weight_planes: w = sum_p 2^p * S[p]."""
    r_w = planes.shape[0]
    scale = (2 ** jnp.arange(r_w, dtype=jnp.int32)).reshape(
        (r_w,) + (1,) * (planes.ndim - 1))
    return jnp.sum(planes.astype(jnp.int32) * scale, axis=0)


def quantize_weight_odd(w_int: jnp.ndarray, r_w: int) -> jnp.ndarray:
    """Snap integers in [-(2^r_w-1), 2^r_w-1] to the representable odd grid."""
    full = 2**r_w - 1
    w = jnp.clip(w_int, -full, full)
    # nearest odd integer: 2*floor(w/2)+1 rounds {2k,2k+1} -> 2k+1
    return (2 * jnp.floor_divide(w, 2) + 1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# integer dot-product (the DP array + MBIW stages)
# ---------------------------------------------------------------------------

def bitplane_dot(x_uint: jnp.ndarray, planes: jnp.ndarray) -> jnp.ndarray:
    """dp = X . W with W decoded from its +/-1 bit-planes.

    x_uint : (..., K) unsigned ints < 2^r_in
    planes : (r_w, K, N) +/-1
    returns: (..., N) int32
    """
    return x_uint.astype(jnp.int32) @ decode_weight_planes(planes)


def bitplane_dot_serial(x_uint: jnp.ndarray, planes: jnp.ndarray, r_in: int
                        ) -> jnp.ndarray:
    """Literal input-serial, weight-parallel evaluation (matches the macro's
    MBIW sequencing): dp = sum_k 2^k sum_p 2^p (X[k] . S[p]).
    Provided for the kernel oracle; equal to `x @ decode(planes)`."""
    x = x_uint.astype(jnp.int32)
    r_w = planes.shape[0]
    acc = jnp.zeros(x.shape[:-1] + (planes.shape[-1],), jnp.int32)
    for k in range(r_in):
        x_bit = ((x >> k) & 1)
        per_bit = jnp.zeros_like(acc)
        for p in range(r_w):
            per_bit = per_bit + (2**p) * (x_bit @ planes[p].astype(jnp.int32))
        acc = acc + (2**k) * per_bit
    return acc


# ---------------------------------------------------------------------------
# DSCI-ADC (Eq. 7) in code space
# ---------------------------------------------------------------------------

def adc_gain_factor(r_in: int, r_w: int, r_out: int, n_dp: int,
                    swing: float = 1.0, alpha_adc: float = 1.0) -> float:
    """Codes-per-unit-dp of the full chain at gamma=1 (see module docstring)."""
    return swing / (2.0 * alpha_adc) * (2.0 ** (r_out - 1)) / (
        n_dp * 2.0 ** (r_in + r_w))


def dsci_adc_code(dp: jnp.ndarray, *, r_in: int, r_w: int, r_out: int,
                  n_dp: int, gamma: jnp.ndarray | float = 1.0,
                  beta_codes: jnp.ndarray | float = 0.0,
                  swing: float = 1.0, alpha_adc: float = 1.0) -> jnp.ndarray:
    """Eq. (7): rescale dp into ADC codes with ABN gain/offset and floor."""
    g = adc_gain_factor(r_in, r_w, r_out, n_dp, swing, alpha_adc)
    mid = 2 ** (r_out - 1)
    code = jnp.floor(mid + gamma * g * dp.astype(jnp.float32) + beta_codes)
    return jnp.clip(code, 0, 2**r_out - 1).astype(jnp.int32)


def dequantize_code(code: jnp.ndarray, *, r_in: int, r_w: int, r_out: int,
                    n_dp: int, gamma: jnp.ndarray | float = 1.0,
                    beta_codes: jnp.ndarray | float = 0.0,
                    swing: float = 1.0, alpha_adc: float = 1.0
                    ) -> jnp.ndarray:
    """Map ADC codes back to dp units (inverse of the ABN-scaled ADC)."""
    g = adc_gain_factor(r_in, r_w, r_out, n_dp, swing, alpha_adc)
    mid = 2 ** (r_out - 1)
    return (code.astype(jnp.float32) + 0.5 - mid - beta_codes) / (gamma * g)


# ---------------------------------------------------------------------------
# end-to-end reference macro
# ---------------------------------------------------------------------------

def cim_matmul_ref(x_uint: jnp.ndarray, planes: jnp.ndarray, *, r_in: int,
                   r_out: int, gamma: jnp.ndarray | float = 1.0,
                   beta_codes: jnp.ndarray | float = 0.0,
                   cfg: CIMMacroConfig = DEFAULT_MACRO,
                   n_rows_used: Optional[int] = None,
                   ideal: bool = False) -> jnp.ndarray:
    """Digital-equivalent of one macro evaluation.

    x_uint : (..., K) unsigned ints < 2^r_in, K <= cfg.n_rows
    planes : (r_w, K, N) +/-1 weight bit-planes
    gamma/beta_codes : scalars or (N,) per-channel ABN parameters
    ideal  : if True, swing=1 / alpha_adc=1 (parasitic-free); otherwise the
             serial-split swing efficiency for ceil(K/36) units is used.
    returns: (..., N) int32 ADC codes in [0, 2^r_out - 1]
    """
    k_dim = x_uint.shape[-1]
    r_w = planes.shape[0]
    n_rows_used = k_dim if n_rows_used is None else n_rows_used
    units = cfg.units_for_rows(n_rows_used)
    n_dp = units * cfg.rows_per_unit
    swing = 1.0 if ideal else cfg.swing_efficiency(units)
    alpha_adc = 1.0 if ideal else cfg.alpha_adc()
    dp = x_uint.astype(jnp.int32) @ decode_weight_planes(planes)
    return dsci_adc_code(dp, r_in=r_in, r_w=r_w, r_out=r_out, n_dp=n_dp,
                         gamma=gamma, beta_codes=beta_codes, swing=swing,
                         alpha_adc=alpha_adc)
