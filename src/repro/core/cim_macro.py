"""Voltage-domain behavioural model of the IMAGINE CIM-SRAM macro.

Implements the full analog pipeline of Sec. III in simulation units of volts:

  1. swing-adaptive charge-based DP      (Eq. 1/4, serial-split DPL)
  2. MBIW input-serial accumulation      (Eq. 5, alpha_mb charge sharing)
  3. MBIW weight-parallel combination    (Eq. 6, pairwise LSB->MSB sharing)
  4. DSCI-ADC with in-conversion ABN     (Eq. 7, SAR loop with gamma 'zoom'
                                          and 5b offset), SA offset +
                                          7b calibration residue

With `noise=NO_NOISE` the model is *exactly* (to float32 rounding) the
digital reference in core/digital_ref.py — asserted by tests.

Shapes: x_uint (B, K) unsigned < 2^r_in; planes (r_w, K, N) in {-1,+1}.
The model evaluates ONE macro tile (K <= 1152, N <= 64 output channels when
r_w=4); layer-level tiling lives in core/mapping.py.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO
from repro.core import noise_model as nm
from repro.core.noise_model import NoiseConfig, NO_NOISE


def dp_bit_voltage(x_bit: jnp.ndarray, plane_dot: jnp.ndarray,
                   alpha_eff: float, settle: float,
                   cfg: CIMMacroConfig) -> jnp.ndarray:
    """DPL deviation (from the VDDL precharge) after one single-bit DP.

    plane_dot : (B, N) = sum_i x_bit_i * s_i  already computed by caller
    """
    del x_bit
    return settle * alpha_eff * cfg.vddl * plane_dot


def mbiw_input_accumulate(per_bit_dev: jnp.ndarray, *, r_in: int,
                          noise: NoiseConfig, cfg: CIMMacroConfig,
                          key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Eq. (5): accumulate per-input-bit DP deviations, LSB first, through
    alpha_mb ~= 1/2 charge sharing.  per_bit_dev: (r_in, B, N) volts.

    Returns the accumulated deviation from VDDL (B, N)."""
    alpha_mb = cfg.alpha_mb()
    v_acc = jnp.zeros_like(per_bit_dev[0])        # deviation from VDDL
    for k in range(r_in):
        v_in = per_bit_dev[k]
        if noise.enabled:
            v_acc_next = alpha_mb * v_acc + (1.0 - alpha_mb) * v_in
            v_acc_next = v_acc_next + nm.charge_injection_error(
                v_in + cfg.vddl, v_acc + cfg.vddl, noise, cfg)
            v_acc = v_acc_next
        else:
            v_acc = alpha_mb * v_acc + (1.0 - alpha_mb) * v_in
    if noise.enabled:
        v_acc = v_acc - nm.leakage_droop(r_in, cfg.t_dp_ns, noise)
        if key is not None:
            v_acc = v_acc + nm.sample_thermal(key, v_acc.shape, noise, cfg)
    return v_acc


def mbiw_weight_combine(per_plane_dev: jnp.ndarray, r_w: int) -> jnp.ndarray:
    """Eq. (6): pairwise inter-column charge sharing, LSB -> MSB.

    per_plane_dev: (r_w, B, N) accumulated deviations per weight plane.
    The LSB plane is first halved against the VDDL-precharged node, then each
    sharing with the next plane halves again:
        V = sum_p 2^(p - r_w) * V_p    (deviation units)."""
    v = 0.5 * per_plane_dev[0]                    # self-weighting of the LSB
    for p in range(1, r_w):
        v = 0.5 * (v + per_plane_dev[p])
    return v


def dsci_adc(v_dev: jnp.ndarray, *, r_out: int, gamma: jnp.ndarray,
             beta_v: jnp.ndarray, sa_offset_v: jnp.ndarray,
             cfg: CIMMacroConfig, noise: NoiseConfig = NO_NOISE,
             key: Optional[jax.Array] = None) -> jnp.ndarray:
    """DSCI SAR conversion with the ABN gamma 'zoom' (Eq. 7).

    v_dev      : (B, N) DPL deviation from VDDL at conversion start
    gamma      : scalar or (N,) ABN gain (reference-ladder zoom)
    beta_v     : scalar or (N,) ABN offset *in volts on the DPL*
    sa_offset_v: (N,) residual comparator offset after calibration
    returns    : (B, N) int32 codes in [0, 2^r_out - 1]

    The SAR loop compares the (offset-shifted) residue against binary-scaled
    thresholds whose magnitude is divided by gamma — the 'zoom' — and whose
    steps can carry ladder mismatch (gamma-dependent INL, Fig. 13).
    """
    alpha_adc = cfg.alpha_adc()
    v = v_dev + beta_v + sa_offset_v              # Eq. (7) numerator terms
    # one ADC code in volts, after the zoom:
    lsb_v = alpha_adc * cfg.vddh / (gamma * 2.0 ** (r_out - 1))
    mid = 2 ** (r_out - 1)
    if noise.enabled and key is not None:
        # ladder mismatch: per-step relative error, grows with gamma since
        # the absolute step shrinks but the mismatch floor does not.  The
        # per-step draw is shared across columns; gamma (scalar or (N,))
        # only scales its magnitude — so per-channel ABN gains broadcast.
        step_sigma = 0.0015 * jnp.sqrt(jnp.asarray(gamma, jnp.float32))
        eta = jax.random.normal(key, (r_out,))
    else:
        step_sigma = jnp.float32(0.0)
        eta = jnp.zeros((r_out,))
    code = jnp.zeros(v.shape, jnp.int32)
    for k in range(r_out - 1, -1, -1):            # MSB first
        trial = code + (1 << k)
        thresh = (trial.astype(jnp.float32) - mid) * lsb_v \
            * (1.0 + step_sigma * eta[r_out - 1 - k])
        code = jnp.where(v >= thresh, trial, code)
    return jnp.clip(code, 0, 2 ** r_out - 1)


def cim_macro_forward(
    x_uint: jnp.ndarray, planes: jnp.ndarray, *, r_in: int, r_out: int,
    gamma: jnp.ndarray | float = 1.0, beta_v: jnp.ndarray | float = 0.0,
    cfg: CIMMacroConfig = DEFAULT_MACRO, noise: NoiseConfig = NO_NOISE,
    key: Optional[jax.Array] = None,
    sa_offset_v: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """End-to-end analog evaluation of one macro tile.

    x_uint : (B, K) unsigned ints < 2^r_in  (K <= cfg.n_rows)
    planes : (r_w, K, N) in {-1, +1}
    """
    b, k_dim = x_uint.shape
    r_w, k2, n = planes.shape
    assert k_dim == k2, (k_dim, k2)
    units = cfg.units_for_rows(k_dim)
    alpha_eff = cfg.alpha_eff(units)
    settle = nm.settle_fraction(units, cfg.t_dp_ns, noise)
    gamma = jnp.asarray(gamma, jnp.float32)
    beta_v = jnp.asarray(beta_v, jnp.float32)

    if sa_offset_v is None:
        if noise.enabled and key is not None:
            key, sub = jax.random.split(key)
            raw = nm.sample_sa_offsets(sub, n, noise, cfg)
            sa_offset_v = nm.calibration_residue(raw, noise, cfg)
        else:
            sa_offset_v = jnp.zeros((n,))

    x = x_uint.astype(jnp.float32)
    # per (input bit, weight plane) single-bit DPs
    per_plane = []
    for p in range(r_w):
        per_bit = []
        s = planes[p].astype(jnp.float32)         # (K, N)
        for kbit in range(r_in):
            x_bit = jnp.floor(x / 2 ** kbit) % 2.0
            per_bit.append(dp_bit_voltage(x_bit, x_bit @ s, alpha_eff,
                                          settle, cfg))
        per_bit = jnp.stack(per_bit)              # (r_in, B, N)
        if key is not None:
            key, sub = jax.random.split(key)
        else:
            sub = None
        per_plane.append(mbiw_input_accumulate(per_bit, r_in=r_in,
                                               noise=noise, cfg=cfg, key=sub))
    v_mbiw = mbiw_weight_combine(jnp.stack(per_plane), r_w)   # (B, N)

    if key is not None:
        key, sub = jax.random.split(key)
    else:
        sub = None
    return dsci_adc(v_mbiw, r_out=r_out, gamma=gamma, beta_v=beta_v,
                    sa_offset_v=sa_offset_v, cfg=cfg, noise=noise, key=sub)
