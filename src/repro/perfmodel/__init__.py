from repro.perfmodel.macro_perf import (AcceleratorPerfModel, CyclePerf,  # noqa
                                        EnergyModel, schedule_report)
