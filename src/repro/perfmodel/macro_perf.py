"""Cycle + energy model of the IMAGINE macro and accelerator (Sec. IV-V).

Cycle model — Eqs. (8), (9), (10) verbatim:
    N_stall  = 1 + N_cim + ceil(r_out*C_out / BW)             serial
    N_in     = (N_cim-1) + ceil(K*r_in*C_in / BW)             input-dominated
    N_out    = N_cim + ceil(r_out*C_out / BW) - 1             output-dominated

Timing (Sec. III): a CIM evaluation takes r_in DP+accumulate phases
(2*T_dp each), (r_w-1) inter-column sharing phases, and r_out SAR cycles.

Energy — physics-grounded switched-capacitance scaling, calibrated to the
paper's measured anchors (documented inline):
  * E_dp scales with the *connected* DPL capacitance (serial-split: fewer
    units connected -> proportionally less charge moved; Fig. 6c);
  * E_adc scales with r_out (SAR cycles) + the reference-ladder DC burn;
  * anchors: 1.2 POPS/W raw @ 8b in/out 1b w (=> E/cycle ~ 590 pJ at full
    array), 8 POPS/W raw @ 1b (=> ~74 pJ), macro 150 TOPS/W and system
    40 TOPS/W @ 8b-normalized (Table I).
All reported TOPS/W are MODEL OUTPUTS anchored to silicon measurements, not
measurements — stated in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO
from repro.core.mapping import LayerSpec, MacroMapping, map_layer

BW_BITS = 128                      # LMEM I/O bandwidth per cycle (Sec. IV)


@dataclasses.dataclass(frozen=True)
class CyclePerf:
    n_cim: int
    n_in: int
    n_out: int
    n_stall: int
    cycles_per_output: int         # pipelined: max(N_cim, N_in, N_out)
    cycles_serial: int


def cim_eval_time_ns(r_in: int, r_w: int, r_out: int,
                     cfg: CIMMacroConfig = DEFAULT_MACRO) -> float:
    """One macro evaluation (Sec. III.C/D phase sequence)."""
    t_inputs = r_in * 2.0 * cfg.t_dp_ns          # DP + accumulate per bit
    t_weights = max(r_w - 1, 0) * cfg.t_dp_ns    # pairwise column sharing
    t_adc = r_out * cfg.t_adc_bit_ns             # SAR decision+update
    return t_inputs + t_weights + t_adc


def cycle_model(spec: LayerSpec, *, clock_ns: float = 10.0,
                cfg: CIMMacroConfig = DEFAULT_MACRO) -> CyclePerf:
    """Eqs. (8)-(10) for one output-map value of a conv layer."""
    if spec.conv is not None:           # conv-tagged spec: exact geometry
        k = spec.conv.kh
        c_in = spec.conv.c_in
    else:
        k = spec.kernel[0]
        c_in = max(spec.k // (spec.kernel[0] * spec.kernel[1]), 1)
    n_cim = max(1, math.ceil(cim_eval_time_ns(spec.r_in, spec.r_w,
                                              spec.r_out, cfg) / clock_ns))
    n_in = (n_cim - 1) + math.ceil(k * spec.r_in * c_in / BW_BITS)
    n_out = n_cim + math.ceil(spec.r_out * spec.n / BW_BITS) - 1
    n_stall = 1 + n_cim + math.ceil(spec.r_out * spec.n / BW_BITS)
    return CyclePerf(
        n_cim=n_cim, n_in=n_in, n_out=n_out, n_stall=n_stall,
        cycles_per_output=max(n_cim, n_in, n_out),
        cycles_serial=n_in + n_stall)


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    cfg: CIMMacroConfig = DEFAULT_MACRO
    # calibrated constants (see module docstring):
    e_dp_full_pj: float = 31.0     # per input bit, full 32-unit array
    e_adc_pj: float = 28.6         # per SAR bit, all 256 columns
    e_ladder_pj: float = 14.0      # ladder DC + control, per evaluation
    e_digital_per_bit_pj: float = 0.45  # LMEM+datapath per transferred bit

    def e_dp_pj(self, n_units_on: int, r_in: int) -> float:
        """DP energy: switched capacitance of the *connected* DPL section."""
        c = self.cfg
        c_full = c.n_rows * c.c_c + c.n_units * c.c_par_per_unit + c.c_load_adc
        c_on = (n_units_on * c.rows_per_unit * c.c_c
                + n_units_on * c.c_par_per_unit + c.c_load_adc)
        return self.e_dp_full_pj * r_in * (c_on / c_full)

    def e_adc_total_pj(self, r_out: int, gamma: float = 1.0) -> float:
        # gamma>1 slightly raises ladder settle energy (compressed levels
        # are taken lower on the ladder; Fig. 18c shows a mild EE dip)
        return r_out * self.e_adc_pj + self.e_ladder_pj * (
            1.0 + 0.05 * math.log2(max(gamma, 1.0)))

    def macro_energy_pj(self, spec: LayerSpec, mp: MacroMapping,
                        gamma: float = 1.0) -> float:
        """One macro evaluation at the mapped configuration."""
        return (self.e_dp_pj(mp.units_per_tile, spec.r_in)
                + max(spec.r_w - 1, 0) * 0.25 * self.e_dp_pj(
                    mp.units_per_tile, 1)
                + self.e_adc_total_pj(spec.r_out, gamma))

    def macro_ops_per_eval(self, spec: LayerSpec, mp: MacroMapping,
                           normalize_8b: bool = False) -> float:
        """MAC*2 ops per evaluation (active rows x mapped channels)."""
        ch = min(spec.n, self.cfg.n_blocks * max(
            1, self.cfg.cols_per_block // spec.r_w))
        ops = 2.0 * mp.rows_per_tile * ch
        if normalize_8b:
            ops *= (spec.r_in / 8.0) * (spec.r_w / 8.0)
        return ops

    def macro_tops_per_watt(self, spec: LayerSpec, *, gamma: float = 1.0,
                            normalize_8b: bool = False) -> float:
        mp = map_layer(spec, self.cfg)
        e = self.macro_energy_pj(spec, mp, gamma) * 1e-12
        ops = self.macro_ops_per_eval(spec, mp, normalize_8b)
        return ops / e / 1e12

    def macro_throughput_tops(self, spec: LayerSpec, *,
                              clock_ns: float = 10.0,
                              normalize_8b: bool = False) -> float:
        mp = map_layer(spec, self.cfg)
        t = cim_eval_time_ns(spec.r_in, spec.r_w, spec.r_out, self.cfg)
        ops = self.macro_ops_per_eval(spec, mp, normalize_8b)
        return ops / (t * 1e-9) / 1e12


def schedule_report(plan, *, clock_ns: float = 10.0, pipelined: bool = True,
                    gamma: float = 1.0, program=None,
                    point=None) -> Dict[str, object]:
    """Cycle/energy estimates for a runtime engine schedule.

    `plan` is a runtime.engine.NetworkPlan (duck-typed: only
    `plan.layers[i].spec` / `.precision` / `.shard` and `plan.cfg.noise` /
    `.sharding` are read, so there is no perfmodel -> runtime import
    cycle).  Returns per-layer reports, per-precision aggregates keyed
    "r{r_in}x{r_w}b", schedule totals, and an echo of the schedule's noise
    settings (so a Monte-Carlo accuracy report and its perf numbers always
    carry the operating point they were taken at) — the model behind the
    paper's Fig. 22 precision-scaling curves, applied to an executable
    schedule instead of a lone macro.

    `program` (optional, duck-typed on `.stats()`/`.buckets`) is the
    compiled runtime.program.CIMProgram executing the plan: when given,
    the report echoes its compile/cache observability —
    report["program"] = {plans_built, executables_compiled, bucket
    hit/miss counters, the bucket ladder config} — so a perf number always
    carries the amortization state it was measured under.

    Sharded plans (plan.cfg.sharding set) additionally report the device
    partition: per-layer `rep["shard"]` carries the kind ("col" tiles vs
    "rows" of the GEMM M dim), `macro_evals_per_device` (the critical-path
    macro invocations one device performs) and `parallel_efficiency`
    (useful work / devices x per-device work — 1.0 for an even split);
    the report totals gain the same two columns plus a "sharding" echo.

    Autotuned plans (layers with `lp.blocks` set or a non-automatic shard
    kind — see repro.tuner) additionally carry `rep["tune"]`: the chosen
    (bm, bn, bk) blocks and shard kind, plus the roofline model's
    predicted cost next to the heuristic schedule's cost.

    `point` (optional) names the serving operating point the schedule was
    taken at (a precision-ladder rung such as "quality"/"throughput");
    when given, report["operating_point"] echoes the name next to the
    schedule totals so downstream serving telemetry
    (`InflightScheduler.point_report`, Fig. 22 rows) always carries the
    projected TOPS/W of the point it dispatched.
    """
    noise = getattr(getattr(plan, "cfg", None), "noise", None)
    if noise is not None and noise.enabled:
        noise_echo = dict(dataclasses.asdict(noise))
    else:
        noise_echo = {"enabled": False}
    sharding = getattr(getattr(plan, "cfg", None), "sharding", None)
    ap = AcceleratorPerfModel(clock_ns=clock_ns)
    layers = []
    per_prec: Dict[str, Dict[str, float]] = {}
    tot_ops = tot_ops8 = tot_e = tot_t = 0.0
    tot_evals_dev = 0
    for lp in plan.layers:
        rep = ap.layer_report(lp.spec, gamma=gamma, pipelined=pipelined)
        if hasattr(lp, "macro_evals"):      # planned (k, n) tiles per M-row
            rep["macro_evals_schedule"] = lp.macro_evals
        shard = getattr(lp, "shard", None)
        if shard is not None:
            # critical-path macro invocations one device performs: col
            # sharding splits the col tiles, row sharding splits the M rows
            row_tiles = len(lp.k_slices)
            if shard.kind == "col":
                evals_dev = row_tiles * shard.tiles_per_device * lp.spec.m
            else:
                evals_dev = lp.macro_evals * shard.rows_per_device
            rep["shard"] = {
                "kind": shard.kind,
                "devices": shard.devices,
                "macro_evals_per_device": evals_dev,
                "parallel_efficiency": shard.efficiency,
            }
            tot_evals_dev += evals_dev
        blocks = getattr(lp, "blocks", None)
        tuned_kind = None
        if shard is not None and hasattr(lp, "mp"):
            auto = "col" if lp.mp.col_tiles >= shard.devices else "rows"
            if shard.kind != auto:
                tuned_kind = shard.kind
        if blocks is not None or tuned_kind is not None:
            # this layer carries an autotuned schedule: echo the chosen
            # blocks/kind and the roofline model's predicted-vs-heuristic
            # cost.  Lazy import — repro.tuner imports this module, so a
            # top-level import would cycle.
            from repro.tuner import cost as _tc
            from repro.tuner import search as _ts
            cfg = getattr(plan, "cfg", None)
            macro_cfg = getattr(cfg, "macro", DEFAULT_MACRO)
            devices = shard.devices if shard is not None else 1
            heur = _ts.heuristic_choice(lp.spec, cfg, macro_cfg)
            chosen = _tc.ScheduleChoice(*(blocks or heur.blocks),
                                        shard_kind=tuned_kind)
            rep["tune"] = {
                "blocks": tuple(blocks) if blocks is not None
                else heur.blocks,
                "shard_kind": shard.kind if shard is not None else None,
                "predicted_s": _tc.layer_cost(
                    lp.spec, chosen, devices=devices,
                    macro=macro_cfg).total_s,
                "heuristic_s": _tc.layer_cost(
                    lp.spec, heur, devices=devices,
                    macro=macro_cfg).total_s,
            }
        if noise_echo["enabled"]:
            rep["noise"] = dict(noise_echo)   # per-layer copy, no aliasing
        layers.append(rep)
        ops = rep["tops"] * 1e12 * rep["time_s"]
        ops8 = rep["tops_8b_norm"] * 1e12 * rep["time_s"]
        e = rep["macro_energy_j"] + rep["digital_energy_j"]
        key = f"r{lp.spec.r_in}x{lp.spec.r_w}b"
        agg = per_prec.setdefault(
            key, {"ops": 0.0, "energy_j": 0.0, "time_s": 0.0, "layers": 0})
        agg["ops"] += ops
        agg["energy_j"] += e
        agg["time_s"] += rep["time_s"]
        agg["layers"] += 1
        tot_ops += ops
        tot_ops8 += ops8
        tot_e += e
        tot_t += rep["time_s"]
    for agg in per_prec.values():
        agg["tops"] = agg["ops"] / max(agg["time_s"], 1e-30) / 1e12
        agg["tops_per_w"] = agg["ops"] / max(agg["energy_j"], 1e-30) / 1e12
    total = {
        "time_s": tot_t,
        "energy_j": tot_e,
        "tops": tot_ops / max(tot_t, 1e-30) / 1e12,
        "tops_8b_norm": tot_ops8 / max(tot_t, 1e-30) / 1e12,
        "tops_per_w": tot_ops / max(tot_e, 1e-30) / 1e12,
        "macro_evals": plan.total_macro_evals,
    }
    report = {
        "layers": layers,
        "per_precision": per_prec,
        "noise": noise_echo,
        "total": total,
    }
    if point is not None:
        report["operating_point"] = {
            "name": str(point),
            "tops_per_w": total["tops_per_w"],
            "tops": total["tops"],
            "time_s": total["time_s"],
            "energy_j": total["energy_j"],
        }
    if program is not None:
        prog_echo: Dict[str, object] = dict(program.stats())
        buckets = getattr(program, "buckets", None)
        if buckets is not None:
            prog_echo["buckets"] = dataclasses.asdict(buckets)
        report["program"] = prog_echo
    if sharding is not None:
        # schedule-level parallel efficiency: total single-device work over
        # devices x the summed per-device critical paths.  NB units:
        # total["macro_evals"] counts (row x col) tiles per M-row batch
        # (plan.total_macro_evals, pre-sharding API); the two keys below
        # count full macro *invocations* (x the GEMM-row extent m), the
        # same unit as every per-layer rep["macro_evals"] — compare
        # macro_evals_total against macro_evals_per_device, never
        # macro_evals against macro_evals_per_device.
        tot_evals = sum(rep["macro_evals"] for rep in layers)
        devices = max((getattr(lp, "shard").devices
                       for lp in plan.layers
                       if getattr(lp, "shard", None) is not None),
                      default=1)
        total["macro_evals_total"] = tot_evals
        total["macro_evals_per_device"] = tot_evals_dev
        total["parallel_efficiency"] = (
            tot_evals / max(devices * tot_evals_dev, 1))
        report["sharding"] = {"devices": devices,
                             "axis": getattr(sharding, "axis", None)}
    return report


@dataclasses.dataclass(frozen=True)
class AcceleratorPerfModel:
    energy: EnergyModel = EnergyModel()
    clock_ns: float = 10.0

    def layer_report(self, spec: LayerSpec, *, gamma: float = 1.0,
                     pipelined: bool = True) -> Dict[str, float]:
        mp = map_layer(spec, self.energy.cfg)
        cyc = cycle_model(spec, clock_ns=self.clock_ns, cfg=self.energy.cfg)
        evals = mp.macro_evals * spec.m
        cycles = (cyc.cycles_per_output if pipelined else cyc.cycles_serial)
        total_cycles = evals * cycles
        e_macro = self.energy.macro_energy_pj(spec, mp, gamma) * evals
        bits_moved = spec.m * (spec.k * spec.r_in + spec.n * spec.r_out)
        e_digital = self.energy.e_digital_per_bit_pj * bits_moved
        ops = self.energy.macro_ops_per_eval(spec, mp) * evals
        ops_norm = self.energy.macro_ops_per_eval(spec, mp, True) * evals
        t_s = total_cycles * self.clock_ns * 1e-9
        rep = {
            "op": spec.op,
            "macro_evals": evals,
            "cycles_per_output": cycles,
            "total_cycles": total_cycles,
            "time_s": t_s,
            "tops": ops / t_s / 1e12,
            "tops_8b_norm": ops_norm / t_s / 1e12,
            "macro_energy_j": e_macro * 1e-12,
            "digital_energy_j": e_digital * 1e-12,
            "system_tops_per_w": ops / (e_macro + e_digital) / 1.0,
            "system_tops_per_w_8b": ops_norm / (e_macro + e_digital),
            "macro_fraction": e_macro / (e_macro + e_digital),
            "utilization": mp.utilization,
        }
        if spec.conv is not None:
            g = spec.conv
            rep["conv"] = {
                "kernel": (g.kh, g.kw), "stride": g.stride,
                "out_h": g.out_h, "out_w": g.out_w,
                "macro_evals_per_image": mp.macro_evals * g.out_h * g.out_w,
            }
        return rep
