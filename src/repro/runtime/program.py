"""Compiled CIM programs: plan once, serve many (the deployment API).

The IMAGINE macro's whole economics are amortization — weights stay
resident in the 1152x256 array while input-serial activations stream
through — so the runtime should pay planning and XLA tracing once per
*program*, not once per call.  This module is that artifact layer:

    prog   = compile_program(specs, EngineConfig(...))   # plan + cache once
    params = prog.init_params(jax.random.PRNGKey(0))
    bound  = prog.bind(params)          # weights pre-quantized & packed
    y      = bound.serve(x)             # ragged batch -> bucketed dispatch
    ys     = bound.serve_batch([x1, x2, x3])   # multi-request serving
    prog.stats()                        # plans/compiles/bucket hit-miss

Three amortization levers, each observable through `CIMProgram.stats()`:

* **Plan cache** — `compile_program` keys a module-level cache on
  (specs, cfg, activations, pools, buckets): equal programs share one
  `NetworkPlan` (planned exactly once — engine.PLAN_COUNT counts) and one
  executable cache.  `core/cim_layers` engine mode and the serving launcher
  enter the engine exclusively through this cache.
* **Batch bucketing** — `serve` pads the leading batch axis up to a
  power-of-two ladder rung (`BatchBuckets`), so arbitrary request sizes hit
  a bounded set of jit executables instead of one compile per batch size.
  Padding rows are copies of row 0 and are re-pinned before every layer
  (engine._mask_pad_rows), which keeps the dynamic activation-quantization
  statistics — and therefore every live-row bit — identical to an unpadded
  run, clean *and* under a fixed noise key (thermal draws are generated in
  fixed global row blocks, invariant to the padded extent).
* **Weight binding** — `bind(params)` runs engine.bind_network once
  (weight quantization to the odd-integer grid, ABN gamma evaluation,
  col-tile padding), removing the weight-side work from the per-call graph;
  a `BoundProgram` serves without ever touching the fp32 masters again.

Sharded plans (EngineConfig.sharding) serve through the same API — the
bucket executables dispatch the multi-macro shard_map schedule, and the
bucket-padding contract composes with both shard kinds bit-exactly.

Units/shapes follow runtime/engine.py; everything here is orchestration —
no numerics of its own.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import os
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import mapping
from repro.core.noise_model import NoiseConfig
from repro.runtime import engine as rt


@dataclasses.dataclass(frozen=True)
class BatchBuckets:
    """Power-of-two ladder of batch bucket sizes.

    A request of leading batch extent m dispatches at the smallest rung
    `min_bucket * 2^i >= m`; with `max_bucket` set the ladder is capped
    there and larger requests pad to the next *multiple* of max_bucket
    (bounded compile count either way, padding waste < 2x).

    Attributes:
      min_bucket: smallest rung (>= 1).
      max_bucket: ladder cap; 0 means uncapped (pure power-of-two ladder).
    """
    min_bucket: int = 1
    max_bucket: int = 0

    def __post_init__(self):
        if self.min_bucket < 1:
            raise ValueError(f"min_bucket must be >= 1, got "
                             f"{self.min_bucket}")
        if self.max_bucket and self.max_bucket < self.min_bucket:
            raise ValueError(
                f"max_bucket {self.max_bucket} < min_bucket "
                f"{self.min_bucket}")

    def bucket_for(self, m: int) -> int:
        """The padded batch extent a request of `m` rows dispatches at."""
        if m < 1:
            raise ValueError(f"batch extent must be >= 1, got {m}")
        cap = self.max_bucket
        if cap and m > cap:
            return cap * -(-m // cap)        # beyond the ladder: cap grid
        b = self.min_bucket
        while b < m:
            b *= 2
        return min(b, cap) if cap else b

    def ladder(self, max_m: int) -> Tuple[int, ...]:
        """Every distinct bucket requests of size 1..max_m can land on
        (the compile-count bound batch bucketing guarantees)."""
        return tuple(sorted({self.bucket_for(m)
                             for m in range(1, max_m + 1)}))


DEFAULT_BUCKETS = BatchBuckets()

_STAT_KEYS = ("plans_built", "executables_compiled", "bucket_hits",
              "bucket_misses", "run_calls", "serve_calls")

# stride separating per-request noise-id ranges (request_noise_ids):
# 2^20 rows per request before ids collide — collisions would only
# correlate two rows' thermal draws, never break per-request determinism
NOISE_ID_STRIDE = 1 << 20


def request_noise_ids(request_index: int, rows: int) -> jnp.ndarray:
    """Canonical per-row noise-identity ids of one request.

    `(request_index, row)` maps to `request_index * NOISE_ID_STRIDE + row`
    (int32).  Both the fused serve_batch(isolate=True) path and a solo
    per-request serve must key thermal draws on the *same* ids for noise
    runs to be bit-identical — use this helper on both sides.

    Raises ValueError when the range would leave int32: with the default
    stride that is `request_index >= 2048`, where the old arithmetic
    silently wrapped into another request's id range (x64 is disabled, so
    the ids must genuinely fit int32)."""
    if request_index < 0:
        raise ValueError(f"request_index must be >= 0, got {request_index}")
    if rows < 1:
        raise ValueError(f"rows must be >= 1, got {rows}")
    base = request_index * NOISE_ID_STRIDE       # python int: no wrap
    if base + rows - 1 > 0x7FFFFFFF:
        raise ValueError(
            f"request_noise_ids({request_index}, {rows}) spans "
            f"[{base}, {base + rows}) which overflows int32; at stride "
            f"{NOISE_ID_STRIDE} only request indices < "
            f"{(0x7FFFFFFF + 1) // NOISE_ID_STRIDE} are representable")
    return (jnp.arange(rows, dtype=jnp.int32)
            + jnp.int32(base))


# the trace-signature fields an executable cache key must discriminate;
# `executable_key` is the single constructor both dispatch paths and the
# cimcheck recompile-hazard pass (analysis/recompile.py) share, so a field
# added to the jit signature but dropped from the key is statically visible
EXEC_KEY_FIELDS = ("kind", "extent", "noise", "keyed", "devices", "bound",
                   "reference", "segmented", "identity", "point")


def executable_key(kind: str, extent: int, *, noise: bool, keyed: bool,
                   devices: int, bound: bool, reference: bool,
                   segmented: bool, identity: bool,
                   point: str = "") -> tuple:
    """The cache key of one executable trace signature.

    Mirrors the jit static/presence signature of `_exec_jit`: dispatch
    kind ("exact"/"bucket") and batch extent, plus every operand-presence
    flag that changes the traced graph (noise operands, PRNG key, device
    mesh, bound params, reference oracle, segment ids, noise-identity
    ids), plus the serving operating-point tag (`point`, "" for the base
    point) — distinct precision-ladder rungs execute distinct plans, so
    the point must discriminate or the key would report a hit while jit
    retraces.  Keep in sync with EXEC_KEY_FIELDS."""
    return (kind, int(extent), bool(noise), bool(keyed), int(devices),
            bool(bound), bool(reference), bool(segmented), bool(identity),
            str(point))


@functools.partial(jax.jit, static_argnames=("plan",))
def _bind_jit(plan: rt.NetworkPlan, params: rt.Params):
    return list(rt.bind_network(plan, list(params)))


class CIMProgram:
    """An immutable, hashable compiled CIM inference artifact.

    Owns one `NetworkPlan` (planned exactly once) plus a cache of jitted
    executables keyed on (dispatch kind, batch bucket, noise on/off, key
    presence, device count, bound, reference) — the fields that change the
    traced graph.  Two dispatch styles:

    * `run(params, x, ...)` — exact-shape dispatch, the legacy
      run_network semantics (one executable per distinct batch extent);
    * `serve(params, x, ...)` / `bind(params).serve(x, ...)` — batch-
      bucketed dispatch: x pads up the `BatchBuckets` ladder, runs, and
      slices back, bit-exact with an exact-shape run of the same inputs.

    Programs are hashable on (plan, buckets) — the executable/stat caches
    are bookkeeping, not identity.
    """

    __slots__ = ("_plan", "_buckets", "_executables", "_stats")

    def __init__(self, plan: rt.NetworkPlan,
                 buckets: BatchBuckets = DEFAULT_BUCKETS):
        object.__setattr__(self, "_plan", plan)
        object.__setattr__(self, "_buckets", buckets)
        object.__setattr__(self, "_executables", {})
        object.__setattr__(self, "_stats",
                           {k: 0 for k in _STAT_KEYS} | {"plans_built": 1})

    def __setattr__(self, name, value):
        raise AttributeError("CIMProgram is immutable")

    def __hash__(self):
        return hash((self._plan, self._buckets))

    def __eq__(self, other):
        return (type(other) is CIMProgram and self._plan == other._plan
                and self._buckets == other._buckets)

    def __repr__(self):
        lay = len(self._plan.layers)
        return (f"CIMProgram({lay} layers, buckets={self._buckets}, "
                f"executables={len(self._executables)})")

    @property
    def plan(self) -> rt.NetworkPlan:
        """The jit-static NetworkPlan this program executes."""
        return self._plan

    @property
    def buckets(self) -> BatchBuckets:
        """The batch-bucket ladder `serve` pads requests onto."""
        return self._buckets

    @property
    def cfg(self) -> rt.EngineConfig:
        """The plan's shared EngineConfig."""
        return self._plan.cfg

    def init_params(self, key: jax.Array) -> rt.Params:
        """Distribution-aware per-layer parameters (core/cim_layers init)."""
        return rt.init_network_params(self._plan, key)

    def bind(self, params: rt.Params) -> "BoundProgram":
        """Pre-quantize/pack the weights: the per-call path never touches
        the fp32 masters again.  Returns a BoundProgram closed over the
        engine.bind_network products (odd-integer weight codes, dequant
        scales, padded ABN gain/offset)."""
        return BoundProgram(self, tuple(_bind_jit(self._plan, list(params))))

    # -- dispatch ----------------------------------------------------------

    def _devices(self) -> int:
        sh = self._plan.cfg.sharding
        return sh.resolve_devices() if sh is not None else 1

    def _canon(self, x: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
        """Collapse leading dims to one canonical batch axis (so equal
        batch extents share one executable regardless of lead shape)."""
        x = jnp.asarray(x)
        g = self._plan.layers[0].spec.conv
        if g is not None:
            if x.ndim < 4 or x.shape[-3:] != g.spatial_in:
                raise ValueError(
                    f"input shape {x.shape} != first conv layer's "
                    f"(..., {g.h}, {g.w}, {g.c_in})")
            return x.reshape((-1,) + x.shape[-3:]), x.shape[:-3]
        k0 = self._plan.layers[0].spec.k
        if x.ndim < 1 or x.shape[-1] != k0:
            raise ValueError(
                f"input width {x.shape[-1] if x.ndim else 0} != first "
                f"layer's k={k0}")
        return x.reshape((-1, k0)), x.shape[:-1]

    def _canon_rows(self, v, m: int, name: str):
        """Canonicalize an optional per-sample id vector (segments /
        noise_ids) against the collapsed batch extent `m`."""
        if v is None:
            return None
        v = jnp.asarray(v, jnp.int32).reshape(-1)
        if v.shape[0] != m:
            raise ValueError(
                f"{name} has {v.shape[0]} entries for batch extent {m}")
        return v

    def _note_executable(self, key: tuple, bucketed: bool) -> None:
        st = self._stats
        st["serve_calls" if bucketed else "run_calls"] += 1
        if key in self._executables:
            if bucketed:
                st["bucket_hits"] += 1
            return
        self._executables[key] = True
        st["executables_compiled"] += 1
        if bucketed:
            st["bucket_misses"] += 1

    def run(self, params: rt.Params, x: jnp.ndarray,
            key: Optional[jax.Array] = None,
            noise: Optional[NoiseConfig] = None, *,
            segments: Optional[jnp.ndarray] = None,
            noise_ids: Optional[jnp.ndarray] = None,
            reference: bool = False) -> jnp.ndarray:
        """Exact-shape dispatch (run_network semantics, no bucketing): one
        cached executable per distinct batch extent.  `reference=True`
        runs the pure-jnp digital oracle of the same schedule.
        `segments`/`noise_ids` are optional per-sample ids: segment-wise
        activation quantization and identity-keyed noise draws (the
        per-request isolation primitives — see BoundProgram.serve)."""
        nz = rt._dispatch_noise(self._plan, noise)
        xc, lead = self._canon(x)
        seg = self._canon_rows(segments, xc.shape[0], "segments")
        nid = self._canon_rows(noise_ids, xc.shape[0], "noise_ids")
        # the key tuple mirrors the jit trace signature: dispatch kind and
        # key presence both change the traced graph, so they discriminate
        self._note_executable(
            executable_key("exact", xc.shape[0], noise=nz is not None,
                           keyed=key is not None, devices=self._devices(),
                           bound=False, reference=bool(reference),
                           segmented=seg is not None,
                           identity=nid is not None), bucketed=False)
        y = rt._exec_jit(self._plan, list(params), xc, None, key, nz,
                         seg, nid, False, bool(reference))
        return y.reshape(lead + y.shape[1:])

    def serve(self, params: rt.Params, x: jnp.ndarray,
              key: Optional[jax.Array] = None,
              noise: Optional[NoiseConfig] = None, *,
              segments: Optional[jnp.ndarray] = None,
              noise_ids: Optional[jnp.ndarray] = None,
              reference: bool = False, point: str = "") -> jnp.ndarray:
        """Batch-bucketed dispatch with per-call params (weight binding
        stays in the jitted graph — use bind(params).serve(...) to hoist
        it).  Bit-exact with `run` on the same inputs.  `point` tags the
        dispatch with a serving operating-point name (joins the
        executable key; "" is the base point)."""
        return self._serve_padded(list(params), False, x, key, noise,
                                  bool(reference), segments, noise_ids,
                                  point)

    def _serve_padded(self, payload, bound: bool, x: jnp.ndarray,
                      key, noise, reference: bool,
                      segments=None, noise_ids=None,
                      point: str = "") -> jnp.ndarray:
        nz = rt._dispatch_noise(self._plan, noise)
        xc, lead = self._canon(x)
        m = xc.shape[0]
        if m < 1:
            raise ValueError("cannot serve an empty batch")
        seg = self._canon_rows(segments, m, "segments")
        nid = self._canon_rows(noise_ids, m, "noise_ids")
        bucket = self._buckets.bucket_for(m)
        if bucket > m:
            pad = jnp.broadcast_to(xc[:1], (bucket - m,) + xc.shape[1:])
            xc = jnp.concatenate([xc, pad], axis=0)
            # pad ids mirror the pad rows (copies of row 0): the pad rows
            # stay duplicates inside row 0's segment, so no segment's
            # min/max can move and live rows stay bit-exact
            if seg is not None:
                seg = jnp.concatenate(
                    [seg, jnp.broadcast_to(seg[:1], (bucket - m,))])
            if nid is not None:
                nid = jnp.concatenate(
                    [nid, jnp.broadcast_to(nid[:1], (bucket - m,))])
        self._note_executable(
            executable_key("bucket", bucket, noise=nz is not None,
                           keyed=key is not None, devices=self._devices(),
                           bound=bound, reference=reference,
                           segmented=seg is not None,
                           identity=nid is not None,
                           point=str(point)), bucketed=True)
        y = rt._exec_jit(self._plan, payload, xc,
                         jnp.asarray(m, jnp.int32), key, nz, seg, nid,
                         bound, reference)
        return y[:m].reshape(lead + y.shape[1:])

    # -- observability -----------------------------------------------------

    def stats(self) -> Dict[str, int]:
        """Compile/cache counters of this program: plans_built (always 1 —
        the plan is built at compile_program time), executables_compiled
        (distinct trace signatures dispatched: kind, bucket, noise, key
        presence, devices, bound, reference), bucket_hits/bucket_misses
        (serve-path ladder lookups), run_calls/serve_calls."""
        return dict(self._stats)

    def perf_report(self, **kw):
        """perfmodel.schedule_report of the plan, with this program's
        compile/bucket stats echoed under report["program"]."""
        from repro.perfmodel.macro_perf import schedule_report
        return schedule_report(self._plan, program=self, **kw)


class BoundProgram:
    """A CIMProgram closed over pre-quantized weights (the serve-side
    artifact: no fp32 weight masters, no per-call weight quantization).

    `serve(x)` dispatches one request through the batch-bucket ladder;
    `serve_batch([x1, ...])` concatenates requests, serves the fused batch
    once, and splits the results back per request.  By default the fusion
    shares the dynamic activation-quantization statistics across the fused
    batch (exactly like running the concatenated batch through the
    engine) — bit-exact with `serve(concat(requests))`, not with
    per-request serve calls.  `serve_batch(..., isolate=True)` instead
    tags each request as its own quantization segment (segment-wise
    `quantize_act`), making every request bit-identical to serving it
    alone — the contract in-flight batched decode
    (runtime/scheduler.py) is built on."""

    __slots__ = ("program", "_binds")

    def __init__(self, program: CIMProgram, binds: Tuple[Dict, ...]):
        object.__setattr__(self, "program", program)
        object.__setattr__(self, "_binds", binds)

    def __setattr__(self, name, value):
        raise AttributeError("BoundProgram is immutable")

    @property
    def plan(self) -> rt.NetworkPlan:
        """The backing program's NetworkPlan."""
        return self.program.plan

    def serve(self, x: jnp.ndarray, key: Optional[jax.Array] = None,
              noise: Optional[NoiseConfig] = None, *,
              segments: Optional[jnp.ndarray] = None,
              noise_ids: Optional[jnp.ndarray] = None,
              reference: bool = False, point: str = "") -> jnp.ndarray:
        """Bucketed dispatch of one request through the bound weights
        (bit-exact with the unbucketed engine on the same inputs, clean
        and under a fixed noise key).

        `segments` ((B,) int32, optional) switches activation quantization
        to per-segment statistics: samples with different ids never share
        dynamic swing state, so a fused batch is bit-exact with serving
        each segment alone.  `noise_ids` ((B,) int32, optional) keys the
        noise model's thermal draws by sample identity instead of batch
        position (see request_noise_ids) — together they make noisy fused
        serving bit-exact with solo serving under one key.  `point` tags
        the dispatch with the serving operating-point name ("" = base):
        it joins the executable key so precision-ladder rungs never alias
        one cache entry."""
        return self.program._serve_padded(list(self._binds), True, x, key,
                                          noise, bool(reference),
                                          segments, noise_ids, point)

    __call__ = serve

    def reference(self, x: jnp.ndarray, key: Optional[jax.Array] = None,
                  noise: Optional[NoiseConfig] = None, *,
                  segments: Optional[jnp.ndarray] = None,
                  noise_ids: Optional[jnp.ndarray] = None,
                  point: str = "") -> jnp.ndarray:
        """The pure-jnp digital oracle of serve (bit-exact with it)."""
        return self.serve(x, key, noise, segments=segments,
                          noise_ids=noise_ids, reference=True, point=point)

    def serve_batch(self, requests: Sequence[jnp.ndarray],
                    key: Optional[jax.Array] = None,
                    noise: Optional[NoiseConfig] = None, *,
                    isolate: bool = False) -> List[jnp.ndarray]:
        """Multi-request serving: concatenate, bucket-pad, dispatch once
        (through the sharded engine when the plan is sharded), split.

        Args:
          requests: per-request activation arrays, each batch-major with
            the plan's feature shape — (b_i, K0) dense or
            (b_i, H, W, C_in) conv.
          key: PRNG key for noise-enabled plans (one key for the fused
            batch; per-request noise follows each request's row offset —
            or its request_noise_ids identity under `isolate`).
          noise: optional operating-point override (traced — no recompile).
          isolate: per-request numerical isolation.  False (default)
            keeps the legacy fusion semantics — the dynamic activation-
            quantization statistics are shared across the fused batch, so
            the results are bit-exact with `serve(concat(requests))` but
            NOT with per-request serves.  True tags each request as its
            own quantization segment (and, under noise, keys thermal
            draws on request_noise_ids(i, b_i)), making every request's
            rows bit-identical to a solo
            `serve(x_i, key, segments=zeros(b_i),
            noise_ids=request_noise_ids(i, b_i))` call.
        Returns:
          One result array per request, in order, each with its own
          leading b_i.
        """
        if not requests:
            return []
        xs = [jnp.asarray(r) for r in requests]
        feat = xs[0].shape[1:]
        for i, r in enumerate(xs):
            if r.ndim != len(feat) + 1 or r.shape[1:] != feat:
                raise ValueError(
                    f"request {i} shape {r.shape} is not batch-major with "
                    f"feature shape {feat}")
        sizes = [r.shape[0] for r in xs]
        segments = noise_ids = None
        if isolate:
            segments = jnp.concatenate(
                [jnp.full((b,), i, jnp.int32)
                 for i, b in enumerate(sizes)])
            if key is not None:
                noise_ids = jnp.concatenate(
                    [request_noise_ids(i, b)
                     for i, b in enumerate(sizes)])
        y = self.serve(jnp.concatenate(xs, axis=0), key, noise,
                       segments=segments, noise_ids=noise_ids)
        out, s = [], 0
        for b in sizes:
            out.append(y[s:s + b])
            s += b
        return out

    def stats(self) -> Dict[str, int]:
        """The backing program's compile/bucket counters."""
        return self.program.stats()


class SharedInputProgram:
    """N projection heads over one shared input, fused as ONE program.

    A transformer block computes several projections of the *same*
    normalized hidden state — Q/K/V from the attention input, gate/up from
    the MLP input.  On the macro these are columns of one wide GEMM: the
    activations stream through the rows once and every head's columns
    convert in the same ADC pass.  This artifact expresses that: it
    compiles a single (k -> sum(n_i)) layer via `compile_program` (so the
    fused program shares the global plan cache like any other) and serves
    every head from one dispatch.

    Bit-exactness of the per-head slices vs. per-head programs is
    structural, not approximate: activation quantization depends only on
    the shared input, and weight quantization, ABN gamma/beta, the ADC
    epilogue, and dequantization are all per-output-column — concatenating
    heads along the output axis changes no column's arithmetic
    (tests/test_program.py proves the slices bitwise).
    """

    __slots__ = ("program", "heads", "_offsets")

    def __init__(self, program: CIMProgram,
                 heads: Sequence[Tuple[str, int]]):
        heads = tuple((str(name), int(n)) for name, n in heads)
        if len({name for name, _ in heads}) != len(heads):
            raise ValueError(f"duplicate head names in {heads}")
        n_tot = sum(n for _, n in heads)
        if len(program.plan.layers) != 1:
            raise ValueError("shared-input fusion is a single-layer "
                             f"artifact, got {len(program.plan.layers)} "
                             "layers")
        if program.plan.layers[0].spec.n != n_tot:
            raise ValueError(
                f"program n={program.plan.layers[0].spec.n} != "
                f"sum of head widths {n_tot}")
        offsets, s = [], 0
        for _, n in heads:
            offsets.append((s, s + n))
            s += n
        object.__setattr__(self, "program", program)
        object.__setattr__(self, "heads", heads)
        object.__setattr__(self, "_offsets", tuple(offsets))

    def __setattr__(self, name, value):
        raise AttributeError("SharedInputProgram is immutable")

    @classmethod
    def compile(cls, k: int, heads: Sequence[Tuple[str, int]],
                cfg: rt.EngineConfig = rt.EngineConfig(), *,
                r_in: int, r_w: int, m: int = 8,
                buckets: BatchBuckets = DEFAULT_BUCKETS
                ) -> "SharedInputProgram":
        """Compile (through the global program cache) the fused program of
        `heads` — ((name, n_i), ...) projections sharing a width-k input
        at one precision point.  `m` is the planner's batch-extent hint."""
        heads = tuple((str(name), int(n)) for name, n in heads)
        n_tot = sum(n for _, n in heads)
        prog = compile_program(
            (mapping.LayerSpec(m=m, k=int(k), n=n_tot,
                               r_in=r_in, r_w=r_w),),
            cfg, activations=("none",), buckets=buckets)
        return cls(prog, heads)

    @property
    def k(self) -> int:
        """The shared input width."""
        return self.program.plan.layers[0].spec.k

    def init_params(self, key: jax.Array) -> Dict[str, Dict]:
        """Distribution-aware init, split per head: {name: {"w",
        "abn_log_gamma", "abn_beta"}} with w (k, n_i)."""
        (lay,) = list(self.program.init_params(key))
        out = {}
        for (name, _), (s, e) in zip(self.heads, self._offsets):
            out[name] = {"w": lay["w"][:, s:e],
                         "abn_log_gamma": lay["abn_log_gamma"][s:e],
                         "abn_beta": lay["abn_beta"][s:e]}
        return out

    def bind(self, params: Dict[str, Dict]) -> "SharedInputBind":
        """Concatenate the per-head params along the output axis and bind
        once (weight quantization is per-output-column, so the fused bind
        equals the per-head binds column for column)."""
        missing = [name for name, _ in self.heads if name not in params]
        if missing:
            raise ValueError(f"missing head params {missing}")
        for (name, n) in self.heads:
            w = params[name]["w"]
            if w.shape != (self.k, n):
                raise ValueError(
                    f"head {name!r} weight shape {w.shape} != "
                    f"({self.k}, {n})")
        cat = {
            fld: jnp.concatenate(
                [jnp.asarray(params[name][fld]) for name, _ in self.heads],
                axis=-1 if fld == "w" else 0)
            for fld in ("w", "abn_log_gamma", "abn_beta")}
        return SharedInputBind(self, self.program.bind([cat]))

    def stats(self) -> Dict[str, int]:
        """The fused program's compile/bucket counters."""
        return self.program.stats()


class SharedInputBind:
    """A SharedInputProgram closed over bound (pre-quantized) weights:
    `serve(x)` runs the one fused dispatch and returns {head: slice}."""

    __slots__ = ("shared", "bound")

    def __init__(self, shared: SharedInputProgram, bound: BoundProgram):
        object.__setattr__(self, "shared", shared)
        object.__setattr__(self, "bound", bound)

    def __setattr__(self, name, value):
        raise AttributeError("SharedInputBind is immutable")

    @property
    def program(self) -> CIMProgram:
        """The backing fused CIMProgram."""
        return self.shared.program

    def serve(self, x: jnp.ndarray, key: Optional[jax.Array] = None,
              noise: Optional[NoiseConfig] = None, *,
              segments: Optional[jnp.ndarray] = None,
              noise_ids: Optional[jnp.ndarray] = None,
              reference: bool = False,
              point: str = "") -> Dict[str, jnp.ndarray]:
        """One bucketed dispatch of the shared input; the result splits
        along the output axis into {head name: (..., n_i)}.  Isolation
        arguments (`segments`/`noise_ids`) and the operating-point tag
        (`point`) pass through unchanged — a fused-head serve isolates
        rows exactly like any other program."""
        y = self.bound.serve(x, key, noise, segments=segments,
                             noise_ids=noise_ids, reference=reference,
                             point=point)
        return {name: y[..., s:e]
                for (name, _), (s, e) in zip(self.shared.heads,
                                             self.shared._offsets)}

    __call__ = serve

    def stats(self) -> Dict[str, int]:
        """The backing program's compile/bucket counters."""
        return self.shared.program.stats()


# ---------------------------------------------------------------------------
# the global program cache
# ---------------------------------------------------------------------------

_PROGRAM_CACHE: "collections.OrderedDict[tuple, CIMProgram]" = \
    collections.OrderedDict()
_PLAN_PROGRAMS: "collections.OrderedDict[tuple, CIMProgram]" = \
    collections.OrderedDict()
_CACHE_STATS = {"programs_built": 0, "lookups": 0, "hits": 0,
                "evictions": 0}


def _env_capacity() -> int:
    try:
        cap = int(os.environ.get("REPRO_PROGRAM_CACHE_CAP", "512"))
    except ValueError:
        cap = 512
    return max(cap, 1)


# LRU bound on BOTH module-level caches (the precision ladder times model
# churn would otherwise grow them without limit); mutable holder so tests
# can shrink it without monkeypatching the module global
_CACHE_CAPACITY = [_env_capacity()]


def set_program_cache_capacity(capacity: int) -> int:
    """Set the program-cache LRU capacity (entries per cache table) and
    return the previous value.  Shrinking evicts least-recently-used
    entries immediately; evicted programs keep working wherever they are
    already held — eviction only means an equal future compile_program
    call re-plans.  The startup default is $REPRO_PROGRAM_CACHE_CAP
    (512)."""
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    old = _CACHE_CAPACITY[0]
    _CACHE_CAPACITY[0] = int(capacity)
    for cache in (_PROGRAM_CACHE, _PLAN_PROGRAMS):
        _trim_cache(cache)
    return old


def _trim_cache(cache) -> None:
    while len(cache) > _CACHE_CAPACITY[0]:
        cache.popitem(last=False)
        _CACHE_STATS["evictions"] += 1


def _cache_get(cache, key):
    prog = cache.get(key)
    if prog is not None:
        cache.move_to_end(key)
    return prog


def _cache_put(cache, key, prog) -> None:
    cache[key] = prog
    cache.move_to_end(key)
    _trim_cache(cache)


def _canonical_epilogues(n_layers: int,
                         activations: Optional[Sequence[str]],
                         pools: Optional[Sequence[int]]
                         ) -> Tuple[Tuple[str, ...], Tuple[int, ...]]:
    """plan_network's defaulting, applied eagerly so cache keys are
    canonical (None and the equivalent explicit lists hit one entry)."""
    acts = (("relu",) * (n_layers - 1) + ("none",)
            if activations is None else tuple(activations))
    pls = (1,) * n_layers if pools is None else tuple(pools)
    return acts, pls


def compile_program(specs: Sequence[mapping.LayerSpec],
                    cfg: rt.EngineConfig = rt.EngineConfig(), *,
                    activations: Optional[Sequence[str]] = None,
                    pools: Optional[Sequence[int]] = None,
                    buckets: BatchBuckets = DEFAULT_BUCKETS,
                    verify: str = "off", tune: str = "off",
                    tune_cache: Optional[str] = None) -> CIMProgram:
    """Compile (or fetch from the global cache) the program for a network.

    The cache key is (specs, cfg, activations, pools, buckets) — all
    hashable plan inputs — plus, when tuning, (tune mode, resolved cache
    path) — so every caller of an equal network shares one NetworkPlan
    (planned once; engine.PLAN_COUNT counts) and one executable cache.
    This is the single entry point the model-facing layers (cim_layers
    engine mode, models/cnn, launch/serve) go through.

    Args:
      specs: the network's (conv-tagged) LayerSpecs, in order.
      cfg: shared EngineConfig (noise, sharding, macro, block sizes).
      activations/pools: per-layer epilogues (plan_network defaults).
      buckets: the serve-path batch-bucket ladder.
      verify: cimcheck static verification of the fresh program —
        "strict" raises `repro.analysis.CimcheckError` on any ERROR
        finding, "warn" prints findings to stderr, "off" (default) skips.
        Cache hits skip verification (the program was already checked or
        deliberately not).
      tune: schedule autotuning — "off" (default) plans with the
        EngineConfig heuristics; "analytic" searches block sizes and
        shard kinds with the repro.tuner roofline model; "measure"
        additionally wall-clock times the analytic top-k.  Tuning is
        numerics-neutral: the tuned program's outputs are bit-identical
        to tune="off" (tests/test_tuner.py fuzzes this), and a layer
        whose search keeps the heuristic produces the *same* plan object
        (hash-equal), sharing its executables.
      tune_cache: autotune cache file; None uses
        repro.tuner.default_cache_path(), "" disables persistence for
        this compile.  Corrupt/stale caches degrade to heuristic
        schedules with a TuneCacheWarning — never an error.
    Returns:
      The cached (or freshly planned) CIMProgram.
    """
    if tune not in ("off", "analytic", "measure"):
        raise ValueError(
            f'tune must be "off", "analytic" or "measure", got {tune!r}')
    specs = tuple(specs)
    acts, pls = _canonical_epilogues(len(specs), activations, pools)
    key = (specs, cfg, acts, pls, buckets)
    if tune != "off":
        from repro import tuner
        resolved = (tuner.default_cache_path() if tune_cache is None
                    else tune_cache)
        key = key + (tune, resolved)
    _CACHE_STATS["lookups"] += 1
    prog = _cache_get(_PROGRAM_CACHE, key)
    if prog is not None:
        _CACHE_STATS["hits"] += 1
        return prog
    if tune != "off":
        plan, _ = tuner.tune_network(specs, cfg, acts, pls, mode=tune,
                                     cache_path=resolved)
    else:
        plan = rt.plan_network(specs, cfg, acts, pls)
    prog = _cache_get(_PLAN_PROGRAMS, (plan, buckets))
    if prog is None:
        prog = CIMProgram(plan, buckets)
        _cache_put(_PLAN_PROGRAMS, (plan, buckets), prog)
        _CACHE_STATS["programs_built"] += 1
    _cache_put(_PROGRAM_CACHE, key, prog)
    if verify != "off":
        # inline verification lints the serving graphs (the trace is
        # reused by jit warmup); the exhaustive variant sweep is
        # scripts/cimcheck.py's job
        from repro.analysis import verify_program
        verify_program(prog, mode=verify, graphs="serving")
    return prog


def program_for_plan(plan: rt.NetworkPlan,
                     buckets: BatchBuckets = DEFAULT_BUCKETS) -> CIMProgram:
    """The cached program behind an already-built NetworkPlan (what the
    legacy run_network/run_network_reference entry points dispatch
    through); creates and caches one on first sight of the plan."""
    key = (plan, buckets)
    prog = _cache_get(_PLAN_PROGRAMS, key)
    if prog is None:
        prog = CIMProgram(plan, buckets)
        _cache_put(_PLAN_PROGRAMS, key, prog)
        _CACHE_STATS["programs_built"] += 1
    return prog


def program_cache_stats() -> Dict[str, int]:
    """Global program-cache counters: programs (live cached programs),
    programs_built, lookups, hits (compile_program key hits), evictions
    (LRU drops across both cache tables) and capacity (the LRU bound —
    set_program_cache_capacity / $REPRO_PROGRAM_CACHE_CAP)."""
    return dict(_CACHE_STATS, programs=len(_PLAN_PROGRAMS),
                capacity=_CACHE_CAPACITY[0])


def clear_program_cache() -> None:
    """Drop every cached program and reset the cache counters (tests /
    long-lived processes re-keying on fresh configs)."""
    _PROGRAM_CACHE.clear()
    _PLAN_PROGRAMS.clear()
    for k in list(_CACHE_STATS):
        _CACHE_STATS[k] = 0
