"""Precision-scalable CIM inference runtime.

The paper's headline lever is workload-adaptive 8-to-1b precision scaling
(0.15-8 POPS/W); this module exposes it end-to-end: a network described as
`mapping.LayerSpec`s is *planned* into the macro's row/col tile schedule
(core/mapping.py) and *executed* through precision-specialized, jit-compiled
Pallas kernel variants (kernels/cim_mbiw/ops.kernel_variant), with the
chip's digital partial-sum recombination between row tiles.

    specs = [LayerSpec(m=256, k=1152, n=64, r_in=4, r_w=2), ...]
    engine = CIMInferenceEngine(specs)           # plans + builds dispatch
    params = engine.init_params(jax.random.PRNGKey(0))
    y = engine(params, x)                        # jit-compiled schedule
    y_ref = engine.reference(params, x)          # pure-jnp digital oracle

Numerics: under NO_NOISE the engine is bit-exact with `reference` at every
supported precision — both walk identical tile schedules and evaluate the
identical ADC floor expression; the kernel's int32 accumulator is exact for
one macro row-tile (|dp| <= 1152*255*15 < 2^24).  The activation zero-point
is folded into the per-channel ABN beta *inside* the ADC floor
(beta_eff = beta + gamma*g0*zp_dp), exactly what the chip's
signed-to-unsigned conversion + beta block does.

Per-layer precision is free: each layer's (r_in, r_w, r_out) selects its
kernel variant from a small cached table, so a mixed-precision network
compiles one kernel per distinct operating point, not per layer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import abn as abn_lib
from repro.core import digital_ref, mapping
from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO
from repro.kernels.cim_mbiw import ops as kops

Params = List[Dict[str, jnp.ndarray]]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Execution configuration shared by every layer of a schedule."""
    macro: CIMMacroConfig = DEFAULT_MACRO
    adaptive_swing: bool = True      # serial-split DPL swing adaptation
    gamma_bits: int = -1             # -1: continuous gamma; >=0: HW quant
    max_gamma: float = 32.0
    interpret: bool = True           # Pallas interpret mode (CPU) vs TPU
    bm: int = 128                    # kernel block sizes (MXU-aligned)
    bn: int = 128
    bk: int = 256

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer's macro-tile schedule."""
    spec: mapping.LayerSpec
    mp: mapping.MacroMapping
    precision: kops.KernelPrecision
    g0: float                            # unity-gain codes per dp unit
    k_slices: Tuple[Tuple[int, int], ...]  # (start, size) row tiles
    n_slices: Tuple[Tuple[int, int], ...]  # (start, size) col tiles
    activation: str = "none"             # "none" | "relu"

    @property
    def macro_evals(self) -> int:
        return len(self.k_slices) * len(self.n_slices)


@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    layers: Tuple[LayerPlan, ...]
    cfg: EngineConfig

    @property
    def precisions(self) -> Tuple[kops.KernelPrecision, ...]:
        seen: List[kops.KernelPrecision] = []
        for lp in self.layers:
            if lp.precision not in seen:
                seen.append(lp.precision)
        return tuple(seen)

    @property
    def total_macro_evals(self) -> int:
        return sum(lp.macro_evals for lp in self.layers)


def _layer_g0(spec: mapping.LayerSpec, mp: mapping.MacroMapping,
              cfg: EngineConfig) -> float:
    macro = cfg.macro
    units = mp.units_per_tile if cfg.adaptive_swing else macro.n_units
    n_dp = units * macro.rows_per_unit
    return digital_ref.adc_gain_factor(
        spec.r_in, spec.r_w, spec.r_out, n_dp,
        macro.swing_efficiency(units), macro.alpha_adc())


def plan_layer(spec: mapping.LayerSpec, cfg: EngineConfig = EngineConfig(),
               activation: str = "none") -> LayerPlan:
    mp = mapping.map_layer(spec, cfg.macro)
    prec = kops.KernelPrecision(spec.r_in, spec.r_w, spec.r_out)
    return LayerPlan(
        spec=spec, mp=mp, precision=prec, g0=_layer_g0(spec, mp, cfg),
        k_slices=tuple(mapping.split_k_slices(spec.k, mp.row_tiles)),
        n_slices=tuple(mapping.split_k_slices(spec.n, mp.col_tiles)),
        activation=activation)


def plan_network(specs: Sequence[mapping.LayerSpec],
                 cfg: EngineConfig = EngineConfig(),
                 activations: Optional[Sequence[str]] = None) -> NetworkPlan:
    """Plan a feed-forward network: layer i's N must equal layer i+1's K.

    `activations`: per-layer epilogue nonlinearity; defaults to relu between
    layers and none after the last (the CNN workloads of the paper).
    """
    specs = list(specs)
    for a, b in zip(specs[:-1], specs[1:]):
        if a.n != b.k:
            raise ValueError(f"layer chain mismatch: n={a.n} feeds k={b.k}")
    if activations is None:
        activations = ["relu"] * (len(specs) - 1) + ["none"]
    if len(activations) != len(specs):
        raise ValueError("one activation per layer required")
    return NetworkPlan(
        layers=tuple(plan_layer(s, cfg, act)
                     for s, act in zip(specs, activations)),
        cfg=cfg)


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def _quantize_inputs(lp: LayerPlan, params: Dict[str, jnp.ndarray],
                     x2: jnp.ndarray, cfg: EngineConfig):
    """Shared prologue of the kernel and reference paths: dynamic activation
    quantization, weight quantization, ABN gamma."""
    from repro.core.quantization import quantize_act, quantize_weight
    aq = quantize_act(x2, lp.spec.r_in)
    wq = quantize_weight(params["w"], lp.spec.r_w, axis=0)
    gamma = abn_lib.abn_gamma(
        abn_lib.ABNParams(params["abn_log_gamma"], params["abn_beta"]),
        gamma_bits=cfg.gamma_bits, max_gamma=cfg.max_gamma)
    return aq, wq, gamma


def _layer_tiles(lp: LayerPlan, params: Dict[str, jnp.ndarray],
                 x2: jnp.ndarray, cfg: EngineConfig, *,
                 matmul) -> jnp.ndarray:
    """Run one layer's tile schedule; `matmul` evaluates one macro tile
    (kernel variant or jnp oracle) and returns int32 ADC codes."""
    aq, wq, gamma = _quantize_inputs(lp, params, x2, cfg)
    beta = params["abn_beta"]
    mid = 2.0 ** (lp.spec.r_out - 1)
    g0 = lp.g0
    dp_hat = []
    for (ns, nsz) in lp.n_slices:
        ne = ns + nsz
        acc = jnp.zeros(x2.shape[:-1] + (nsz,), jnp.float32)
        for (ks, ksz) in lp.k_slices:
            ke = ks + ksz
            # zero-point: x = q*s + z -> z*colsum is per-channel constant,
            # folded into the ABN offset inside the ADC floor
            zp_dp = (aq.zero / aq.scale) * jnp.sum(wq.q[ks:ke, ns:ne], axis=0)
            beta_eff = beta[ns:ne] + gamma[ns:ne] * g0 * zp_dp
            codes = matmul(aq.q[..., ks:ke], wq.q[ks:ke, ns:ne],
                           gamma[ns:ne], beta_eff, g0)
            # digital partial-sum recombination in dp units; dequantizing
            # against the *raw* beta keeps the zero-point contribution in
            # dp_hat, exactly like the fakequant training path
            acc = acc + (codes.astype(jnp.float32) + 0.5 - mid
                         - beta[None, ns:ne]) / (gamma[None, ns:ne] * g0)
        dp_hat.append(acc)
    y = jnp.concatenate(dp_hat, axis=-1) * aq.scale * wq.scale.reshape(-1)
    if lp.activation == "relu":
        y = jax.nn.relu(y)
    elif lp.activation != "none":
        raise ValueError(f"unknown activation {lp.activation!r}")
    return y


def _kernel_matmul(lp: LayerPlan, cfg: EngineConfig):
    fn = kops.kernel_variant(lp.precision, bm=cfg.bm, bn=cfg.bn, bk=cfg.bk,
                             interpret=cfg.interpret)

    def matmul(xq, wqt, gamma_t, beta_t, g0):
        return fn(xq, wqt, gamma_t, beta_t, g0)
    return matmul


def _reference_matmul(lp: LayerPlan, cfg: EngineConfig):
    del cfg
    from repro.kernels.cim_mbiw.ref import cim_matmul_ref

    def matmul(xq, wqt, gamma_t, beta_t, g0):
        # the shared oracle keeps the ADC floor expression in float-op
        # lockstep with the kernel epilogue (bit-exactness contract)
        return cim_matmul_ref(xq, wqt, gamma_t, beta_t, g0=g0,
                              r_out=lp.spec.r_out)
    return matmul


def _forward(plan: NetworkPlan, params: Params, x: jnp.ndarray,
             reference: bool) -> jnp.ndarray:
    k0 = plan.layers[0].spec.k
    if x.shape[-1] != k0:
        raise ValueError(
            f"input width {x.shape[-1]} != first layer's k={k0}")
    if len(params) != len(plan.layers):
        raise ValueError(f"{len(params)} param dicts for "
                         f"{len(plan.layers)} planned layers")
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1])).astype(jnp.float32)
    for lp, p in zip(plan.layers, params):
        mk = _reference_matmul if reference else _kernel_matmul
        x2 = _layer_tiles(lp, p, x2, plan.cfg, matmul=mk(lp, plan.cfg))
    return x2.reshape(lead + (x2.shape[-1],))


@functools.partial(jax.jit, static_argnames=("plan",))
def run_network(plan: NetworkPlan, params: Params,
                x: jnp.ndarray) -> jnp.ndarray:
    """Execute the planned schedule through the Pallas kernel variants.

    x: (..., K0) real-valued activations; returns (..., N_last)."""
    return _forward(plan, params, x, reference=False)


@functools.partial(jax.jit, static_argnames=("plan",))
def run_network_reference(plan: NetworkPlan, params: Params,
                          x: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp digital oracle of the identical schedule (bit-exact)."""
    return _forward(plan, params, x, reference=True)


class CIMInferenceEngine:
    """Plans a LayerSpec network once; every call dispatches the cached
    jit-compiled schedule."""

    def __init__(self, specs: Sequence[mapping.LayerSpec],
                 cfg: EngineConfig = EngineConfig(),
                 activations: Optional[Sequence[str]] = None):
        self.cfg = cfg
        self.plan = plan_network(specs, cfg, activations)

    def init_params(self, key: jax.Array) -> Params:
        """Distribution-aware per-layer parameters (core/cim_layers init)."""
        from repro.core.cim_layers import CIMConfig, init_cim_linear
        params = []
        for lp in self.plan.layers:
            key, sub = jax.random.split(key)
            lcfg = CIMConfig(
                r_in=lp.spec.r_in, r_w=lp.spec.r_w, r_out=lp.spec.r_out,
                adaptive_swing=self.cfg.adaptive_swing,
                gamma_bits=self.cfg.gamma_bits, max_gamma=self.cfg.max_gamma,
                macro=self.cfg.macro)
            params.append(init_cim_linear(sub, lp.spec.k, lp.spec.n,
                                          cfg=lcfg))
        return params

    def __call__(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        return run_network(self.plan, params, x)

    def reference(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        return run_network_reference(self.plan, params, x)

    def perf_report(self, **kw):
        """Per-layer + aggregate cycle/energy estimates (perfmodel)."""
        from repro.perfmodel.macro_perf import schedule_report
        return schedule_report(self.plan, **kw)
