"""Precision-scalable CIM inference runtime (single- and multi-macro).

The paper's headline lever is workload-adaptive 8-to-1b precision scaling
(0.15-8 POPS/W); this module exposes it end-to-end: a network described as
`mapping.LayerSpec`s is *planned* into the macro's row/col tile schedule
(core/mapping.py) and *executed* through precision-specialized, jit-compiled
Pallas kernel variants (kernels/cim_mbiw/ops.kernel_variant), with the
chip's digital partial-sum recombination between row tiles.

    specs = [LayerSpec(m=256, k=1152, n=64, r_in=4, r_w=2), ...]
    engine = CIMInferenceEngine(specs)           # plans + builds dispatch
    params = engine.init_params(jax.random.PRNGKey(0))
    y = engine(params, x)                        # jit-compiled schedule
    y_ref = engine.reference(params, x)          # pure-jnp digital oracle

Plan-once/serve-many: the deployment API lives in runtime/program.py —
`compile_program(specs, cfg)` returns an immutable `CIMProgram` (a
NetworkPlan plus an executable cache keyed on batch bucket, noise mode and
device count), `program.bind(params)` pre-quantizes the weights into a
`BoundProgram`, and `.serve`/`.serve_batch` dispatch ragged request batches
through a power-of-two bucket ladder with zero re-planning and zero
re-tracing after warmup.  `CIMInferenceEngine` (below) is a thin
compatibility wrapper over that cache, and this module's `run_network` is
the legacy per-call entry (DeprecationWarning, still bit-exact).

Convolution front-end: a `LayerSpec` built by `mapping.conv_layer_spec`
carries its NHWC `ConvGeometry`; the engine then consumes image
activations directly — the K = kh*kw*C_in row groups of the paper's
Sec. III/IV conv mapping are formed on the fly by an im2col streaming
stage (`im2col_patches` + optional `EngineConfig.stream_rows` chunking of
the patch rows through the kernel), and the GEMM output is reshaped back
to (B, out_h, out_w, C_out) for the next layer.  Max-pool epilogues
(`pools`) and the conv -> dense flatten are planned per layer, so a whole
CNN (e.g. LeNet: conv1 -> pool -> conv2 -> pool -> fc1 -> fc2) runs
through one engine:

    specs, acts, pools = models.cnn.lenet_engine_specs(batch=128)
    engine = CIMInferenceEngine(specs, activations=acts, pools=pools)
    logits = engine(params, images)              # (B, 28, 28, 1) -> (B, 10)

Multi-macro sharding: the 1152x256 macro is a building block — the paper's
system-level 40 TOPS/W numbers assume it is replicated.  With
`EngineConfig(sharding=ShardingConfig(devices=D))` each layer's schedule
partitions across a 1-D `jax.sharding.Mesh` of D devices (the
`jax_compat.shard_map` shim; the per-device body is the same cached Pallas
variant): layers with at least D independent col tiles shard those
(`mapping.shard_layer` kind "col", disjoint output channels per device);
layers with fewer col tiles shard the GEMM-row dimension M = B*OH*OW via
the same stream_rows-style row chunking ("rows" kind, weights replicated).
Both partitions are bit-exact with the single-device schedule — columns
and GEMM rows never interact before the digital partial-sum recombination,
and the noise model's per-tile draws are device-count independent (below).

    cfg = EngineConfig(sharding=ShardingConfig(devices=8))
    engine = CIMInferenceEngine(specs, cfg)      # same API, D-macro dispatch

Numerics: under NO_NOISE the engine is bit-exact with `reference` at every
supported precision — both walk identical tile schedules and evaluate the
identical ADC floor expression; the kernel's int32 accumulator is exact for
one macro row-tile (|dp| <= 1152*255*15 < 2^24).  The activation zero-point
is folded into the per-channel ABN beta *inside* the ADC floor
(beta_eff = beta + gamma*g0*zp_dp), exactly what the chip's
signed-to-unsigned conversion + beta block does.

Per-layer precision is free: each layer's (r_in, r_w, r_out) selects its
kernel variant from a small cached table, so a mixed-precision network
compiles one kernel per distinct operating point, not per layer; the
variant's block sizes are clamped to the dispatched tile geometry
(ops.kernel_variant_for_tile), so a sharded schedule's smaller per-device
tiles do not pad up to full-macro blocks.

Noise-injected mode (post-silicon studies, paper Sec. III.E/V.A): with
`EngineConfig(noise=NoiseConfig(...))` the full equivalent noise model runs
through the same planned schedule — the kernel variants dispatch in raw-dp
mode (`fuse_adc=False`) and a vectorized post-kernel epilogue applies, in
code units and at the exact points the fakequant/sim paths inject them:
per-physical-column SA offsets + 7b calibration residue (static per macro,
shared across col tiles), thermal kT/C noise on the dp, DPL settling INL
and MBIW charge-injection as gain terms on g0, and leakage droop.  Runs
take a PRNG key (`engine(params, x, key)`); thermal draws are generated
per (layer, row tile, col tile) over the layer's *full* GEMM-row extent
and sliced per stream chunk / device shard, so a fixed key is fully
deterministic AND invariant to both the stream_rows chunking and the
device count — sharded noisy inference is bit-exact with the
single-device path.  `CIMInferenceEngine.monte_carlo(params, x, key,
n_trials)` stacks seeded trials for Monte-Carlo accuracy-vs-noise sweeps.
Under NO_NOISE the fused bit-exact path is unchanged.

Compilation: only `NoiseConfig.enabled`/`.calibrated` are static (they
switch the kernel's fuse_adc path and the calibration branch); the numeric
sigma/offset/gain terms enter the jitted schedule as *traced* scalars
(NoiseConfig is a JAX pytree), so a sweep across noise operating points
shares one compile: `engine(params, x, key, noise=point_i)`.

Units cheat-sheet (see also core/noise_model.py):
  * `dp` / `dp_hat`            — integer dot-product units (codes of the
                                  ideal digital MAC, pre-ADC);
  * `*_codes`                  — ADC output codes in [0, 2^r_out);
  * `g0`                       — codes per dp unit at gamma=1 (unitless);
  * `*_v`                      — volts (only inside the noise model);
  * activations in/out         — real-valued (dequantized) float32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import abn as abn_lib
from repro.core import digital_ref, mapping
from repro.core import noise_model as nm
from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO
from repro.core.noise_model import NO_NOISE, NoiseConfig
from repro.core.quantization import _static_reciprocal, rounding_barrier
from repro.kernels.cim_mbiw import ops as kops

Params = List[Dict[str, jnp.ndarray]]

# incremented once per jit trace of the schedule (a trace == a compile);
# tests assert that a noise operating-point sweep does not grow it
TRACE_COUNT = {"n": 0}

# incremented once per plan_network() that actually plans (a compiled
# program is planned exactly once; repeated dispatches through the
# runtime.program cache must be cache hits) — the planning-side mirror of
# TRACE_COUNT, asserted by tests/test_program.py
PLAN_COUNT = {"n": 0}

# thermal kT/C draws are generated per fixed-size global GEMM-row block
# (keys fold the block index), then sliced to the live extent: the values a
# given (layer, row tile, col tile, GEMM row) sees are invariant to the
# total row extent, so batch-bucket padding, stream_rows chunking and
# device sharding all reuse identical draws (jax's threefry bits are NOT
# prefix-stable across draw shapes, so a single full-extent draw would
# change every value whenever padding changed the extent)
NOISE_ROW_BLOCK = 128

_DEPRECATION = {"warned": False}


def _warn_legacy_entry(name: str) -> None:
    """One non-spammy DeprecationWarning per process for the per-call API."""
    if _DEPRECATION["warned"]:
        return
    _DEPRECATION["warned"] = True
    import warnings
    warnings.warn(
        f"{name} re-enters the engine per call; compile once with "
        "repro.runtime.program.compile_program(...) (or "
        "CIMInferenceEngine.compile()) and serve through the returned "
        "CIMProgram/BoundProgram for the plan-once/serve-many path",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """Multi-macro (multi-device) partitioning of the planned schedule.

    Attributes:
      devices: mesh size D; 0 means "every device jax reports at plan
        time".  The run raises if fewer devices are visible at dispatch.
      axis: mesh axis name (purely cosmetic; shows up in shard_map specs).

    Per-layer kind selection (col tiles vs GEMM rows) is automatic — see
    `mapping.shard_layer`.  A `devices=1` config is a valid degenerate
    case that still routes dispatch through shard_map on a 1-device mesh.
    """
    devices: int = 0
    axis: str = "macro"

    def resolve_devices(self) -> int:
        """Concrete mesh size: `devices`, or every visible device."""
        return self.devices if self.devices > 0 else jax.device_count()


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Execution configuration shared by every layer of a schedule."""
    macro: CIMMacroConfig = DEFAULT_MACRO
    adaptive_swing: bool = True      # serial-split DPL swing adaptation
    gamma_bits: int = -1             # -1: continuous gamma; >=0: HW quant
    max_gamma: float = 32.0
    interpret: bool = True           # Pallas interpret mode (CPU) vs TPU
    bm: int = 128                    # kernel block sizes (MXU-aligned),
    bn: int = 128                    # clamped per dispatched tile geometry
    bk: int = 256
    stream_rows: int = 0             # im2col streaming: GEMM rows per kernel
                                     # dispatch (0 = single dispatch); bounds
                                     # the Pallas working set for large maps
    noise: NoiseConfig = NO_NOISE    # post-silicon equivalent noise model;
                                     # enabled -> runs require a PRNG key
    sharding: Optional[ShardingConfig] = None  # multi-macro dispatch; None
                                     # keeps the single-device path

    def replace(self, **kw) -> "EngineConfig":
        """A copy with the given fields replaced (dataclasses.replace)."""
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer's macro-tile schedule.

    `n_slices` are *uniform* col tiles (mapping.split_even_slices): every
    tile spans `tile_n` channels and the covered extent `n_pad` may exceed
    spec.n — execution pads the column arrays and discards the excess.
    Uniformity is what lets col tiles dispatch SPMD across devices and
    keeps noise draws device-count independent.  `shard` is the layer's
    device partition (None on single-device plans).  `blocks` is an
    optional per-layer (bm, bn, bk) kernel block-size override (the
    schedule autotuner's knob); None uses the EngineConfig defaults —
    either way the kernel is numerically identical at any block size, so
    `blocks` only moves DMA traffic, never bits."""
    spec: mapping.LayerSpec
    mp: mapping.MacroMapping
    precision: kops.KernelPrecision
    g0: float                            # unity-gain codes per dp unit
    k_slices: Tuple[Tuple[int, int], ...]  # (start, size) row tiles
    n_slices: Tuple[Tuple[int, int], ...]  # (start, size) uniform col tiles
    activation: str = "none"             # "none" | "relu"
    pool: int = 1                        # max-pool window/stride epilogue
    shard: Optional[mapping.LayerShard] = None
    blocks: Optional[Tuple[int, int, int]] = None  # tuned (bm, bn, bk)

    @property
    def macro_evals(self) -> int:
        """Macro invocations per M-row batch: row tiles x col tiles."""
        return len(self.k_slices) * len(self.n_slices)

    @property
    def tile_n(self) -> int:
        """Channels per (uniform) col tile."""
        return self.n_slices[0][1]

    @property
    def n_pad(self) -> int:
        """Column extent covered by the uniform col tiles (>= spec.n)."""
        return len(self.n_slices) * self.tile_n

    @property
    def out_shape(self) -> Tuple[int, ...]:
        """Per-sample feature shape this layer emits (after pooling)."""
        g = self.spec.conv
        if g is None:
            return (self.spec.n,)
        return (g.out_h // self.pool, g.out_w // self.pool, g.c_out)


@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    """An immutable, hashable planned schedule (the jit static argument)."""
    layers: Tuple[LayerPlan, ...]
    cfg: EngineConfig

    @property
    def precisions(self) -> Tuple[kops.KernelPrecision, ...]:
        """Distinct kernel operating points, in first-use order (the
        compiled-variant table of the schedule)."""
        seen: List[kops.KernelPrecision] = []
        for lp in self.layers:
            if lp.precision not in seen:
                seen.append(lp.precision)
        return tuple(seen)

    @property
    def total_macro_evals(self) -> int:
        """Schedule-wide macro invocations per M-row batch of work."""
        return sum(lp.macro_evals for lp in self.layers)


def _layer_g0(spec: mapping.LayerSpec, mp: mapping.MacroMapping,
              cfg: EngineConfig) -> float:
    macro = cfg.macro
    units = mp.units_per_tile if cfg.adaptive_swing else macro.n_units
    n_dp = units * macro.rows_per_unit
    return digital_ref.adc_gain_factor(
        spec.r_in, spec.r_w, spec.r_out, n_dp,
        macro.swing_efficiency(units), macro.alpha_adc())


def plan_layer(spec: mapping.LayerSpec, cfg: EngineConfig = EngineConfig(),
               activation: str = "none", pool: int = 1, *,
               blocks: Optional[Tuple[int, int, int]] = None,
               shard_kind: Optional[str] = None) -> LayerPlan:
    """Plan one layer: macro mapping, uniform col tiles, device partition.

    Args:
      spec: the GEMM/conv layer.
      cfg: shared execution config; cfg.sharding (if set) adds the layer's
        LayerShard for cfg.sharding.resolve_devices() macros.
      activation: "none" | "relu" epilogue.
      pool: max-pool window/stride (conv layers only, 1 = none).
      blocks: optional per-layer (bm, bn, bk) kernel block override (the
        schedule autotuner's winner); None keeps cfg.bm/bn/bk.  Numerics-
        neutral at any value (exact int32 accumulation).
      shard_kind: optional explicit "col"/"rows" shard kind (requires
        cfg.sharding); None keeps mapping.shard_layer's heuristic.
    Returns:
      LayerPlan (hashable; part of the jit-static NetworkPlan).
    """
    if pool < 1:
        raise ValueError(f"pool must be >= 1, got {pool}")
    if blocks is not None:
        blocks = tuple(int(b) for b in blocks)
        if len(blocks) != 3 or min(blocks) < 1:
            raise ValueError(f"blocks must be 3 positive ints, got {blocks}")
    if shard_kind is not None and cfg.sharding is None:
        raise ValueError("shard_kind override requires cfg.sharding")
    if pool > 1 and spec.conv is None:
        raise ValueError("pooling epilogue requires a conv layer")
    if spec.conv is not None:
        g = spec.conv
        if spec.k != g.kh * g.kw * g.c_in or spec.n != g.c_out:
            raise ValueError(
                f"conv geometry {g} inconsistent with GEMM view "
                f"k={spec.k} n={spec.n}")
        if pool > 1 and (g.out_h < pool or g.out_w < pool):
            raise ValueError(f"pool {pool} larger than conv output "
                             f"{g.out_h}x{g.out_w}")
    mp = mapping.map_layer(spec, cfg.macro)
    prec = kops.KernelPrecision(spec.r_in, spec.r_w, spec.r_out)
    shard = None
    if cfg.sharding is not None:
        shard = mapping.shard_layer(spec, mp, cfg.sharding.resolve_devices(),
                                    kind=shard_kind)
    return LayerPlan(
        spec=spec, mp=mp, precision=prec, g0=_layer_g0(spec, mp, cfg),
        k_slices=tuple(mapping.split_k_slices(spec.k, mp.row_tiles)),
        n_slices=tuple(mapping.split_even_slices(spec.n, mp.col_tiles)),
        activation=activation, pool=pool, shard=shard, blocks=blocks)


def _check_chain(layers: Sequence[LayerPlan]) -> None:
    """Feed-forward shape check across the mixed conv/dense chain: a dense
    layer's K must equal the flattened feature count of its predecessor, a
    conv layer's (h, w, c_in) must equal the predecessor's spatial output."""
    prev: Optional[LayerPlan] = None
    for i, lp in enumerate(layers):
        g = lp.spec.conv
        if prev is not None:
            out = prev.out_shape
            if g is None:
                feed = 1
                for d in out:
                    feed *= d
                if feed != lp.spec.k:
                    raise ValueError(
                        f"layer chain mismatch: layer {i-1} emits {out} "
                        f"({feed} features) but layer {i} expects "
                        f"k={lp.spec.k}")
            else:
                if len(out) != 3:
                    raise ValueError(
                        f"layer chain mismatch: conv layer {i} needs NHWC "
                        f"input but layer {i-1} emits flat {out}")
                if out != g.spatial_in:
                    raise ValueError(
                        f"layer chain mismatch: layer {i-1} emits {out} "
                        f"but conv layer {i} expects {g.spatial_in}")
                if prev.spec.conv is not None \
                        and prev.spec.conv.batch != g.batch:
                    raise ValueError(
                        f"layer chain mismatch: conv batch "
                        f"{prev.spec.conv.batch} != {g.batch} at layer {i}")
        prev = lp


def plan_network(specs: Sequence[mapping.LayerSpec],
                 cfg: EngineConfig = EngineConfig(),
                 activations: Optional[Sequence[str]] = None,
                 pools: Optional[Sequence[int]] = None, *,
                 schedule: Optional[Sequence] = None) -> NetworkPlan:
    """Plan a feed-forward network of dense and conv-tagged LayerSpecs.

    `activations`: per-layer epilogue nonlinearity; defaults to relu between
    layers and none after the last (the CNN workloads of the paper).
    `pools`: per-layer max-pool window/stride (1 = none, conv layers only),
    applied after the activation — together with the automatic conv -> dense
    flatten this covers the paper's LeNet-class CNNs.
    `schedule`: optional per-layer schedule overrides from the autotuner —
    one `None` (heuristic) or `(blocks, shard_kind)` pair per layer, where
    `blocks` is a (bm, bn, bk) tuple or None and `shard_kind` an explicit
    "col"/"rows" or None.  Overrides never change numerics, only which
    compiled kernel variants and device partition execute the same math.
    """
    specs = list(specs)
    if activations is None:
        activations = ["relu"] * (len(specs) - 1) + ["none"]
    if len(activations) != len(specs):
        raise ValueError("one activation per layer required")
    if pools is None:
        pools = [1] * len(specs)
    if len(pools) != len(specs):
        raise ValueError("one pool factor per layer required")
    if schedule is None:
        schedule = [None] * len(specs)
    if len(schedule) != len(specs):
        raise ValueError("one schedule override (or None) per layer "
                         "required")
    layers = tuple(plan_layer(
        s, cfg, act, pool,
        blocks=None if sc is None else sc[0],
        shard_kind=None if sc is None else sc[1])
        for s, act, pool, sc in zip(specs, activations, pools, schedule))
    _check_chain(layers)
    PLAN_COUNT["n"] += 1
    return NetworkPlan(layers=layers, cfg=cfg)


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def im2col_patches(x: jnp.ndarray, g: mapping.ConvGeometry) -> jnp.ndarray:
    """(B, H, W, C_in) -> (B, out_h, out_w, kh*kw*C_in) patch tensor whose
    trailing axis matches the engine's (K, N) weight layout."""
    patches = jax.lax.conv_general_dilated_patches(
        x, (g.kh, g.kw), (g.stride, g.stride), padding=list(g.padding),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    b, oh, ow, kf = patches.shape
    # conv_general_dilated_patches returns channel-major (C*kh*kw) features;
    # weights are laid out (kh*kw*C) — reorder to match (cf. cim_layers).
    patches = patches.reshape(b, oh, ow, g.c_in, g.kh * g.kw)
    return jnp.swapaxes(patches, -1, -2).reshape(b, oh, ow, kf)


def _pad_dim(x: jnp.ndarray, axis: int, size: int,
             value: float = 0.0) -> jnp.ndarray:
    """Pad `axis` of `x` up to `size` with a constant (no-op if already)."""
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg, constant_values=value)


def bind_layer(lp: LayerPlan, params: Dict[str, jnp.ndarray],
               cfg: EngineConfig) -> Dict[str, jnp.ndarray]:
    """Precompute one layer's weight-side operands (the `bind` stage).

    Everything here depends only on the parameters and the plan — not on the
    activations — so a compiled program computes it once
    (`CIMProgram.bind(params)`) and removes weight quantization + ABN gamma
    evaluation from the per-call path; the legacy per-call entry points run
    the same function inside their jitted graph.

    Args:
      lp: the planned layer.
      params: {"w" (K, N), "abn_log_gamma" (N,), "abn_beta" (N,)}.
      cfg: shared execution config (gamma quantization settings).
    Returns:
      dict of arrays, column-padded to the plan's uniform col-tile extent:
      "wqq" (K, n_pad) odd-integer weight codes, "w_scale" (N,) dequant
      scale, "gamma_p"/"beta_p" (n_pad,) padded ABN gain/offset (gamma pads
      with 1.0 — it divides in the dequant).
    """
    from repro.core.quantization import quantize_weight
    wq = quantize_weight(params["w"], lp.spec.r_w, axis=0)
    gamma = abn_lib.abn_gamma(
        abn_lib.ABNParams(params["abn_log_gamma"], params["abn_beta"]),
        gamma_bits=cfg.gamma_bits, max_gamma=cfg.max_gamma)
    n_pad = lp.n_pad
    return {
        "wqq": _pad_dim(wq.q, 1, n_pad),
        "w_scale": wq.scale.reshape(-1),
        "gamma_p": _pad_dim(gamma, 0, n_pad, value=1.0),
        "beta_p": _pad_dim(params["abn_beta"], 0, n_pad),
    }


def bind_network(plan: NetworkPlan, params: Params) -> Tuple[Dict, ...]:
    """bind_layer over a whole plan: one weight-side operand dict per layer
    (the payload of a BoundProgram).  Validates the per-layer param count."""
    if len(params) != len(plan.layers):
        raise ValueError(f"{len(params)} param dicts for "
                         f"{len(plan.layers)} planned layers")
    return tuple(bind_layer(lp, p, plan.cfg)
                 for lp, p in zip(plan.layers, params))


def _mask_pad_rows(x: jnp.ndarray, m_valid: jnp.ndarray) -> jnp.ndarray:
    """Overwrite batch rows at index >= m_valid with a copy of row 0.

    Batch-bucketed dispatch pads the leading batch axis up to a bucket
    size; this runs before every layer so the padded rows are always
    duplicates of a live row when the dynamic activation quantization
    computes its global min/max (duplicates never move a min/max), keeping
    the valid rows bit-exact with an unpadded run — even in noise mode,
    where the padded rows decorrelate from their source within a layer."""
    idx = jax.lax.broadcasted_iota(
        jnp.int32, (x.shape[0],) + (1,) * (x.ndim - 1), 0)
    return jnp.where(idx < m_valid, x, x[:1])


@dataclasses.dataclass
class _LayerNoise:
    """Per-layer noise context of one engine run (built at trace time).

    `offset_codes`/`droop_codes` are per padded output column (code units);
    tiles slice them.  `gain_mult` collects the deterministic INL terms
    (DPL settling, MBIW charge injection) as a multiplier on the code gain.
    `thermal` holds the pre-drawn kT/C noise in dp units for every
    (row tile, col tile) over the layer's full GEMM-row extent — shape
    (k_tiles, n_tiles_padded, rows, tile_n) — so slicing rows (stream
    chunks, row shards) or col tiles (device shards) never changes a
    draw: noisy execution is chunking- and device-count-invariant."""
    offset_codes: jnp.ndarray        # (n_cols_padded,) code units
    droop_codes: jnp.ndarray         # (n_cols_padded,) code units
    gain_mult: jnp.ndarray           # scalar multiplier on gamma * g0
    thermal: jnp.ndarray             # (KT, NT_pad, rows, tile_n) dp units

    def rows(self, sl: slice) -> "_LayerNoise":
        """The context restricted to a GEMM-row slice."""
        return dataclasses.replace(self, thermal=self.thermal[:, :, sl, :])


def _layer_noise(lp: LayerPlan, cfg: EngineConfig, noise: NoiseConfig,
                 gamma_p: jnp.ndarray, key: jax.Array, m: int,
                 row_ids: Optional[jnp.ndarray] = None,
                 row_sub: Optional[jnp.ndarray] = None) -> _LayerNoise:
    """Noise terms of one layer in code/dp units, injected exactly where the
    fakequant (thermal, SA residue) and sim (settling, charge injection,
    leakage) paths put them.  `noise` carries *traced* scalars; only its
    enabled/calibrated flags are static.  `gamma_p` is the column-padded
    ABN gain; `m` the layer's full GEMM-row extent (thermal draws cover it
    once, device/chunk slices reuse them).

    `row_ids`/`row_sub` (optional, (m,) int32) switch the thermal draws
    from *positional* global-row-block keys to *identity* keys: each GEMM
    row's draw folds its caller-assigned id (and an intra-sample counter
    for the conv im2col expansion) instead of its position in the batch.
    An in-flight scheduler derives ids from (request uid, token step), so
    a request's draws are invariant to its slot, its batchmates, and the
    dispatch extent — the noise-mode half of per-request isolation."""
    macro, spec = cfg.macro, lp.spec
    units = lp.mp.units_per_tile if cfg.adaptive_swing else macro.n_units
    # memory note: the thermal field is O(row_tiles * n_pad * m) floats
    # (m rounded up to NOISE_ROW_BLOCK) — the same order as the layer's
    # aq.q/dp_hat buffers the engine already materializes (a small constant
    # factor, not a new asymptotic class), but it is NOT bounded by
    # stream_rows.
    # static per-physical-column SA offsets after 7b calibration, shared
    # across col tiles (the macro is reused sequentially)
    res_v = nm.sample_column_residues(jax.random.fold_in(key, 0), spec.n,
                                      spec.r_w, noise, macro)
    res_v = _pad_dim(res_v, 0, gamma_p.shape[0])
    lsb0_v = macro.alpha_adc() * macro.vddh / 2.0 ** (spec.r_out - 1)
    # volts -> codes conversions feed the ADC floor: pre-fold the LSB
    # divide into a trace-time reciprocal and pin the products, exactly
    # like the gain*dp product in the ADC epilogue (cimcheck NB001/NB002)
    inv_lsb0 = _static_reciprocal(lsb0_v)
    offset_codes = rounding_barrier(gamma_p * res_v * inv_lsb0)
    # leakage droop on V_acc, attenuated by the weight-parallel combination
    droop_v = nm.leakage_droop(spec.r_in, macro.t_dp_ns, noise) \
        * (1.0 - 2.0 ** (-spec.r_w))
    droop_codes = rounding_barrier(gamma_p * droop_v * inv_lsb0)
    settle = nm.settle_fraction(units, macro.t_dp_ns, noise)
    ci = nm.charge_injection_gain(spec.r_in, noise, macro)
    sigma_dp = nm.thermal_sigma_dp(noise, spec.r_out, lp.g0)
    # one independent field per (row tile, col tile) spanning all GEMM rows,
    # generated in fixed NOISE_ROW_BLOCK-row blocks whose keys fold the
    # *global* (row tile, col tile, row block) indices: any partition of
    # rows or tiles across chunks/devices sees identical values, and a
    # batch-bucketed run (rows padded past the live extent) only *extends*
    # the field — the live-row prefix never changes
    tkey = jax.random.fold_in(key, 1)
    tsz = lp.tile_n
    n_blocks = -(-max(m, 1) // NOISE_ROW_BLOCK)

    def tile_field(ki: int, ni: int) -> jnp.ndarray:
        kt = jax.random.fold_in(jax.random.fold_in(tkey, ki), ni)
        if row_ids is not None:
            # identity-keyed draws: fold each row's caller id + intra-
            # sample counter, so the value a row sees depends only on
            # what it *is*, never on where it sits in the batch
            sub = (row_sub if row_sub is not None
                   else jnp.zeros_like(row_ids))

            def draw(rid, sb):
                rk = jax.random.fold_in(jax.random.fold_in(kt, rid), sb)
                return jax.random.normal(rk, (tsz,))
            return jax.vmap(draw)(row_ids, sub)
        blocks = [jax.random.normal(jax.random.fold_in(kt, b),
                                    (NOISE_ROW_BLOCK, tsz))
                  for b in range(n_blocks)]
        field = blocks[0] if n_blocks == 1 else jnp.concatenate(blocks)
        return field[:m]

    thermal = jnp.stack([
        jnp.stack([sigma_dp * tile_field(ki, ni)
                   for ni in range(len(lp.n_slices))])
        for ki in range(len(lp.k_slices))])
    return _LayerNoise(
        offset_codes=offset_codes, droop_codes=droop_codes,
        gain_mult=jnp.asarray(settle * (1.0 + ci), jnp.float32),
        thermal=thermal)


def _noise_adc_code(lp: LayerPlan, dp: jnp.ndarray, gamma_t: jnp.ndarray,
                    beta_eff: jnp.ndarray, nctx: _LayerNoise,
                    n_slice: Tuple[int, int],
                    thermal: jnp.ndarray) -> jnp.ndarray:
    """ADC conversion of one macro tile's raw dp with the noise terms
    applied pre-floor — the engine-side mirror of fakequant's
    adc_quantize(dp + thermal, gain, beta + offsets).  `thermal` is the
    tile's pre-drawn kT/C slice (dp units, already row-aligned)."""
    ns, ne = n_slice
    dp = dp.astype(jnp.float32) + thermal
    mid = 2.0 ** (lp.spec.r_out - 1)
    code = jnp.floor(mid + rounding_barrier(gamma_t * lp.g0
                                            * nctx.gain_mult * dp)
                     + beta_eff
                     + nctx.offset_codes[ns:ne] - nctx.droop_codes[ns:ne])
    return jnp.clip(code, 0.0, 2.0 ** lp.spec.r_out - 1.0).astype(jnp.int32)


def _tile_schedule(lp: LayerPlan, q_rows: jnp.ndarray, zp: jnp.ndarray,
                   wqq: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
                   *, matmul,
                   nctx: Optional[_LayerNoise] = None) -> jnp.ndarray:
    """One block of GEMM rows through a (k, n) tile schedule.

    `wqq`/`gamma`/`beta` span a whole number of uniform col tiles (the
    caller's local column extent — all tiles on a single-device run, one
    device's tiles under col sharding); `matmul` evaluates one macro tile
    (kernel variant or jnp oracle) and returns int32 ADC codes — or raw
    int32 dp when a noise context is given, in which case the ADC
    conversion (with the noise terms and the tile's pre-drawn thermal
    slice) runs here.  Returns dp_hat (rows, local cols) in dp units."""
    mid = 2.0 ** (lp.spec.r_out - 1)
    g0 = lp.g0
    tsz = lp.tile_n
    # materialized ADC gain: the fakequant reference and this schedule must
    # dequantize with the identical float in every fusion context
    # (quantization.rounding_barrier)
    gain = rounding_barrier(gamma * g0)
    dp_hat = []
    for ni in range(wqq.shape[1] // tsz):
        ns, ne = ni * tsz, (ni + 1) * tsz
        acc = jnp.zeros((q_rows.shape[0], tsz), jnp.float32)
        for ki, (ks, ksz) in enumerate(lp.k_slices):
            ke = ks + ksz
            # zero-point: x = q*s + z -> z*colsum is per-channel constant,
            # folded into the ABN offset inside the ADC floor
            zp_dp = zp * jnp.sum(wqq[ks:ke, ns:ne], axis=0)
            beta_eff = beta[ns:ne] + rounding_barrier(gain[ns:ne] * zp_dp)
            out = matmul(q_rows[:, ks:ke], wqq[ks:ke, ns:ne],
                         gamma[ns:ne], beta_eff, g0)
            if nctx is None:
                codes = out
            else:
                codes = _noise_adc_code(lp, out, gamma[ns:ne], beta_eff,
                                        nctx, (ns, ne),
                                        nctx.thermal[ki, ni])
            # digital partial-sum recombination in dp units; dequantizing
            # against the *raw* beta keeps the zero-point contribution in
            # dp_hat, exactly like the fakequant training path
            acc = acc + (codes.astype(jnp.float32) + 0.5 - mid
                         - beta[None, ns:ne]) / gain[None, ns:ne]
        dp_hat.append(acc)
    return jnp.concatenate(dp_hat, axis=-1)


def _schedule_rows(lp: LayerPlan, cfg: EngineConfig, q_rows: jnp.ndarray,
                   zp: jnp.ndarray, wqq: jnp.ndarray, gamma: jnp.ndarray,
                   beta: jnp.ndarray, *, matmul,
                   nctx: Optional[_LayerNoise]) -> jnp.ndarray:
    """Stream `q_rows` through the tile schedule in cfg.stream_rows chunks
    (the im2col streaming stage).  Quantization stays global (or
    per-segment — `zp` is then per-row and chunks alongside the rows) and
    the noise context pre-draws per-tile thermal fields over all rows, so
    chunking is bit-invariant — with or without noise."""
    m = q_rows.shape[0]
    chunk = cfg.stream_rows if cfg.stream_rows > 0 else max(m, 1)
    parts = []
    for s in range(0, max(m, 1), chunk):
        sl = slice(s, min(s + chunk, m))
        parts.append(_tile_schedule(
            lp, q_rows[sl], zp if zp.ndim == 0 else zp[sl], wqq, gamma,
            beta, matmul=matmul,
            nctx=nctx.rows(sl) if nctx is not None else None))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)


def _engine_mesh(sharding: ShardingConfig, devices: int):
    from repro.launch.mesh import make_engine_mesh
    return make_engine_mesh(devices, sharding.axis)


def _sharded_schedule(lp: LayerPlan, cfg: EngineConfig, q_rows: jnp.ndarray,
                      zp: jnp.ndarray, wqq: jnp.ndarray, gamma: jnp.ndarray,
                      beta: jnp.ndarray, *, matmul,
                      nctx: Optional[_LayerNoise]) -> jnp.ndarray:
    """Dispatch one layer's tile schedule across the device mesh.

    kind "col": the uniform col tiles (padded up to a multiple of the
    device count with all-zero dummy tiles) spread over the mesh axis —
    each device runs `_schedule_rows` on its contiguous tile group, output
    columns concatenate across devices.  kind "rows": the GEMM rows
    (zero-padded to a multiple of the device count) spread instead, every
    device holding the full weight tiles.  The per-device body is the same
    `_schedule_rows` the serial path runs, and all noise terms are
    pre-drawn outside the shard_map, so both kinds are bit-exact with the
    single-device schedule (padding only ever adds discarded rows/cols)."""
    from jax.sharding import PartitionSpec as P

    from repro.jax_compat import shard_map

    shard, m = lp.shard, q_rows.shape[0]
    mesh = _engine_mesh(cfg.sharding, shard.devices)
    ax = cfg.sharding.axis
    noisy = nctx is not None

    def body(q_l, zp_l, wq_l, g_l, b_l, *noise_l):
        nl = _LayerNoise(*noise_l) if noisy else None
        return _schedule_rows(lp, cfg, q_l, zp_l, wq_l, g_l, b_l,
                              matmul=matmul, nctx=nl)

    if shard.kind == "col":
        t_tot = shard.devices * shard.tiles_per_device
        n_tot = t_tot * lp.tile_n
        wqq = _pad_dim(wqq, 1, n_tot)
        gamma = _pad_dim(gamma, 0, n_tot, value=1.0)   # 1.0: dequant div
        beta = _pad_dim(beta, 0, n_tot)
        args = [q_rows, zp, wqq, gamma, beta]
        specs = [P(), P(), P(None, ax), P(ax), P(ax)]
        if noisy:
            args += [_pad_dim(nctx.offset_codes, 0, n_tot),
                     _pad_dim(nctx.droop_codes, 0, n_tot),
                     nctx.gain_mult, _pad_dim(nctx.thermal, 1, t_tot)]
            specs += [P(ax), P(ax), P(), P(None, ax, None, None)]

        out = shard_map(body, mesh=mesh, in_specs=tuple(specs),
                        out_specs=P(None, ax), check_vma=False)(*args)
        return out                       # (m, n_tot); caller slices cols

    # kind == "rows": data-parallel over the GEMM-row dimension; a per-row
    # zero-point (segment quantization) shards with its rows, a global
    # scalar replicates
    m_tot = shard.devices * -(-max(m, 1) // shard.devices)
    q_pad = _pad_dim(q_rows, 0, m_tot)
    zp_arg = zp if zp.ndim == 0 else _pad_dim(zp, 0, m_tot)
    zp_spec = P() if zp.ndim == 0 else P(ax, None)
    args = [q_pad, zp_arg, wqq, gamma, beta]
    specs = [P(ax, None), zp_spec, P(), P(), P()]
    if noisy:
        args += [nctx.offset_codes, nctx.droop_codes, nctx.gain_mult,
                 _pad_dim(nctx.thermal, 2, m_tot)]
        specs += [P(), P(), P(), P(None, None, ax, None)]

    out = shard_map(body, mesh=mesh, in_specs=tuple(specs),
                    out_specs=P(ax, None), check_vma=False)(*args)
    return out[:m]                       # drop row padding


def _layer_tiles(lp: LayerPlan, bind: Dict[str, jnp.ndarray],
                 x2: jnp.ndarray, cfg: EngineConfig, *, matmul,
                 key: Optional[jax.Array] = None,
                 noise: Optional[NoiseConfig] = None,
                 sharded: bool = False,
                 seg_rows: Optional[jnp.ndarray] = None,
                 nid_rows: Optional[jnp.ndarray] = None,
                 sub_rows: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Run one layer's tile schedule over (M, K) GEMM rows.

    `bind` carries the precomputed weight-side operands (bind_layer);
    activation quantization and the noise context (offsets, per-tile
    thermal fields) are built globally per call, then the schedule executes
    serially in stream chunks or sharded across the mesh — numerically
    identical paths.

    `seg_rows` (optional, (M,) int32) switches the activation quantization
    to per-segment statistics (quantize_act segment path): the zero-point
    becomes per-row and folds into a per-row beta_eff inside the ADC
    floor, so rows of different segments never share swing state.
    `nid_rows`/`sub_rows` key the noise model's thermal draws by row
    identity instead of position (see _layer_noise)."""
    from repro.core.quantization import quantize_act
    if seg_rows is None:
        aq = quantize_act(x2, lp.spec.r_in)
    else:
        aq = quantize_act(x2, lp.spec.r_in, segment_ids=seg_rows,
                          num_segments=x2.shape[0])
    n = lp.spec.n
    wqq, gamma_p, beta_p = bind["wqq"], bind["gamma_p"], bind["beta_p"]
    m = x2.shape[0]
    nctx = (_layer_noise(lp, cfg, noise, gamma_p, key, m,
                         row_ids=nid_rows, row_sub=sub_rows)
            if noise is not None else None)
    zp = jnp.asarray(aq.zero / aq.scale, jnp.float32)
    if sharded and lp.shard is not None:
        dp_hat = _sharded_schedule(lp, cfg, aq.q, zp, wqq, gamma_p, beta_p,
                                   matmul=matmul, nctx=nctx)
    else:
        dp_hat = _schedule_rows(lp, cfg, aq.q, zp, wqq, gamma_p, beta_p,
                                matmul=matmul, nctx=nctx)
    y = dp_hat[:, :n] * aq.scale * bind["w_scale"]
    if lp.activation == "relu":
        y = jax.nn.relu(y)
    elif lp.activation != "none":
        raise ValueError(f"unknown activation {lp.activation!r}")
    return y


def _run_layer(lp: LayerPlan, bind: Dict[str, jnp.ndarray], x: jnp.ndarray,
               cfg: EngineConfig, *, matmul,
               key: Optional[jax.Array] = None,
               noise: Optional[NoiseConfig] = None,
               sharded: bool = False,
               seg: Optional[jnp.ndarray] = None,
               nids: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """One planned layer end-to-end: im2col (conv), tile schedule,
    activation, pooling, and the reshape back to the next layer's view.

    `seg`/`nids` are per *batch sample* (B,) segment and noise-identity
    ids; a conv layer's im2col expansion repeats them across the sample's
    out_h*out_w GEMM rows (plus an intra-sample counter for the noise
    draws), a dense layer uses them as-is."""
    g = lp.spec.conv
    if g is not None:
        if x.ndim != 4 or x.shape[1:] != g.spatial_in:
            raise ValueError(
                f"conv layer expects (B, {g.h}, {g.w}, {g.c_in}) "
                f"activations, got {x.shape}")
        b = x.shape[0]
        rep = g.out_h * g.out_w
        x2 = im2col_patches(x, g).reshape(b * rep, lp.spec.k)
        seg_rows = None if seg is None else jnp.repeat(seg, rep)
        nid_rows = None if nids is None else jnp.repeat(nids, rep)
        sub_rows = (None if nids is None else
                    jnp.tile(jnp.arange(rep, dtype=jnp.int32), b))
    else:
        x2 = x.reshape(x.shape[0], -1)        # conv -> dense flatten (NHWC)
        if x2.shape[-1] != lp.spec.k:
            raise ValueError(f"dense layer expects {lp.spec.k} features, "
                             f"got {x2.shape[-1]} from {x.shape}")
        seg_rows, nid_rows, sub_rows = seg, nids, None
    y = _layer_tiles(lp, bind, x2, cfg, matmul=matmul, key=key,
                     noise=noise, sharded=sharded, seg_rows=seg_rows,
                     nid_rows=nid_rows, sub_rows=sub_rows)
    if g is not None:
        y = y.reshape(b, g.out_h, g.out_w, g.c_out)
    if lp.pool > 1:
        y = jax.lax.reduce_window(
            y, -jnp.inf, jax.lax.max, (1, lp.pool, lp.pool, 1),
            (1, lp.pool, lp.pool, 1), "VALID")
    return y


def _kernel_matmul(lp: LayerPlan, cfg: EngineConfig):
    # under noise the kernel dispatches in raw-dp mode; the noise ADC
    # epilogue in _tile_schedule owns the conversion
    fuse = not cfg.noise.enabled
    # per-layer tuned blocks (autotuner winners) override the config-wide
    # defaults; the kernel is bit-identical at any block size
    bm, bn, bk = lp.blocks if lp.blocks is not None \
        else (cfg.bm, cfg.bn, cfg.bk)

    def matmul(xq, wqt, gamma_t, beta_t, g0):
        # variant cache keyed on the dispatched tile geometry: per-device
        # tiles of a sharded schedule get fitted block sizes, not
        # full-macro padding
        fn = kops.kernel_variant_for_tile(
            lp.precision, xq.shape[0], xq.shape[1], wqt.shape[1],
            bm=bm, bn=bn, bk=bk, interpret=cfg.interpret,
            fuse_adc=fuse)
        return fn(xq, wqt, gamma_t, beta_t, g0)
    return matmul


def _reference_matmul(lp: LayerPlan, cfg: EngineConfig):
    from repro.kernels.cim_mbiw.ref import cim_matmul_ref

    if cfg.noise.enabled:
        def matmul(xq, wqt, gamma_t, beta_t, g0):
            # raw integer dp: the shared noise ADC epilogue runs outside,
            # so kernel and reference stay bit-exact under a common key
            return xq.astype(jnp.int32) @ wqt.astype(jnp.int32)
        return matmul

    def matmul(xq, wqt, gamma_t, beta_t, g0):
        # the shared oracle keeps the ADC floor expression in float-op
        # lockstep with the kernel epilogue (bit-exactness contract)
        return cim_matmul_ref(xq, wqt, gamma_t, beta_t, g0=g0,
                              r_out=lp.spec.r_out)
    return matmul


def _forward(plan: NetworkPlan, binds: Sequence[Dict[str, jnp.ndarray]],
             x: jnp.ndarray, reference: bool,
             key: Optional[jax.Array] = None,
             noise: Optional[NoiseConfig] = None,
             m_valid: Optional[jnp.ndarray] = None,
             seg: Optional[jnp.ndarray] = None,
             nids: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    if plan.cfg.noise.enabled and key is None:
        raise ValueError(
            "noise-injected engine run requires a PRNG key: pass key= to "
            "run_network/CIMInferenceEngine.__call__ (or plan with "
            "noise=NO_NOISE for the deterministic deployed path)")
    g0 = plan.layers[0].spec.conv
    if g0 is not None:
        if x.ndim < 4 or x.shape[-3:] != g0.spatial_in:
            raise ValueError(
                f"input shape {x.shape} != first conv layer's "
                f"(..., {g0.h}, {g0.w}, {g0.c_in})")
        lead = x.shape[:-3]
        xc = x.reshape((-1,) + x.shape[-3:]).astype(jnp.float32)
    else:
        k0 = plan.layers[0].spec.k
        if x.shape[-1] != k0:
            raise ValueError(
                f"input width {x.shape[-1]} != first layer's k={k0}")
        lead = x.shape[:-1]
        xc = x.reshape((-1, x.shape[-1])).astype(jnp.float32)
    noisy = noise is not None
    sharded = (not reference) and plan.cfg.sharding is not None
    if seg is not None and seg.shape[0] != xc.shape[0]:
        raise ValueError(f"segments extent {seg.shape[0]} != canonical "
                         f"batch extent {xc.shape[0]}")
    if nids is not None and nids.shape[0] != xc.shape[0]:
        raise ValueError(f"noise_ids extent {nids.shape[0]} != canonical "
                         f"batch extent {xc.shape[0]}")
    for i, (lp, bind) in enumerate(zip(plan.layers, binds)):
        if m_valid is not None:       # batch-bucketed run: re-pin pad rows
            xc = _mask_pad_rows(xc, m_valid)
        mk = _reference_matmul if reference else _kernel_matmul
        lkey = jax.random.fold_in(key, i) if noisy else None
        xc = _run_layer(lp, bind, xc, plan.cfg, matmul=mk(lp, plan.cfg),
                        key=lkey, noise=noise, sharded=sharded, seg=seg,
                        nids=nids)
    return xc.reshape(lead + xc.shape[1:])


@functools.partial(jax.jit, static_argnames=("plan", "bound", "reference"))
def _exec_jit(plan: NetworkPlan, payload, x: jnp.ndarray, m_valid,
              key, noise, seg, nids, bound: bool,
              reference: bool) -> jnp.ndarray:
    """The one jitted executable behind every engine entry point.

    `payload` is the per-layer parameter list (bound=False: weight binding
    runs inside this graph, the legacy per-call behaviour) or a tuple of
    bind_layer products (bound=True: weight quantization left the per-call
    path at CIMProgram.bind time).  `m_valid` (traced) marks the live batch
    extent of a bucket-padded run, or None for exact-shape dispatch.
    `seg`/`nids` (traced, (B,) int32 or None) are the per-sample segment
    ids of segment-wise activation quantization and the per-sample noise
    identity ids of identity-keyed thermal draws."""
    TRACE_COUNT["n"] += 1            # trace-time side effect: 1 per compile
    if bound:
        binds = list(payload)
    else:
        if len(payload) != len(plan.layers):
            raise ValueError(f"{len(payload)} param dicts for "
                             f"{len(plan.layers)} planned layers")
        binds = [bind_layer(lp, p, plan.cfg)
                 for lp, p in zip(plan.layers, payload)]
    return _forward(plan, binds, x, reference=reference, key=key,
                    noise=noise, m_valid=m_valid, seg=seg, nids=nids)


def _dispatch_noise(plan: NetworkPlan,
                    noise: Optional[NoiseConfig]) -> Optional[NoiseConfig]:
    """Resolve the run's noise operating point as a *traced* operand.

    None -> the planned point (or no noise at all under NO_NOISE plans);
    an explicit NoiseConfig overrides the planned numeric terms at dispatch
    time without recompiling, but must agree on `enabled` (that flag
    switches the static fuse_adc kernel path — replan to change modes)."""
    base = plan.cfg.noise
    if noise is None:
        return base if base.enabled else None
    if bool(noise.enabled) != bool(base.enabled):
        raise ValueError(
            f"noise override enabled={noise.enabled} conflicts with the "
            f"planned enabled={base.enabled}; replan with "
            "EngineConfig(noise=...) to switch modes")
    return noise if noise.enabled else None


def init_network_params(plan: NetworkPlan, key: jax.Array) -> Params:
    """Distribution-aware per-layer parameters for a planned network
    (core/cim_layers init, one {"w", "abn_log_gamma", "abn_beta"} dict per
    layer in plan order)."""
    from repro.core.cim_layers import CIMConfig, init_cim_linear
    cfg = plan.cfg
    params = []
    for lp in plan.layers:
        key, sub = jax.random.split(key)
        lcfg = CIMConfig(
            r_in=lp.spec.r_in, r_w=lp.spec.r_w, r_out=lp.spec.r_out,
            adaptive_swing=cfg.adaptive_swing,
            gamma_bits=cfg.gamma_bits, max_gamma=cfg.max_gamma,
            macro=cfg.macro)
        params.append(init_cim_linear(sub, lp.spec.k, lp.spec.n, cfg=lcfg))
    return params


def run_network(plan: NetworkPlan, params: Params, x: jnp.ndarray,
                key: Optional[jax.Array] = None,
                noise: Optional[NoiseConfig] = None, *,
                segments: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Execute the planned schedule through the Pallas kernel variants.

    .. deprecated:: this is the per-call entry point; it keeps working
       unchanged (backed by the program cache of runtime/program.py, so
       repeated calls at one plan reuse the compiled executable) but new
       code should compile once via `compile_program` and serve through
       the returned CIMProgram/BoundProgram.

    Args:
      plan: the (jit-static) NetworkPlan; with plan.cfg.sharding set the
        schedule dispatches across the device mesh via shard_map.
      params: one {"w", "abn_log_gamma", "abn_beta"} dict per layer.
      x: (..., K0) real-valued activations for a dense-first plan, or
        (..., H, W, C_in) NHWC images for a conv-first plan.
      key: PRNG key seeding the noise model (required when the plan has
        noise enabled, ignored under NO_NOISE).
      noise: optional NoiseConfig whose *numeric* terms override the
        planned operating point at dispatch time — traced scalars, so a
        sweep across operating points shares one compile.
      segments: optional (B,) int32 per-sample segment ids — activation
        quantization reduces per segment instead of batch-globally, so
        samples in different segments never share dynamic swing state
        (the serving-side per-request isolation primitive).
    Returns:
      (..., N_last) activations — or (..., out_h, out_w, C_out) if the
      last layer is a conv.
    """
    _warn_legacy_entry("run_network")
    from repro.runtime.program import program_for_plan
    return program_for_plan(plan).run(params, x, key, noise,
                                      segments=segments)


def run_network_reference(plan: NetworkPlan, params: Params, x: jnp.ndarray,
                          key: Optional[jax.Array] = None,
                          noise: Optional[NoiseConfig] = None) -> jnp.ndarray:
    """Pure-jnp digital oracle of the identical schedule (bit-exact with
    the kernel path — including under noise, where both share the same
    post-matmul ADC epilogue and pre-drawn per-tile thermal fields, and
    including sharded plans, which the oracle executes serially)."""
    from repro.runtime.program import program_for_plan
    return program_for_plan(plan).run(params, x, key, noise,
                                      reference=True)


class CIMInferenceEngine:
    """Thin compatibility wrapper over a compiled `CIMProgram`.

    Construction routes through the global program cache of
    runtime/program.py, so two engines over equal (specs, cfg) share one
    plan and one executable cache; every call dispatches the cached
    jit-compiled schedule (single-device or sharded per cfg.sharding).
    New code should hold the program directly: `engine.compile()` (or
    `compile_program(specs, cfg)`) returns it."""

    def __init__(self, specs: Sequence[mapping.LayerSpec],
                 cfg: EngineConfig = EngineConfig(),
                 activations: Optional[Sequence[str]] = None,
                 pools: Optional[Sequence[int]] = None):
        from repro.runtime.program import compile_program
        self.cfg = cfg
        self.program = compile_program(specs, cfg, activations=activations,
                                       pools=pools)

    @property
    def plan(self) -> NetworkPlan:
        """The backing program's (jit-static) NetworkPlan."""
        return self.program.plan

    def compile(self):
        """The backing CIMProgram — the plan-once/serve-many artifact
        (bind weights with .bind(params), serve ragged batches with
        .serve/.serve_batch)."""
        return self.program

    def init_params(self, key: jax.Array) -> Params:
        """Distribution-aware per-layer parameters (core/cim_layers init)."""
        return init_network_params(self.plan, key)

    def __call__(self, params: Params, x: jnp.ndarray,
                 key: Optional[jax.Array] = None,
                 noise: Optional[NoiseConfig] = None) -> jnp.ndarray:
        """Exact-shape dispatch of the compiled schedule (legacy per-call
        API; prefer engine.compile() + program.bind(params).serve(x))."""
        _warn_legacy_entry("CIMInferenceEngine.__call__")
        return self.program.run(params, x, key, noise)

    def reference(self, params: Params, x: jnp.ndarray,
                  key: Optional[jax.Array] = None,
                  noise: Optional[NoiseConfig] = None) -> jnp.ndarray:
        """The pure-jnp digital oracle of the same plan (bit-exact with
        __call__ at every precision, clean or under a common key)."""
        return self.program.run(params, x, key, noise, reference=True)

    def monte_carlo(self, params: Params, x: jnp.ndarray, key: jax.Array,
                    n_trials: int,
                    noise: Optional[NoiseConfig] = None) -> jnp.ndarray:
        """Batched seeded noise trials: (n_trials, *engine(params, x).shape).

        Splits `key` into one subkey per trial and stacks the outputs;
        every trial reuses the jit cache of the planned schedule, so the
        cost is n_trials dispatches, not n_trials compiles (`noise` points
        share the compile too — traced operands).  Deterministic for a
        fixed key; requires a noise-enabled plan."""
        if not self.cfg.noise.enabled:
            raise ValueError("monte_carlo requires EngineConfig(noise=...) "
                             "with noise enabled")
        if n_trials < 1:
            raise ValueError(f"n_trials must be >= 1, got {n_trials}")
        keys = jax.random.split(key, n_trials)
        return jnp.stack([self.program.run(params, x, k, noise)
                          for k in keys])

    def perf_report(self, **kw):
        """Per-layer + aggregate cycle/energy estimates (perfmodel);
        sharded plans add per-device macro_evals and parallel efficiency,
        and the report echoes the backing program's compile/bucket stats
        under "program"."""
        from repro.perfmodel.macro_perf import schedule_report
        return schedule_report(self.plan, program=self.program, **kw)
