"""Precision-scalable CIM inference runtime.

The paper's headline lever is workload-adaptive 8-to-1b precision scaling
(0.15-8 POPS/W); this module exposes it end-to-end: a network described as
`mapping.LayerSpec`s is *planned* into the macro's row/col tile schedule
(core/mapping.py) and *executed* through precision-specialized, jit-compiled
Pallas kernel variants (kernels/cim_mbiw/ops.kernel_variant), with the
chip's digital partial-sum recombination between row tiles.

    specs = [LayerSpec(m=256, k=1152, n=64, r_in=4, r_w=2), ...]
    engine = CIMInferenceEngine(specs)           # plans + builds dispatch
    params = engine.init_params(jax.random.PRNGKey(0))
    y = engine(params, x)                        # jit-compiled schedule
    y_ref = engine.reference(params, x)          # pure-jnp digital oracle

Convolution front-end: a `LayerSpec` built by `mapping.conv_layer_spec`
carries its NHWC `ConvGeometry`; the engine then consumes image
activations directly — the K = kh*kw*C_in row groups of the paper's
Sec. III/IV conv mapping are formed on the fly by an im2col streaming
stage (`im2col_patches` + optional `EngineConfig.stream_rows` chunking of
the patch rows through the kernel), and the GEMM output is reshaped back
to (B, out_h, out_w, C_out) for the next layer.  Max-pool epilogues
(`pools`) and the conv -> dense flatten are planned per layer, so a whole
CNN (e.g. LeNet: conv1 -> pool -> conv2 -> pool -> fc1 -> fc2) runs
through one engine:

    specs, acts, pools = models.cnn.lenet_engine_specs(batch=128)
    engine = CIMInferenceEngine(specs, activations=acts, pools=pools)
    logits = engine(params, images)              # (B, 28, 28, 1) -> (B, 10)

Numerics: under NO_NOISE the engine is bit-exact with `reference` at every
supported precision — both walk identical tile schedules and evaluate the
identical ADC floor expression; the kernel's int32 accumulator is exact for
one macro row-tile (|dp| <= 1152*255*15 < 2^24).  The activation zero-point
is folded into the per-channel ABN beta *inside* the ADC floor
(beta_eff = beta + gamma*g0*zp_dp), exactly what the chip's
signed-to-unsigned conversion + beta block does.

Per-layer precision is free: each layer's (r_in, r_w, r_out) selects its
kernel variant from a small cached table, so a mixed-precision network
compiles one kernel per distinct operating point, not per layer.

Noise-injected mode (post-silicon studies, paper Sec. III.E/V.A): with
`EngineConfig(noise=NoiseConfig(...))` the full equivalent noise model runs
through the same planned schedule — the kernel variants dispatch in raw-dp
mode (`fuse_adc=False`) and a vectorized post-kernel epilogue applies, in
code units and at the exact points the fakequant/sim paths inject them:
per-physical-column SA offsets + 7b calibration residue (static per macro,
shared across col tiles), thermal kT/C noise on the dp, DPL settling INL
and MBIW charge-injection as gain terms on g0, and leakage droop.  Runs
take a PRNG key (`engine(params, x, key)`); per-tile keys are derived by
folding (layer, stream chunk, row tile, col tile) indices, so a fixed key
is fully deterministic while tiles stay statistically independent.
`CIMInferenceEngine.monte_carlo(params, x, key, n_trials)` stacks seeded
trials for Monte-Carlo accuracy-vs-noise sweeps.  Under NO_NOISE the fused
bit-exact path is unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import abn as abn_lib
from repro.core import digital_ref, mapping
from repro.core import noise_model as nm
from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO
from repro.core.noise_model import NO_NOISE, NoiseConfig
from repro.kernels.cim_mbiw import ops as kops

Params = List[Dict[str, jnp.ndarray]]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Execution configuration shared by every layer of a schedule."""
    macro: CIMMacroConfig = DEFAULT_MACRO
    adaptive_swing: bool = True      # serial-split DPL swing adaptation
    gamma_bits: int = -1             # -1: continuous gamma; >=0: HW quant
    max_gamma: float = 32.0
    interpret: bool = True           # Pallas interpret mode (CPU) vs TPU
    bm: int = 128                    # kernel block sizes (MXU-aligned)
    bn: int = 128
    bk: int = 256
    stream_rows: int = 0             # im2col streaming: GEMM rows per kernel
                                     # dispatch (0 = single dispatch); bounds
                                     # the Pallas working set for large maps
    noise: NoiseConfig = NO_NOISE    # post-silicon equivalent noise model;
                                     # enabled -> runs require a PRNG key

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer's macro-tile schedule."""
    spec: mapping.LayerSpec
    mp: mapping.MacroMapping
    precision: kops.KernelPrecision
    g0: float                            # unity-gain codes per dp unit
    k_slices: Tuple[Tuple[int, int], ...]  # (start, size) row tiles
    n_slices: Tuple[Tuple[int, int], ...]  # (start, size) col tiles
    activation: str = "none"             # "none" | "relu"
    pool: int = 1                        # max-pool window/stride epilogue

    @property
    def macro_evals(self) -> int:
        return len(self.k_slices) * len(self.n_slices)

    @property
    def out_shape(self) -> Tuple[int, ...]:
        """Per-sample feature shape this layer emits (after pooling)."""
        g = self.spec.conv
        if g is None:
            return (self.spec.n,)
        return (g.out_h // self.pool, g.out_w // self.pool, g.c_out)


@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    layers: Tuple[LayerPlan, ...]
    cfg: EngineConfig

    @property
    def precisions(self) -> Tuple[kops.KernelPrecision, ...]:
        seen: List[kops.KernelPrecision] = []
        for lp in self.layers:
            if lp.precision not in seen:
                seen.append(lp.precision)
        return tuple(seen)

    @property
    def total_macro_evals(self) -> int:
        return sum(lp.macro_evals for lp in self.layers)


def _layer_g0(spec: mapping.LayerSpec, mp: mapping.MacroMapping,
              cfg: EngineConfig) -> float:
    macro = cfg.macro
    units = mp.units_per_tile if cfg.adaptive_swing else macro.n_units
    n_dp = units * macro.rows_per_unit
    return digital_ref.adc_gain_factor(
        spec.r_in, spec.r_w, spec.r_out, n_dp,
        macro.swing_efficiency(units), macro.alpha_adc())


def plan_layer(spec: mapping.LayerSpec, cfg: EngineConfig = EngineConfig(),
               activation: str = "none", pool: int = 1) -> LayerPlan:
    if pool < 1:
        raise ValueError(f"pool must be >= 1, got {pool}")
    if pool > 1 and spec.conv is None:
        raise ValueError("pooling epilogue requires a conv layer")
    if spec.conv is not None:
        g = spec.conv
        if spec.k != g.kh * g.kw * g.c_in or spec.n != g.c_out:
            raise ValueError(
                f"conv geometry {g} inconsistent with GEMM view "
                f"k={spec.k} n={spec.n}")
        if pool > 1 and (g.out_h < pool or g.out_w < pool):
            raise ValueError(f"pool {pool} larger than conv output "
                             f"{g.out_h}x{g.out_w}")
    mp = mapping.map_layer(spec, cfg.macro)
    prec = kops.KernelPrecision(spec.r_in, spec.r_w, spec.r_out)
    return LayerPlan(
        spec=spec, mp=mp, precision=prec, g0=_layer_g0(spec, mp, cfg),
        k_slices=tuple(mapping.split_k_slices(spec.k, mp.row_tiles)),
        n_slices=tuple(mapping.split_k_slices(spec.n, mp.col_tiles)),
        activation=activation, pool=pool)


def _check_chain(layers: Sequence[LayerPlan]) -> None:
    """Feed-forward shape check across the mixed conv/dense chain: a dense
    layer's K must equal the flattened feature count of its predecessor, a
    conv layer's (h, w, c_in) must equal the predecessor's spatial output."""
    prev: Optional[LayerPlan] = None
    for i, lp in enumerate(layers):
        g = lp.spec.conv
        if prev is not None:
            out = prev.out_shape
            if g is None:
                feed = 1
                for d in out:
                    feed *= d
                if feed != lp.spec.k:
                    raise ValueError(
                        f"layer chain mismatch: layer {i-1} emits {out} "
                        f"({feed} features) but layer {i} expects "
                        f"k={lp.spec.k}")
            else:
                if len(out) != 3:
                    raise ValueError(
                        f"layer chain mismatch: conv layer {i} needs NHWC "
                        f"input but layer {i-1} emits flat {out}")
                if out != g.spatial_in:
                    raise ValueError(
                        f"layer chain mismatch: layer {i-1} emits {out} "
                        f"but conv layer {i} expects {g.spatial_in}")
                if prev.spec.conv is not None \
                        and prev.spec.conv.batch != g.batch:
                    raise ValueError(
                        f"layer chain mismatch: conv batch "
                        f"{prev.spec.conv.batch} != {g.batch} at layer {i}")
        prev = lp


def plan_network(specs: Sequence[mapping.LayerSpec],
                 cfg: EngineConfig = EngineConfig(),
                 activations: Optional[Sequence[str]] = None,
                 pools: Optional[Sequence[int]] = None) -> NetworkPlan:
    """Plan a feed-forward network of dense and conv-tagged LayerSpecs.

    `activations`: per-layer epilogue nonlinearity; defaults to relu between
    layers and none after the last (the CNN workloads of the paper).
    `pools`: per-layer max-pool window/stride (1 = none, conv layers only),
    applied after the activation — together with the automatic conv -> dense
    flatten this covers the paper's LeNet-class CNNs.
    """
    specs = list(specs)
    if activations is None:
        activations = ["relu"] * (len(specs) - 1) + ["none"]
    if len(activations) != len(specs):
        raise ValueError("one activation per layer required")
    if pools is None:
        pools = [1] * len(specs)
    if len(pools) != len(specs):
        raise ValueError("one pool factor per layer required")
    layers = tuple(plan_layer(s, cfg, act, pool)
                   for s, act, pool in zip(specs, activations, pools))
    _check_chain(layers)
    return NetworkPlan(layers=layers, cfg=cfg)


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def im2col_patches(x: jnp.ndarray, g: mapping.ConvGeometry) -> jnp.ndarray:
    """(B, H, W, C_in) -> (B, out_h, out_w, kh*kw*C_in) patch tensor whose
    trailing axis matches the engine's (K, N) weight layout."""
    patches = jax.lax.conv_general_dilated_patches(
        x, (g.kh, g.kw), (g.stride, g.stride), padding=list(g.padding),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    b, oh, ow, kf = patches.shape
    # conv_general_dilated_patches returns channel-major (C*kh*kw) features;
    # weights are laid out (kh*kw*C) — reorder to match (cf. cim_layers).
    patches = patches.reshape(b, oh, ow, g.c_in, g.kh * g.kw)
    return jnp.swapaxes(patches, -1, -2).reshape(b, oh, ow, kf)


def _quantize_inputs(lp: LayerPlan, params: Dict[str, jnp.ndarray],
                     x2: jnp.ndarray, cfg: EngineConfig):
    """Shared prologue of the kernel and reference paths: dynamic activation
    quantization, weight quantization, ABN gamma."""
    from repro.core.quantization import quantize_act, quantize_weight
    aq = quantize_act(x2, lp.spec.r_in)
    wq = quantize_weight(params["w"], lp.spec.r_w, axis=0)
    gamma = abn_lib.abn_gamma(
        abn_lib.ABNParams(params["abn_log_gamma"], params["abn_beta"]),
        gamma_bits=cfg.gamma_bits, max_gamma=cfg.max_gamma)
    return aq, wq, gamma


@dataclasses.dataclass
class _LayerNoise:
    """Per-layer noise context of one engine run (built at trace time).

    `offset_codes`/`droop_codes` are per *global* output channel; tiles
    slice them.  `gain_mult` collects the deterministic INL terms (DPL
    settling, MBIW charge injection) as a multiplier on the code gain;
    `sigma_dp` is the thermal RMS in dp units (shared expression with the
    fakequant path, noise_model.thermal_sigma_dp).  `key` seeds the
    per-tile thermal draws."""
    offset_codes: jnp.ndarray        # (N,) static SA residue, code units
    droop_codes: jnp.ndarray         # (N,) leakage droop, code units
    gain_mult: jnp.ndarray           # scalar, multiplies gamma * g0 on dp
    sigma_dp: float                  # thermal RMS in dp units
    key: jax.Array                   # base key for per-tile thermal draws


def _layer_noise(lp: LayerPlan, cfg: EngineConfig, gamma: jnp.ndarray,
                 key: jax.Array) -> _LayerNoise:
    """Noise terms of one layer in code units, injected exactly where the
    fakequant (thermal, SA residue) and sim (settling, charge injection,
    leakage) paths put them."""
    noise, macro, spec = cfg.noise, cfg.macro, lp.spec
    units = lp.mp.units_per_tile if cfg.adaptive_swing else macro.n_units
    # static per-physical-column SA offsets after 7b calibration, shared
    # across col tiles (the macro is reused sequentially)
    res_v = nm.sample_column_residues(jax.random.fold_in(key, 0), spec.n,
                                      spec.r_w, noise, macro)
    lsb0_v = macro.alpha_adc() * macro.vddh / 2.0 ** (spec.r_out - 1)
    offset_codes = gamma * res_v / lsb0_v
    # leakage droop on V_acc, attenuated by the weight-parallel combination
    droop_v = nm.leakage_droop(spec.r_in, macro.t_dp_ns, noise) \
        * (1.0 - 2.0 ** (-spec.r_w))
    droop_codes = gamma * droop_v / lsb0_v
    settle = nm.settle_fraction(units, macro.t_dp_ns, noise)
    ci = nm.charge_injection_gain(spec.r_in, noise, macro)
    return _LayerNoise(
        offset_codes=offset_codes, droop_codes=droop_codes,
        gain_mult=settle * (1.0 + ci),
        sigma_dp=nm.thermal_sigma_dp(noise, spec.r_out, lp.g0),
        key=jax.random.fold_in(key, 1))


def _noise_adc_code(lp: LayerPlan, dp: jnp.ndarray, gamma_t: jnp.ndarray,
                    beta_eff: jnp.ndarray, nctx: _LayerNoise,
                    n_slice: Tuple[int, int], tkey: jax.Array) -> jnp.ndarray:
    """ADC conversion of one macro tile's raw dp with the noise terms
    applied pre-floor — the engine-side mirror of fakequant's
    adc_quantize(dp + thermal, gain, beta + offsets)."""
    ns, ne = n_slice
    dp = dp.astype(jnp.float32) + nctx.sigma_dp * jax.random.normal(
        tkey, dp.shape)
    mid = 2.0 ** (lp.spec.r_out - 1)
    code = jnp.floor(mid + gamma_t * lp.g0 * nctx.gain_mult * dp + beta_eff
                     + nctx.offset_codes[ns:ne] - nctx.droop_codes[ns:ne])
    return jnp.clip(code, 0.0, 2.0 ** lp.spec.r_out - 1.0).astype(jnp.int32)


def _tile_schedule(lp: LayerPlan, q_rows: jnp.ndarray, aq, wq,
                   gamma: jnp.ndarray, beta: jnp.ndarray, *,
                   matmul, nctx: Optional[_LayerNoise] = None,
                   chunk_idx: int = 0) -> jnp.ndarray:
    """One chunk of GEMM rows through the layer's (k, n) tile schedule;
    `matmul` evaluates one macro tile (kernel variant or jnp oracle) and
    returns int32 ADC codes — or raw int32 dp when a noise context is
    given, in which case the ADC conversion (with the noise terms and a
    per-tile PRNG key) runs here.  Returns dp_hat (rows, N) in dp units."""
    mid = 2.0 ** (lp.spec.r_out - 1)
    g0 = lp.g0
    dp_hat = []
    for ni, (ns, nsz) in enumerate(lp.n_slices):
        ne = ns + nsz
        acc = jnp.zeros((q_rows.shape[0], nsz), jnp.float32)
        for ki, (ks, ksz) in enumerate(lp.k_slices):
            ke = ks + ksz
            # zero-point: x = q*s + z -> z*colsum is per-channel constant,
            # folded into the ABN offset inside the ADC floor
            zp_dp = (aq.zero / aq.scale) * jnp.sum(wq.q[ks:ke, ns:ne], axis=0)
            beta_eff = beta[ns:ne] + gamma[ns:ne] * g0 * zp_dp
            out = matmul(q_rows[:, ks:ke], wq.q[ks:ke, ns:ne],
                         gamma[ns:ne], beta_eff, g0)
            if nctx is None:
                codes = out
            else:
                # independent thermal draw per (stream chunk, row, col) tile
                tkey = jax.random.fold_in(jax.random.fold_in(
                    jax.random.fold_in(nctx.key, chunk_idx), ki), ni)
                codes = _noise_adc_code(lp, out, gamma[ns:ne], beta_eff,
                                        nctx, (ns, ne), tkey)
            # digital partial-sum recombination in dp units; dequantizing
            # against the *raw* beta keeps the zero-point contribution in
            # dp_hat, exactly like the fakequant training path
            acc = acc + (codes.astype(jnp.float32) + 0.5 - mid
                         - beta[None, ns:ne]) / (gamma[None, ns:ne] * g0)
        dp_hat.append(acc)
    return jnp.concatenate(dp_hat, axis=-1)


def _layer_tiles(lp: LayerPlan, params: Dict[str, jnp.ndarray],
                 x2: jnp.ndarray, cfg: EngineConfig, *,
                 matmul, key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Run one layer's tile schedule over (M, K) GEMM rows.  With
    `cfg.stream_rows` set, rows are streamed through the kernel in chunks
    (the im2col streaming stage) — quantization stays global, and rows are
    independent through the elementwise ADC epilogue, so chunking is
    bit-invariant (and under noise, chunks draw from disjoint fold_in
    keys, so chunking changes no distribution)."""
    aq, wq, gamma = _quantize_inputs(lp, params, x2, cfg)
    beta = params["abn_beta"]
    nctx = _layer_noise(lp, cfg, gamma, key) if cfg.noise.enabled else None
    m = x2.shape[0]
    chunk = cfg.stream_rows if cfg.stream_rows > 0 else max(m, 1)
    chunks = [_tile_schedule(lp, aq.q[s:s + chunk], aq, wq, gamma, beta,
                             matmul=matmul, nctx=nctx, chunk_idx=ci)
              for ci, s in enumerate(range(0, max(m, 1), chunk))]
    dp_hat = chunks[0] if len(chunks) == 1 else jnp.concatenate(chunks, 0)
    y = dp_hat * aq.scale * wq.scale.reshape(-1)
    if lp.activation == "relu":
        y = jax.nn.relu(y)
    elif lp.activation != "none":
        raise ValueError(f"unknown activation {lp.activation!r}")
    return y


def _run_layer(lp: LayerPlan, params: Dict[str, jnp.ndarray], x: jnp.ndarray,
               cfg: EngineConfig, *, matmul,
               key: Optional[jax.Array] = None) -> jnp.ndarray:
    """One planned layer end-to-end: im2col (conv), tile schedule,
    activation, pooling, and the reshape back to the next layer's view."""
    g = lp.spec.conv
    if g is not None:
        if x.ndim != 4 or x.shape[1:] != g.spatial_in:
            raise ValueError(
                f"conv layer expects (B, {g.h}, {g.w}, {g.c_in}) "
                f"activations, got {x.shape}")
        b = x.shape[0]
        x2 = im2col_patches(x, g).reshape(b * g.out_h * g.out_w, lp.spec.k)
    else:
        x2 = x.reshape(x.shape[0], -1)        # conv -> dense flatten (NHWC)
        if x2.shape[-1] != lp.spec.k:
            raise ValueError(f"dense layer expects {lp.spec.k} features, "
                             f"got {x2.shape[-1]} from {x.shape}")
    y = _layer_tiles(lp, params, x2, cfg, matmul=matmul, key=key)
    if g is not None:
        y = y.reshape(b, g.out_h, g.out_w, g.c_out)
    if lp.pool > 1:
        y = jax.lax.reduce_window(
            y, -jnp.inf, jax.lax.max, (1, lp.pool, lp.pool, 1),
            (1, lp.pool, lp.pool, 1), "VALID")
    return y


def _kernel_matmul(lp: LayerPlan, cfg: EngineConfig):
    # under noise the kernel dispatches in raw-dp mode; the noise ADC
    # epilogue in _tile_schedule owns the conversion
    fn = kops.kernel_variant(lp.precision, bm=cfg.bm, bn=cfg.bn, bk=cfg.bk,
                             interpret=cfg.interpret,
                             fuse_adc=not cfg.noise.enabled)

    def matmul(xq, wqt, gamma_t, beta_t, g0):
        return fn(xq, wqt, gamma_t, beta_t, g0)
    return matmul


def _reference_matmul(lp: LayerPlan, cfg: EngineConfig):
    from repro.kernels.cim_mbiw.ref import cim_matmul_ref

    if cfg.noise.enabled:
        def matmul(xq, wqt, gamma_t, beta_t, g0):
            # raw integer dp: the shared noise ADC epilogue runs outside,
            # so kernel and reference stay bit-exact under a common key
            return xq.astype(jnp.int32) @ wqt.astype(jnp.int32)
        return matmul

    def matmul(xq, wqt, gamma_t, beta_t, g0):
        # the shared oracle keeps the ADC floor expression in float-op
        # lockstep with the kernel epilogue (bit-exactness contract)
        return cim_matmul_ref(xq, wqt, gamma_t, beta_t, g0=g0,
                              r_out=lp.spec.r_out)
    return matmul


def _forward(plan: NetworkPlan, params: Params, x: jnp.ndarray,
             reference: bool, key: Optional[jax.Array] = None) -> jnp.ndarray:
    if len(params) != len(plan.layers):
        raise ValueError(f"{len(params)} param dicts for "
                         f"{len(plan.layers)} planned layers")
    if plan.cfg.noise.enabled and key is None:
        raise ValueError(
            "noise-injected engine run requires a PRNG key: pass key= to "
            "run_network/CIMInferenceEngine.__call__ (or plan with "
            "noise=NO_NOISE for the deterministic deployed path)")
    g0 = plan.layers[0].spec.conv
    if g0 is not None:
        if x.ndim < 4 or x.shape[-3:] != g0.spatial_in:
            raise ValueError(
                f"input shape {x.shape} != first conv layer's "
                f"(..., {g0.h}, {g0.w}, {g0.c_in})")
        lead = x.shape[:-3]
        xc = x.reshape((-1,) + x.shape[-3:]).astype(jnp.float32)
    else:
        k0 = plan.layers[0].spec.k
        if x.shape[-1] != k0:
            raise ValueError(
                f"input width {x.shape[-1]} != first layer's k={k0}")
        lead = x.shape[:-1]
        xc = x.reshape((-1, x.shape[-1])).astype(jnp.float32)
    noisy = plan.cfg.noise.enabled
    for i, (lp, p) in enumerate(zip(plan.layers, params)):
        mk = _reference_matmul if reference else _kernel_matmul
        lkey = jax.random.fold_in(key, i) if noisy else None
        xc = _run_layer(lp, p, xc, plan.cfg, matmul=mk(lp, plan.cfg),
                        key=lkey)
    return xc.reshape(lead + xc.shape[1:])


@functools.partial(jax.jit, static_argnames=("plan",))
def run_network(plan: NetworkPlan, params: Params, x: jnp.ndarray,
                key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Execute the planned schedule through the Pallas kernel variants.

    x: (..., K0) real-valued activations for a dense-first plan, or
    (..., H, W, C_in) NHWC images for a conv-first plan; returns
    (..., N_last) — or (..., out_h, out_w, C_out) if the last layer is a
    conv.  `key` seeds the noise model when the plan has noise enabled
    (required then, ignored under NO_NOISE)."""
    return _forward(plan, params, x, reference=False, key=key)


@functools.partial(jax.jit, static_argnames=("plan",))
def run_network_reference(plan: NetworkPlan, params: Params, x: jnp.ndarray,
                          key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Pure-jnp digital oracle of the identical schedule (bit-exact with
    the kernel path — including under noise, where both share the same
    post-matmul ADC epilogue and per-tile keys)."""
    return _forward(plan, params, x, reference=True, key=key)


class CIMInferenceEngine:
    """Plans a LayerSpec network once; every call dispatches the cached
    jit-compiled schedule."""

    def __init__(self, specs: Sequence[mapping.LayerSpec],
                 cfg: EngineConfig = EngineConfig(),
                 activations: Optional[Sequence[str]] = None,
                 pools: Optional[Sequence[int]] = None):
        self.cfg = cfg
        self.plan = plan_network(specs, cfg, activations, pools)

    def init_params(self, key: jax.Array) -> Params:
        """Distribution-aware per-layer parameters (core/cim_layers init)."""
        from repro.core.cim_layers import CIMConfig, init_cim_linear
        params = []
        for lp in self.plan.layers:
            key, sub = jax.random.split(key)
            lcfg = CIMConfig(
                r_in=lp.spec.r_in, r_w=lp.spec.r_w, r_out=lp.spec.r_out,
                adaptive_swing=self.cfg.adaptive_swing,
                gamma_bits=self.cfg.gamma_bits, max_gamma=self.cfg.max_gamma,
                macro=self.cfg.macro)
            params.append(init_cim_linear(sub, lp.spec.k, lp.spec.n,
                                          cfg=lcfg))
        return params

    def __call__(self, params: Params, x: jnp.ndarray,
                 key: Optional[jax.Array] = None) -> jnp.ndarray:
        return run_network(self.plan, params, x, key)

    def reference(self, params: Params, x: jnp.ndarray,
                  key: Optional[jax.Array] = None) -> jnp.ndarray:
        return run_network_reference(self.plan, params, x, key)

    def monte_carlo(self, params: Params, x: jnp.ndarray, key: jax.Array,
                    n_trials: int) -> jnp.ndarray:
        """Batched seeded noise trials: (n_trials, *engine(params, x).shape).

        Splits `key` into one subkey per trial and stacks the outputs;
        every trial reuses the jit cache of the planned schedule, so the
        cost is n_trials dispatches, not n_trials compiles.  Deterministic
        for a fixed key; requires a noise-enabled plan."""
        if not self.cfg.noise.enabled:
            raise ValueError("monte_carlo requires EngineConfig(noise=...) "
                             "with noise enabled")
        if n_trials < 1:
            raise ValueError(f"n_trials must be >= 1, got {n_trials}")
        keys = jax.random.split(key, n_trials)
        return jnp.stack([run_network(self.plan, params, x, k)
                          for k in keys])

    def perf_report(self, **kw):
        """Per-layer + aggregate cycle/energy estimates (perfmodel)."""
        from repro.perfmodel.macro_perf import schedule_report
        return schedule_report(self.plan, **kw)
