"""Elastic mesh management: rebuild the mesh from whatever devices exist.

Checkpoints store logical arrays (checkpoint/ckpt.py), so scaling the job
up or down between restarts is: rebuild mesh -> re-device_put with the new
shardings -> continue.  `choose_mesh_shape` keeps the model axis as close
to the requested TP degree as the device count allows and gives the rest
to data (then pod) parallelism.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.jax_compat import make_mesh as _compat_make_mesh


def choose_mesh_shape(n_devices: int, tp: int = 16,
                      pods: int = 1) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    tp = math.gcd(tp, n_devices)
    rest = n_devices // tp
    if pods > 1 and rest % pods == 0:
        return (pods, rest // pods, tp), ("pod", "data", "model")
    return (rest, tp), ("data", "model")


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> jax.sharding.Mesh:
    return _compat_make_mesh(tuple(shape), tuple(axes))


def reshard_tree(tree, shardings):
    """device_put a logical pytree onto (possibly new) shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), tree, shardings)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
