"""Continuous in-flight batching for the compiled CIM serving runtime.

Static batching serves a fleet the way a bus serves commuters: everyone
waits for the batch to fill, and everyone rides until the *longest* request
finishes.  In-flight (continuous) batching admits and retires requests
*between decode steps* instead — the per-token economics the ROADMAP's
"millions of users" north star requires.  This module is that layer, built
on three invariants the PR 5/PR 6 runtime provides:

* **Bounded executables** — fused decode dispatches at the `BatchBuckets`
  ladder rung covering the highest live slot, so any admit/retire schedule
  touches the same small executable set (zero recompiles after warmup;
  engine.TRACE_COUNT/PLAN_COUNT observable).
* **Per-request numerical isolation** — every slot is its own activation-
  quantization segment (`quantize_act` segment path) and, under noise, its
  thermal draws are keyed on (request uid, call index) rather than batch
  position.  A request's token stream is therefore *bit-identical* to
  serving it alone (`decode_sequential`), whatever its batchmates,
  arrival order, slot, or the device count.
* **Gather-free slot lifecycle** — admission prefms a solo prefill and
  writes one state row; retirement just frees the slot id.  No state is
  ever compacted, shifted, or gathered, so neither event can perturb the
  requests already in flight.

The model here (`CIMDecodeLM`) is a greedy decode-only *transformer* LM
whose projections all serve through compiled CIM programs: per block, a
fused Q/K/V `SharedInputBind` (three heads of one shared normalized
input), an O `BoundProgram`, a fused gate/up `SharedInputBind`, and a
down `BoundProgram` — with digital RMS norms, rotary embedding, and
ring-buffer KV attention between them (token mixing stays digital, per
the macro mapping in docs/ARCHITECTURE.md §8).  Its per-slot state is a
pytree (KV rings + position), and the scheduler treats state generically
through `init_state`/`step_rows`, so the isolation property tests fuzz
the real serving datapath, not a toy d->d stand-in.

PR 10 adds workload-adaptive precision serving on top: a `CIMDecodeLM`
can carry `variants` — alternative block stacks serving the SAME weights
at other precision points (the `repro.precision.plan_ladder` rungs) —
and every `Request` carries an operating-point tag.  The scheduler fuses
only same-point requests per decode step (round-robin across live
points), the point joins the executable cache key, and per-request
bit-exactness vs `decode_sequential` holds at every point.  Attention
runs through the `kernels.flash_attn.ops.ring_decode_attention` Pallas
kernel, bit-exact with the digital reference.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapping
from repro.kernels.flash_attn.ops import ring_decode_attention
from repro.runtime import engine as rt
from repro.runtime.program import (DEFAULT_BUCKETS, NOISE_ID_STRIDE,
                                   BatchBuckets, BoundProgram,
                                   SharedInputBind, SharedInputProgram,
                                   compile_program)


@dataclasses.dataclass(frozen=True)
class Request:
    """One decode request: a prompt plus a generation budget.

    `uid` must be unique among in-flight requests — it seeds the request's
    noise identity (noise_id(uid, call)), so two live requests sharing a
    uid would also share thermal draws.

    `point` tags the serving operating point (a precision-ladder rung
    such as "quality"/"throughput"; "" is the model's base point): the
    scheduler decodes the request through the model's blocks for that
    point and only ever fuses it with same-point batchmates."""
    uid: int
    prompt: Tuple[int, ...]
    max_new_tokens: int
    point: str = ""

    def __post_init__(self):
        if len(self.prompt) < 1:
            raise ValueError("request needs a non-empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("request needs max_new_tokens >= 1")
        if not isinstance(self.point, str):
            raise ValueError("operating point must be a str tag, got "
                             f"{self.point!r}")


@dataclasses.dataclass
class RequestRecord:
    """Bookkeeping of one request's life in the scheduler (all step
    indices are scheduler-clock values; -1 means 'not yet')."""
    request: Request
    arrival_step: int
    slot: int = -1
    calls: int = 0                    # model calls made (prefill + decode)
    tokens: List[int] = dataclasses.field(default_factory=list)
    admitted_step: int = -1
    first_token_step: int = -1
    finished_step: int = -1

    @property
    def done(self) -> bool:
        """Whether the generation budget has been spent."""
        return len(self.tokens) >= self.request.max_new_tokens


class SlotMap:
    """Lowest-free-slot allocator for the in-flight batch.

    The dispatch extent is `extent()` — highest live slot + 1 — so keeping
    allocations low keeps the fused batch at the smallest bucket rung.
    Freeing a slot is O(1) bookkeeping and moves no data (gather-free
    retirement)."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._free = list(range(capacity))    # min-heap of free slot ids
        self._live: set = set()

    def alloc(self) -> int:
        """Claim and return the lowest free slot (raises when full)."""
        if not self._free:
            raise RuntimeError("no free slot")
        s = heapq.heappop(self._free)
        self._live.add(s)
        return s

    def free(self, slot: int) -> None:
        """Release a live slot back to the pool (no data movement)."""
        self._live.remove(slot)
        heapq.heappush(self._free, slot)

    def live(self) -> Tuple[int, ...]:
        """The live slot ids, ascending."""
        return tuple(sorted(self._live))

    def extent(self) -> int:
        """Highest live slot + 1 (the fused dispatch extent), 0 if idle."""
        return max(self._live) + 1 if self._live else 0

    @property
    def n_free(self) -> int:
        """How many slots are currently free."""
        return len(self._free)


def _rms_norm(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Non-parametric RMS norm (strictly per row — no batch statistics)."""
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def _rope(x: jnp.ndarray, pos: jnp.ndarray,
          theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding of (R, H, hd) vectors at per-row positions (R,)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[:, None, None] * freq[None, None, :]
    c, s = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:2 * half]
    rot = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return rot if 2 * half == hd else jnp.concatenate(
        [rot, x[..., 2 * half:]], axis=-1)


@dataclasses.dataclass(frozen=True)
class DecodeBlock:
    """One transformer block's bound CIM artifacts: the fused Q/K/V
    shared-input bind, the O projection, the fused gate/up bind, and the
    down projection.  Every block of a CIMDecodeLM shares the same four
    *programs* (one per distinct shape — the keyed program cache), each
    block owning only its binds — the per-expert/per-block serve-many
    pattern."""
    qkv: SharedInputBind
    o: BoundProgram
    gate_up: SharedInputBind
    down: BoundProgram


class CIMDecodeLM:
    """A greedy decode-only transformer LM over bound CIM programs.

    Per block and per decode step (one new token per row):

        h1 = rms_norm(x);  q,k,v = qkv.serve(h1)     # one fused dispatch
        attn = ring-KV causal attention(rope(q), rope(k), v)   # digital
        x   += o.serve(attn)
        h2 = rms_norm(x);  g,u = gate_up.serve(h2)   # one fused dispatch
        x   += down.serve(silu(g) * u)

    with tied logits `rms_norm(x) @ embed.T` and greedy argmax.  All four
    GEMMs per block serve through compiled CIM programs; the norms, rope,
    attention, and activation are digital (ARCHITECTURE.md §8).  Per-slot
    state is a pytree — KV rings (depth, window, H, hd) plus the absolute
    position — and everything outside the programs is strictly per-row,
    so program-level request isolation (per-row quantization segments +
    identity-keyed noise) makes fused rows bit-identical to solo rows.

    `variants` (optional) maps operating-point tags to alternative block
    stacks serving the SAME weights at other precision points (the
    precision-ladder rungs `repro.precision.plan_ladder` emits): point
    "" is always the base `blocks`.  State shape is precision-independent,
    so a request's KV rings survive whatever point it decodes at."""

    def __init__(self, embed: jnp.ndarray, blocks: Sequence[DecodeBlock],
                 *, n_heads: int, window: int = 16,
                 rope_theta: float = 10000.0,
                 variants: Optional[Dict[str, Sequence[DecodeBlock]]] = None):
        embed = jnp.asarray(embed, jnp.float32)
        if embed.ndim != 2:
            raise ValueError(f"embed must be (vocab, d), got {embed.shape}")
        d = embed.shape[1]
        if n_heads < 1 or d % n_heads:
            raise ValueError(f"d={d} not divisible into {n_heads} heads")
        if window < 1:
            raise ValueError(f"KV window must be >= 1, got {window}")
        blocks = tuple(blocks)
        if not blocks:
            raise ValueError("need at least one DecodeBlock")
        vmap: Dict[str, Tuple[DecodeBlock, ...]] = {"": blocks}
        for name, vblocks in (variants or {}).items():
            name = str(name)
            if not name:
                raise ValueError('"" names the base point; variant tags '
                                 "must be non-empty")
            vblocks = tuple(vblocks)
            if len(vblocks) != len(blocks):
                raise ValueError(
                    f"variant {name!r} has {len(vblocks)} blocks, base "
                    f"has {len(blocks)}")
            vmap[name] = vblocks
        for name, blks in vmap.items():
            for i, blk in enumerate(blks):
                if blk.qkv.shared.k != d or blk.o.plan.layers[-1].spec.n != d:
                    raise ValueError(
                        f"block {i} of point {name!r} is not d->d at d={d}")
        self.embed = embed
        self.blocks = blocks
        self.variants = vmap
        self.n_heads = n_heads
        self.window = window
        self.rope_theta = rope_theta

    @property
    def d(self) -> int:
        """Model width."""
        return self.embed.shape[1]

    @property
    def vocab(self) -> int:
        """Vocabulary size (rows of the tied embedding)."""
        return self.embed.shape[0]

    @property
    def depth(self) -> int:
        """Transformer block count."""
        return len(self.blocks)

    @property
    def bound(self) -> BoundProgram:
        """A representative bound program (all programs share one
        EngineConfig and bucket ladder — this is the one observability
        handle the scheduler and tests key their checks on)."""
        return self.blocks[0].o

    @property
    def points(self) -> Tuple[str, ...]:
        """The operating-point tags this model serves (sorted; always
        includes "" — the base point)."""
        return tuple(sorted(self.variants))

    def blocks_for(self, point: str) -> Tuple[DecodeBlock, ...]:
        """The block stack serving one operating point (ValueError on an
        unknown tag — the scheduler validates requests at submit)."""
        try:
            return self.variants[point]
        except KeyError:
            raise ValueError(
                f"unknown operating point {point!r}; this model serves "
                f"{sorted(self.variants)}") from None

    def bound_for(self, point: str) -> BoundProgram:
        """The representative bound program of one operating point (its
        perf_report carries the point's projected TOPS/W)."""
        return self.blocks_for(point)[0].o

    @classmethod
    def toy(cls, key: jax.Array, *, d: int = 96, depth: int = 2,
            vocab: int = 61, r_in: int = 4, r_w: int = 2,
            cfg: Optional[rt.EngineConfig] = None,
            buckets: BatchBuckets = DEFAULT_BUCKETS,
            n_heads: int = 4, window: int = 16,
            d_ff: int = 0,
            points: Optional[Dict[str, Sequence]] = None) -> "CIMDecodeLM":
        """A small self-contained transformer LM (compile + init + bind in
        one call) — the workhorse of the scheduler property tests and the
        serving benchmark.  `depth` counts transformer blocks; all blocks
        share the same four programs (program-cache reuse is depth-fold),
        each with its own bind.

        `points` (optional) maps operating-point tags to precision
        assignments: either one (r_in, r_w) pair applied to all four
        projections, or four pairs in (qkv, o, gate_up, down) order —
        the per-layer assignment `repro.precision.assign` emits.  Every
        point binds the SAME fp32 masters (initialized once from the
        base programs), so points differ only in serving precision."""
        cfg = cfg or rt.EngineConfig()
        if d % n_heads:
            n_heads = 1
        d_ff = d_ff or 2 * d

        def _norm(rs):
            rs = tuple(tuple(r) if isinstance(r, (tuple, list)) else r
                       for r in rs)
            if len(rs) == 2 and all(isinstance(r, int) for r in rs):
                rs = (rs,) * 4
            if len(rs) != 4:
                raise ValueError(
                    "a point is one (r_in, r_w) pair or four pairs in "
                    f"(qkv, o, gate_up, down) order, got {rs!r}")
            return tuple((int(a), int(b)) for a, b in rs)

        def _progs(rs):
            (qi, qw), (oi, ow), (gi, gw), (zi, zw) = rs
            return (
                SharedInputProgram.compile(
                    d, (("q", d), ("k", d), ("v", d)), cfg,
                    r_in=qi, r_w=qw, buckets=buckets),
                compile_program(
                    (mapping.LayerSpec(m=8, k=d, n=d, r_in=oi, r_w=ow),),
                    cfg, activations=("none",), buckets=buckets),
                SharedInputProgram.compile(
                    d, (("gate", d_ff), ("up", d_ff)), cfg,
                    r_in=gi, r_w=gw, buckets=buckets),
                compile_program(
                    (mapping.LayerSpec(m=8, k=d_ff, n=d, r_in=zi,
                                       r_w=zw),),
                    cfg, activations=("none",), buckets=buckets))

        base_progs = _progs(_norm((r_in, r_w)))
        point_progs = {str(name): _progs(_norm(rs))
                       for name, rs in (points or {}).items()}
        qkv_p, o_p, gu_p, dn_p = base_progs
        blocks: List[DecodeBlock] = []
        variants: Dict[str, List[DecodeBlock]] = {n: []
                                                  for n in point_progs}
        for b in range(depth):
            kb = jax.random.fold_in(key, 100 + b)
            # one set of fp32 masters per block, shared by every point
            # (init_params of a CIMProgram may be lazy — materialize once)
            qkv_w = qkv_p.init_params(jax.random.fold_in(kb, 0))
            o_w = list(o_p.init_params(jax.random.fold_in(kb, 1)))
            gu_w = gu_p.init_params(jax.random.fold_in(kb, 2))
            dn_w = list(dn_p.init_params(jax.random.fold_in(kb, 3)))

            def _block(progs):
                q, o, g, z = progs
                return DecodeBlock(qkv=q.bind(qkv_w), o=o.bind(o_w),
                                   gate_up=g.bind(gu_w), down=z.bind(dn_w))

            blocks.append(_block(base_progs))
            for name, progs in point_progs.items():
                variants[name].append(_block(progs))
        embed = 0.25 * jax.random.normal(jax.random.fold_in(key, 1),
                                         (vocab, d), jnp.float32)
        return cls(embed, blocks, n_heads=n_heads, window=window,
                   variants={n: tuple(v) for n, v in variants.items()}
                   or None)

    @staticmethod
    def noise_id(uid: int, call: int) -> int:
        """Deterministic noise identity of one request's `call`-th model
        call (prefill steps count) — what makes a request's thermal draws
        invariant to slot, batchmates, and dispatch extent.  Both the
        fused scheduler and decode_sequential derive ids here."""
        return (uid * NOISE_ID_STRIDE + call) % (1 << 31)

    @staticmethod
    def _proj_ids(noise_ids: Optional[jnp.ndarray],
                  proj: int) -> Optional[jnp.ndarray]:
        """Per-projection noise identities: the four GEMMs of each block
        must draw distinct thermal noise, so the row identity mixes with a
        per-projection index.  A pure function of the row's own id — the
        fused and sequential paths derive identical ids per row."""
        if noise_ids is None:
            return None
        return (noise_ids * jnp.int32(29)
                + jnp.int32(proj)) & jnp.int32(0x7FFFFFFF)

    def init_state(self, n: int) -> Dict[str, jnp.ndarray]:
        """Fresh per-slot decode state for `n` slots: KV rings of shape
        (n, depth, window, H, hd) plus each slot's absolute position
        (position 0 = first prompt token).  All recurrence lives here —
        step_rows embeds the current token fresh each call."""
        hd = self.d // self.n_heads
        shape = (n, self.depth, self.window, self.n_heads, hd)
        return {"k": jnp.zeros(shape, jnp.float32),
                "v": jnp.zeros(shape, jnp.float32),
                "pos": jnp.zeros((n,), jnp.int32)}

    def step_rows(self, state: Dict[str, jnp.ndarray], tokens: jnp.ndarray,
                  noise_ids: Optional[jnp.ndarray],
                  key: Optional[jax.Array], *, point: str = ""
                  ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
        """One fused decode step over R state rows: returns the updated
        state rows and the (R,) greedy next tokens.  Every row is its own
        quantization segment in every program dispatch, and attention only
        reads the row's own KV ring, so the rows never interact.  `point`
        selects the operating point's block stack and travels into every
        program dispatch (the executable-key point axis)."""
        blocks = self.blocks_for(point)
        rows = tokens.shape[0]
        hd = self.d // self.n_heads
        seg = jnp.arange(rows, dtype=jnp.int32)
        pos = state["pos"]                                   # (R,)
        x = self.embed[tokens]                               # (R, d)
        idx = pos % self.window                              # ring write
        # absolute position of each ring slot j given the row's pos
        # (common.attention_block's ring recovery): src = pos - ((pos-j)%L)
        j = jnp.arange(self.window, dtype=jnp.int32)
        src = pos[:, None] - ((pos[:, None] - j[None, :]) % self.window)
        bias = jnp.where(src < 0, -1e9, 0.0)                 # (R, L)
        new_k, new_v = state["k"], state["v"]
        for b, blk in enumerate(blocks):
            h1 = _rms_norm(x)
            qkv = blk.qkv.serve(
                h1, key, segments=seg,
                noise_ids=self._proj_ids(noise_ids, 4 * b), point=point)
            q = _rope(qkv["q"].reshape(rows, self.n_heads, hd), pos,
                      self.rope_theta)
            kk = _rope(qkv["k"].reshape(rows, self.n_heads, hd), pos,
                       self.rope_theta)
            vv = qkv["v"].reshape(rows, self.n_heads, hd)
            new_k = new_k.at[jnp.arange(rows), b, idx].set(kk)
            new_v = new_v.at[jnp.arange(rows), b, idx].set(vv)
            kr, vr = new_k[:rows, b], new_v[:rows, b]        # (R, L, H, hd)
            attn = ring_decode_attention(q, kr, vr, bias)
            x = x + blk.o.serve(
                attn.reshape(rows, self.d), key, segments=seg,
                noise_ids=self._proj_ids(noise_ids, 4 * b + 1),
                point=point)
            h2 = _rms_norm(x)
            gu = blk.gate_up.serve(
                h2, key, segments=seg,
                noise_ids=self._proj_ids(noise_ids, 4 * b + 2),
                point=point)
            x = x + blk.down.serve(
                jax.nn.silu(gu["gate"]) * gu["up"], key, segments=seg,
                noise_ids=self._proj_ids(noise_ids, 4 * b + 3),
                point=point)
        logits = _rms_norm(x) @ self.embed.T
        new_state = {"k": new_k, "v": new_v, "pos": pos + 1}
        return new_state, jnp.argmax(logits, axis=-1)

    def prefill(self, request: Request, key: Optional[jax.Array]
                ) -> Tuple[Dict[str, jnp.ndarray], int, int]:
        """Consume a request's prompt solo (batch-1 steps at the ladder's
        smallest rung) and return (state row pytree, first generated
        token, model calls made).  Runs identically whether the request
        later decodes fused or sequentially, so admission never enters
        the equality argument."""
        st = self.init_state(1)
        tok = None
        for j, t in enumerate(request.prompt):
            nid = None if key is None else jnp.asarray(
                [self.noise_id(request.uid, j)], jnp.int32)
            st, nxt = self.step_rows(
                st, jnp.asarray([t % self.vocab], jnp.int32), nid, key,
                point=request.point)
            tok = int(nxt[0])
        row = jax.tree_util.tree_map(lambda a: a[0], st)
        return row, tok, len(request.prompt)


def decode_sequential(model: CIMDecodeLM, request: Request,
                      key: Optional[jax.Array] = None) -> List[int]:
    """The isolation baseline: decode one request entirely alone (batch-1
    prefill + batch-1 decode steps), with the identical noise-id schedule
    the in-flight scheduler would use.  InflightScheduler must reproduce
    this token stream bit for bit for every request of every schedule —
    the property tests/test_scheduler.py fuzzes."""
    row, tok, calls = model.prefill(request, key)
    tokens = [tok]
    st = jax.tree_util.tree_map(lambda a: a[None], row)
    while len(tokens) < request.max_new_tokens:
        nid = None if key is None else jnp.asarray(
            [model.noise_id(request.uid, calls)], jnp.int32)
        st, nxt = model.step_rows(
            st, jnp.asarray([tokens[-1]], jnp.int32), nid, key,
            point=request.point)
        tokens.append(int(nxt[0]))
        calls += 1
    return tokens


class InflightScheduler:
    """The continuous-batching decode loop over a CIMDecodeLM.

    Lifecycle per `step()`: admit pending requests into free slots (solo
    prefill, one state-row write), run ONE fused decode step over the
    bucket rung covering the highest live slot, append each live slot's
    token, retire exhausted requests (slot free, no data movement).
    Dead slots below the extent ride along as padding — their rows are
    their own quantization segments, so they cannot perturb live rows.

    A single fixed PRNG key serves every step of every request: per-step
    variation comes entirely through the (uid, call) noise identities,
    which is exactly what makes fused noisy decode reproducible by
    decode_sequential under the same key.

    Mixed operating points: each request carries a point tag and a fused
    decode step only ever advances ONE point's group (round-robin over
    the live points), because the points dispatch through different
    compiled programs.  Live slots of other points ride along as padding
    (their outputs are discarded, their state rows are not written), so
    point mixing never enters the bit-exactness argument."""

    def __init__(self, model: CIMDecodeLM, capacity: int = 8,
                 key: Optional[jax.Array] = None):
        if model.bound.plan.cfg.noise.enabled and key is None:
            raise ValueError("noise-enabled model needs a PRNG key")
        self.model = model
        self.key = key
        self.slots = SlotMap(capacity)
        self.state = model.init_state(capacity)   # pytree, leading = slot
        self.cur_tok = np.zeros((capacity,), np.int64)
        self.clock = 0
        self.pending: Deque[RequestRecord] = collections.deque()
        self.by_slot: Dict[int, RequestRecord] = {}
        self.finished: Dict[int, RequestRecord] = {}
        self.extents_seen: set = set()
        self.decode_steps = 0
        self.decode_rows = 0
        self.wall_s = 0.0
        self.points_served: Dict[str, int] = {}
        self._point_rr = 0

    def submit(self, request: Request) -> RequestRecord:
        """Queue a request (arrival stamped at the current clock); it is
        admitted at the next step() with a free slot.  Raises ValueError
        when the request's operating point is not one the model serves."""
        self.model.blocks_for(request.point)
        rec = RequestRecord(request=request, arrival_step=self.clock)
        self.pending.append(rec)
        return rec

    @property
    def n_inflight(self) -> int:
        """Live (admitted, unfinished) request count."""
        return len(self.by_slot)

    @property
    def idle(self) -> bool:
        """True when nothing is pending or in flight."""
        return not self.pending and not self.by_slot

    def _retire(self, rec: RequestRecord) -> None:
        rec.finished_step = self.clock
        self.slots.free(rec.slot)
        del self.by_slot[rec.slot]
        self.finished[rec.request.uid] = rec
        # gather-free: the slot's state row stays in place until the next
        # admission overwrites it

    def _admit(self) -> None:
        while self.pending and self.slots.n_free:
            rec = self.pending.popleft()
            rec.slot = self.slots.alloc()
            rec.admitted_step = self.clock
            h, tok, calls = self.model.prefill(rec.request, self.key)
            rec.calls = calls
            rec.tokens.append(tok)
            rec.first_token_step = self.clock
            self.state = jax.tree_util.tree_map(
                lambda a, r, s=rec.slot: a.at[s].set(r), self.state, h)
            self.cur_tok[rec.slot] = tok
            self.by_slot[rec.slot] = rec
            if rec.done:              # 1-token request: in and out
                self._retire(rec)

    def step(self) -> bool:
        """One scheduler tick: admit, fused-decode ONE operating point's
        group (round-robin over live points), retire.  Returns True if a
        fused decode step ran (False on an idle tick)."""
        self._admit()
        if self.slots.extent() == 0:
            self.clock += 1
            return False
        groups: Dict[str, List[int]] = {}
        for s, rec in self.by_slot.items():
            groups.setdefault(rec.request.point, []).append(s)
        names = sorted(groups)
        pt = names[self._point_rr % len(names)]
        self._point_rr += 1
        group = sorted(groups[pt])
        extent = group[-1] + 1
        bucket = self.model.bound.program.buckets.bucket_for(extent)
        e = min(bucket, self.slots.capacity)
        in_group = set(group)
        nids = None
        if self.key is not None:
            ids = [self.model.noise_id(self.by_slot[s].request.uid,
                                       self.by_slot[s].calls)
                   if s in in_group else -1 for s in range(e)]
            nids = jnp.asarray(ids, jnp.int32)
        t0 = time.perf_counter()
        rows = jax.tree_util.tree_map(lambda a: a[:e], self.state)
        h, nxt = self.model.step_rows(
            rows, jnp.asarray(self.cur_tok[:e], jnp.int32),
            nids, self.key, point=pt)
        nxt = np.asarray(jax.device_get(nxt))
        self.wall_s += time.perf_counter() - t0
        # write back ONLY the group's rows: other points' live slots rode
        # along as padding and must keep their state untouched
        msk = np.zeros((e,), bool)
        msk[group] = True
        jmsk = jnp.asarray(msk)

        def _wb(a, r):
            sel = jmsk.reshape((e,) + (1,) * (r.ndim - 1))
            return a.at[:e].set(jnp.where(sel, r, a[:e]))

        self.state = jax.tree_util.tree_map(_wb, self.state, h)
        self.extents_seen.add(
            int(self.model.bound.program.buckets.bucket_for(e)))
        self.decode_steps += 1
        self.decode_rows += len(group)
        self.points_served[pt] = self.points_served.get(pt, 0) + 1
        self.clock += 1
        for s in group:
            rec = self.by_slot[s]
            tok = int(nxt[s])
            rec.tokens.append(tok)
            rec.calls += 1
            self.cur_tok[s] = tok
            if rec.done:
                self._retire(rec)
        return True

    def run(self, arrivals: Sequence[Tuple[int, Request]],
            max_steps: int = 100000) -> Dict[int, List[int]]:
        """Drive the loop over a timed arrival schedule: each (step,
        request) is submitted once the clock reaches `step`; runs until
        everything retires.  Returns {uid: token stream}."""
        todo = sorted(arrivals, key=lambda a: a[0])
        i = 0
        for _ in range(max_steps):
            while i < len(todo) and todo[i][0] <= self.clock:
                self.submit(todo[i][1])
                i += 1
            if i == len(todo) and self.idle:
                break
            self.step()
        else:
            raise RuntimeError(f"schedule did not drain in {max_steps} "
                               "steps")
        return {uid: list(rec.tokens)
                for uid, rec in self.finished.items()}

    def metrics(self) -> Dict[str, float]:
        """Serving metrics over the finished requests: p50/p99 end-to-end
        latency and time-to-first-token (in scheduler steps), decode
        throughput (tokens per fused step and per wall-second), the
        distinct dispatch bucket rungs seen (the executable-bound
        check), and per-operating-point token counts."""
        recs = list(self.finished.values())
        by_point: Dict[str, float] = {}
        for r in recs:
            p = r.request.point
            by_point[p] = by_point.get(p, 0.0) + len(r.tokens)
        lat = np.asarray([r.finished_step - r.arrival_step for r in recs]
                         or [0], np.float64)
        ttft = np.asarray([r.first_token_step - r.arrival_step
                           for r in recs] or [0], np.float64)
        toks = sum(len(r.tokens) for r in recs)
        return {
            "requests": float(len(recs)),
            "tokens": float(toks),
            "steps": float(self.clock),
            "decode_steps": float(self.decode_steps),
            "latency_steps_p50": float(np.percentile(lat, 50)),
            "latency_steps_p99": float(np.percentile(lat, 99)),
            "ttft_steps_p50": float(np.percentile(ttft, 50)),
            "ttft_steps_p99": float(np.percentile(ttft, 99)),
            "tokens_per_decode_step": float(
                self.decode_rows / max(self.decode_steps, 1)),
            "decode_wall_s": float(self.wall_s),
            "tokens_per_s": float(toks / self.wall_s) if self.wall_s
            else 0.0,
            "extents_seen": sorted(int(e) for e in self.extents_seen),
            "tokens_by_point": {k: float(v)
                                for k, v in sorted(by_point.items())},
        }

    def point_report(self, point: str = "") -> Dict[str, object]:
        """Perf-model projection of one operating point's schedule:
        `macro_perf.schedule_report` over the point's representative
        program, with report["operating_point"] echoing the point's
        projected TOPS/W (what `serve.py --precision-policy` and the
        Fig. 22 rows print next to measured serving throughput)."""
        return self.model.bound_for(point).program.perf_report(point=point)
