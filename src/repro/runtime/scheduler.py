"""Continuous in-flight batching for the compiled CIM serving runtime.

Static batching serves a fleet the way a bus serves commuters: everyone
waits for the batch to fill, and everyone rides until the *longest* request
finishes.  In-flight (continuous) batching admits and retires requests
*between decode steps* instead — the per-token economics the ROADMAP's
"millions of users" north star requires.  This module is that layer, built
on three invariants the PR 5/PR 6 runtime provides:

* **Bounded executables** — fused decode dispatches at the `BatchBuckets`
  ladder rung covering the highest live slot, so any admit/retire schedule
  touches the same small executable set (zero recompiles after warmup;
  engine.TRACE_COUNT/PLAN_COUNT observable).
* **Per-request numerical isolation** — every slot is its own activation-
  quantization segment (`quantize_act` segment path) and, under noise, its
  thermal draws are keyed on (request uid, call index) rather than batch
  position.  A request's token stream is therefore *bit-identical* to
  serving it alone (`decode_sequential`), whatever its batchmates,
  arrival order, slot, or the device count.
* **Gather-free slot lifecycle** — admission prefms a solo prefill and
  writes one state row; retirement just frees the slot id.  No state is
  ever compacted, shifted, or gathered, so neither event can perturb the
  requests already in flight.

The model here (`CIMDecodeLM`) is a deliberately small greedy decode-only
LM over a BoundProgram (embed -> d-to-d CIM network -> tied logits): rich
enough to exercise every runtime path the property tests and the serving
benchmark need, small enough that fuzzing hundreds of schedules stays
cheap.  The transformer serving path reuses the same slot discipline via
models/common.init_slot_kv_cache (see launch/serve.py --inflight).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapping
from repro.runtime import engine as rt
from repro.runtime.program import (DEFAULT_BUCKETS, NOISE_ID_STRIDE,
                                   BatchBuckets, BoundProgram,
                                   compile_program)


@dataclasses.dataclass(frozen=True)
class Request:
    """One decode request: a prompt plus a generation budget.

    `uid` must be unique among in-flight requests — it seeds the request's
    noise identity (noise_id(uid, call)), so two live requests sharing a
    uid would also share thermal draws."""
    uid: int
    prompt: Tuple[int, ...]
    max_new_tokens: int

    def __post_init__(self):
        if len(self.prompt) < 1:
            raise ValueError("request needs a non-empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("request needs max_new_tokens >= 1")


@dataclasses.dataclass
class RequestRecord:
    """Bookkeeping of one request's life in the scheduler (all step
    indices are scheduler-clock values; -1 means 'not yet')."""
    request: Request
    arrival_step: int
    slot: int = -1
    calls: int = 0                    # model calls made (prefill + decode)
    tokens: List[int] = dataclasses.field(default_factory=list)
    admitted_step: int = -1
    first_token_step: int = -1
    finished_step: int = -1

    @property
    def done(self) -> bool:
        """Whether the generation budget has been spent."""
        return len(self.tokens) >= self.request.max_new_tokens


class SlotMap:
    """Lowest-free-slot allocator for the in-flight batch.

    The dispatch extent is `extent()` — highest live slot + 1 — so keeping
    allocations low keeps the fused batch at the smallest bucket rung.
    Freeing a slot is O(1) bookkeeping and moves no data (gather-free
    retirement)."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._free = list(range(capacity))    # kept sorted ascending
        self._live: set = set()

    def alloc(self) -> int:
        """Claim and return the lowest free slot (raises when full)."""
        if not self._free:
            raise RuntimeError("no free slot")
        s = self._free.pop(0)
        self._live.add(s)
        return s

    def free(self, slot: int) -> None:
        """Release a live slot back to the pool (no data movement)."""
        self._live.remove(slot)
        self._free.append(slot)
        self._free.sort()

    def live(self) -> Tuple[int, ...]:
        """The live slot ids, ascending."""
        return tuple(sorted(self._live))

    def extent(self) -> int:
        """Highest live slot + 1 (the fused dispatch extent), 0 if idle."""
        return max(self._live) + 1 if self._live else 0

    @property
    def n_free(self) -> int:
        """How many slots are currently free."""
        return len(self._free)


class CIMDecodeLM:
    """A greedy decode-only LM over a bound CIM program.

    One decode step per row: x = embed[token] + h  ->  CIM network (d in,
    d out, through BoundProgram.serve with per-row segments/noise ids)
    ->  h' = y,  logits = y @ embed.T,  next = argmax.  Everything outside
    the program is strictly per-row, so program-level request isolation
    (segment quantization + identity-keyed noise) is the whole story:
    fused rows are bit-identical to solo rows."""

    def __init__(self, bound: BoundProgram, embed: jnp.ndarray):
        d_in = bound.plan.layers[0].spec.k
        d_out = bound.plan.layers[-1].spec.n
        if d_in != d_out:
            raise ValueError(
                f"decode LM needs a d->d network, got {d_in}->{d_out}")
        if embed.ndim != 2 or embed.shape[1] != d_in:
            raise ValueError(
                f"embed shape {embed.shape} incompatible with d={d_in}")
        self.bound = bound
        self.embed = jnp.asarray(embed, jnp.float32)

    @property
    def d(self) -> int:
        """Model width (the CIM network's input/output feature count)."""
        return self.embed.shape[1]

    @property
    def vocab(self) -> int:
        """Vocabulary size (rows of the tied embedding)."""
        return self.embed.shape[0]

    @classmethod
    def toy(cls, key: jax.Array, *, d: int = 96, depth: int = 2,
            vocab: int = 61, r_in: int = 4, r_w: int = 2,
            cfg: Optional[rt.EngineConfig] = None,
            buckets: BatchBuckets = DEFAULT_BUCKETS) -> "CIMDecodeLM":
        """A small self-contained LM (compile + init + bind in one call) —
        the workhorse of the scheduler property tests and the serving
        benchmark's arrival-rate sweep."""
        specs = tuple(mapping.LayerSpec(m=8, k=d, n=d, r_in=r_in, r_w=r_w)
                      for _ in range(depth))
        prog = compile_program(specs, cfg or rt.EngineConfig(),
                               buckets=buckets)
        params = prog.init_params(jax.random.fold_in(key, 0))
        embed = 0.25 * jax.random.normal(jax.random.fold_in(key, 1),
                                         (vocab, d), jnp.float32)
        return cls(prog.bind(params), embed)

    @staticmethod
    def noise_id(uid: int, call: int) -> int:
        """Deterministic noise identity of one request's `call`-th model
        call (prefill steps count) — what makes a request's thermal draws
        invariant to slot, batchmates, and dispatch extent.  Both the
        fused scheduler and decode_sequential derive ids here."""
        return (uid * NOISE_ID_STRIDE + call) % (1 << 31)

    def step_rows(self, h: jnp.ndarray, tokens: jnp.ndarray,
                  noise_ids: Optional[jnp.ndarray],
                  key: Optional[jax.Array]
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """One fused decode step over (R, d) state rows: returns the new
        state rows and the (R,) greedy next tokens.  Every row is its own
        quantization segment, so the rows never interact."""
        rows = h.shape[0]
        x = self.embed[tokens] + h
        y = self.bound.serve(
            x, key, segments=jnp.arange(rows, dtype=jnp.int32),
            noise_ids=noise_ids)
        logits = y @ self.embed.T
        return y, jnp.argmax(logits, axis=-1)

    def prefill(self, request: Request, key: Optional[jax.Array]
                ) -> Tuple[jnp.ndarray, int, int]:
        """Consume a request's prompt solo (batch-1 steps at the ladder's
        smallest rung) and return (state row (d,), first generated token,
        model calls made).  Runs identically whether the request later
        decodes fused or sequentially, so admission never enters the
        equality argument."""
        h = jnp.zeros((1, self.d), jnp.float32)
        tok = None
        for j, t in enumerate(request.prompt):
            nid = None if key is None else jnp.asarray(
                [self.noise_id(request.uid, j)], jnp.int32)
            h, nxt = self.step_rows(
                h, jnp.asarray([t % self.vocab], jnp.int32), nid, key)
            tok = int(nxt[0])
        return h[0], tok, len(request.prompt)


def decode_sequential(model: CIMDecodeLM, request: Request,
                      key: Optional[jax.Array] = None) -> List[int]:
    """The isolation baseline: decode one request entirely alone (batch-1
    prefill + batch-1 decode steps), with the identical noise-id schedule
    the in-flight scheduler would use.  InflightScheduler must reproduce
    this token stream bit for bit for every request of every schedule —
    the property tests/test_scheduler.py fuzzes."""
    h, tok, calls = model.prefill(request, key)
    tokens = [tok]
    h = h[None]
    while len(tokens) < request.max_new_tokens:
        nid = None if key is None else jnp.asarray(
            [model.noise_id(request.uid, calls)], jnp.int32)
        h, nxt = model.step_rows(
            h, jnp.asarray([tokens[-1]], jnp.int32), nid, key)
        tokens.append(int(nxt[0]))
        calls += 1
    return tokens


class InflightScheduler:
    """The continuous-batching decode loop over a CIMDecodeLM.

    Lifecycle per `step()`: admit pending requests into free slots (solo
    prefill, one state-row write), run ONE fused decode step over the
    bucket rung covering the highest live slot, append each live slot's
    token, retire exhausted requests (slot free, no data movement).
    Dead slots below the extent ride along as padding — their rows are
    their own quantization segments, so they cannot perturb live rows.

    A single fixed PRNG key serves every step of every request: per-step
    variation comes entirely through the (uid, call) noise identities,
    which is exactly what makes fused noisy decode reproducible by
    decode_sequential under the same key."""

    def __init__(self, model: CIMDecodeLM, capacity: int = 8,
                 key: Optional[jax.Array] = None):
        if model.bound.plan.cfg.noise.enabled and key is None:
            raise ValueError("noise-enabled model needs a PRNG key")
        self.model = model
        self.key = key
        self.slots = SlotMap(capacity)
        self.state = jnp.zeros((capacity, model.d), jnp.float32)
        self.cur_tok = np.zeros((capacity,), np.int64)
        self.clock = 0
        self.pending: Deque[RequestRecord] = collections.deque()
        self.by_slot: Dict[int, RequestRecord] = {}
        self.finished: Dict[int, RequestRecord] = {}
        self.extents_seen: set = set()
        self.decode_steps = 0
        self.decode_rows = 0
        self.wall_s = 0.0

    def submit(self, request: Request) -> RequestRecord:
        """Queue a request (arrival stamped at the current clock); it is
        admitted at the next step() with a free slot."""
        rec = RequestRecord(request=request, arrival_step=self.clock)
        self.pending.append(rec)
        return rec

    @property
    def n_inflight(self) -> int:
        """Live (admitted, unfinished) request count."""
        return len(self.by_slot)

    @property
    def idle(self) -> bool:
        """True when nothing is pending or in flight."""
        return not self.pending and not self.by_slot

    def _retire(self, rec: RequestRecord) -> None:
        rec.finished_step = self.clock
        self.slots.free(rec.slot)
        del self.by_slot[rec.slot]
        self.finished[rec.request.uid] = rec
        # gather-free: the slot's state row stays in place until the next
        # admission overwrites it

    def _admit(self) -> None:
        while self.pending and self.slots.n_free:
            rec = self.pending.popleft()
            rec.slot = self.slots.alloc()
            rec.admitted_step = self.clock
            h, tok, calls = self.model.prefill(rec.request, self.key)
            rec.calls = calls
            rec.tokens.append(tok)
            rec.first_token_step = self.clock
            self.state = self.state.at[rec.slot].set(h)
            self.cur_tok[rec.slot] = tok
            self.by_slot[rec.slot] = rec
            if rec.done:              # 1-token request: in and out
                self._retire(rec)

    def step(self) -> bool:
        """One scheduler tick: admit, fused-decode, retire.  Returns True
        if a fused decode step ran (False on an idle tick)."""
        self._admit()
        extent = self.slots.extent()
        if extent == 0:
            self.clock += 1
            return False
        bucket = self.model.bound.program.buckets.bucket_for(extent)
        e = min(bucket, self.slots.capacity)
        nids = None
        if self.key is not None:
            ids = [self.model.noise_id(self.by_slot[s].request.uid,
                                       self.by_slot[s].calls)
                   if s in self.by_slot else -1 for s in range(e)]
            nids = jnp.asarray(ids, jnp.int32)
        t0 = time.perf_counter()
        h, nxt = self.model.step_rows(
            self.state[:e], jnp.asarray(self.cur_tok[:e], jnp.int32),
            nids, self.key)
        nxt = np.asarray(jax.device_get(nxt))
        self.wall_s += time.perf_counter() - t0
        self.state = self.state.at[:e].set(h)
        self.extents_seen.add(
            int(self.model.bound.program.buckets.bucket_for(e)))
        self.decode_steps += 1
        self.decode_rows += len(self.by_slot)
        self.clock += 1
        for s in self.slots.live():
            rec = self.by_slot[s]
            tok = int(nxt[s])
            rec.tokens.append(tok)
            rec.calls += 1
            self.cur_tok[s] = tok
            if rec.done:
                self._retire(rec)
        return True

    def run(self, arrivals: Sequence[Tuple[int, Request]],
            max_steps: int = 100000) -> Dict[int, List[int]]:
        """Drive the loop over a timed arrival schedule: each (step,
        request) is submitted once the clock reaches `step`; runs until
        everything retires.  Returns {uid: token stream}."""
        todo = sorted(arrivals, key=lambda a: a[0])
        i = 0
        for _ in range(max_steps):
            while i < len(todo) and todo[i][0] <= self.clock:
                self.submit(todo[i][1])
                i += 1
            if i == len(todo) and self.idle:
                break
            self.step()
        else:
            raise RuntimeError(f"schedule did not drain in {max_steps} "
                               "steps")
        return {uid: list(rec.tokens)
                for uid, rec in self.finished.items()}

    def metrics(self) -> Dict[str, float]:
        """Serving metrics over the finished requests: p50/p99 end-to-end
        latency and time-to-first-token (in scheduler steps), decode
        throughput (tokens per fused step and per wall-second), and the
        distinct dispatch bucket rungs seen (the executable-bound
        check)."""
        recs = list(self.finished.values())
        lat = np.asarray([r.finished_step - r.arrival_step for r in recs]
                         or [0], np.float64)
        ttft = np.asarray([r.first_token_step - r.arrival_step
                           for r in recs] or [0], np.float64)
        toks = sum(len(r.tokens) for r in recs)
        return {
            "requests": float(len(recs)),
            "tokens": float(toks),
            "steps": float(self.clock),
            "decode_steps": float(self.decode_steps),
            "latency_steps_p50": float(np.percentile(lat, 50)),
            "latency_steps_p99": float(np.percentile(lat, 99)),
            "ttft_steps_p50": float(np.percentile(ttft, 50)),
            "ttft_steps_p99": float(np.percentile(ttft, 99)),
            "tokens_per_decode_step": float(
                self.decode_rows / max(self.decode_steps, 1)),
            "decode_wall_s": float(self.wall_s),
            "tokens_per_s": float(toks / self.wall_s) if self.wall_s
            else 0.0,
            "extents_seen": sorted(int(e) for e in self.extents_seen),
        }
