"""Fault-tolerant training driver.

What runs at 1000+-node scale and what this container can exercise:
  * checkpoint/restart  — real: the driver checkpoints every N steps through
    CheckpointManager and restarts from the newest complete checkpoint after
    any failure (process crash, preemption, injected fault in tests);
  * failure detection   — heartbeat: every step records a monotonic
    heartbeat; a watchdog (or the cluster scheduler) declares the job dead
    when the heartbeat stalls past `heartbeat_timeout_s`.  In-container we
    simulate failures by raising at a chosen step (tests/test_runtime.py);
  * straggler mitigation— per-step deadline: steps slower than
    `straggler_factor` x the rolling median are counted; after
    `max_straggler_strikes` the driver requests a restart-with-respawn
    (on a real cluster: replace the slow host; here: log + continue).
    This is the synchronous-SGD-compatible policy (no gradient staleness);
  * elastic scaling     — checkpoints are mesh-independent (logical arrays),
    so a restart may use a different device count; `elastic.py` rebuilds the
    mesh from whatever jax.devices() reports and re-shards on restore.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint import CheckpointManager


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    heartbeat_timeout_s: float = 300.0
    straggler_factor: float = 2.5
    max_straggler_strikes: int = 5
    max_restarts: int = 3


@dataclasses.dataclass
class StepStats:
    step: int
    loss: float
    duration_s: float
    straggler: bool


class TrainDriver:
    """Drives (state, batch) -> (state, metrics) step functions with
    checkpoint/restart, heartbeat and straggler accounting."""

    def __init__(self, cfg: FTConfig, step_fn: Callable,
                 batch_fn: Callable[[int], Any],
                 state_template: Any):
        self.cfg = cfg
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.manager = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.state_template = state_template
        self.heartbeat = time.monotonic()
        self.history: List[StepStats] = []
        self._durations: List[float] = []
        self.restarts = 0

    # -- state recovery ----------------------------------------------------
    def restore_or_init(self, init_state: Any) -> tuple[Any, int]:
        last = self.manager.latest_step()
        if last is None:
            return init_state, 0
        state, manifest = self.manager.restore(self.state_template)
        return state, int(manifest["step"])

    # -- main loop ----------------------------------------------------------
    def run(self, init_state: Any, num_steps: int,
            fault_injector: Optional[Callable[[int], None]] = None
            ) -> tuple[Any, List[StepStats]]:
        # host-side snapshot: step functions may donate their input buffers,
        # so the restart path must never reuse device arrays from init_state
        import numpy as _np
        import jax as _jax
        host_init = _jax.tree.map(
            lambda x: _np.asarray(_jax.device_get(x)), init_state)

        def fresh_init():
            return _jax.tree.map(_np.asarray, host_init)

        init_state = fresh_init()
        state, start = self.restore_or_init(init_state)
        step = start
        strikes = 0
        while step < num_steps:
            try:
                if fault_injector is not None:
                    fault_injector(step)
                t0 = time.monotonic()
                batch = self.batch_fn(step)
                state, metrics = self.step_fn(state, batch)
                dt = time.monotonic() - t0
                self.heartbeat = time.monotonic()

                median = (sorted(self._durations)[len(self._durations) // 2]
                          if self._durations else dt)
                is_straggler = (len(self._durations) >= 5
                                and dt > self.cfg.straggler_factor * median)
                strikes = strikes + 1 if is_straggler else 0
                self._durations.append(dt)
                if len(self._durations) > 100:
                    self._durations.pop(0)
                self.history.append(StepStats(
                    step=step, loss=float(metrics.get("loss", 0.0)),
                    duration_s=dt, straggler=is_straggler))
                if strikes >= self.cfg.max_straggler_strikes:
                    # on a real cluster: request host replacement + restart
                    strikes = 0
                step += 1
                if step % self.cfg.ckpt_every == 0:
                    self.manager.save(step, state, {"loss": self.history[-1].loss})
            except _InjectedFault:
                # crash-equivalent: lose in-memory state, restart from ckpt
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                state, step = self.restore_or_init(fresh_init())
        self.manager.save(num_steps, state, {})
        self.manager.wait()
        return state, self.history


class _InjectedFault(RuntimeError):
    """Raised by test fault injectors to emulate a node crash."""


def make_fault_injector(fail_at_steps: Dict[int, int]):
    """fail_at_steps: {step: times_to_fail}. Mutates its own copy."""
    remaining = dict(fail_at_steps)

    def inject(step: int):
        if remaining.get(step, 0) > 0:
            remaining[step] -= 1
            raise _InjectedFault(f"injected fault at step {step}")
    return inject
