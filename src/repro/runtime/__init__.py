"""Runtime subsystems: the precision-scalable CIM inference engine (single-
and multi-macro sharded dispatch), the plan-once/serve-many compiled-program
layer on top of it, the continuous in-flight batching scheduler over that
layer, plus the elastic-mesh and fault-tolerance helpers used by the
training launchers."""
from repro.runtime.engine import (CIMInferenceEngine, EngineConfig,  # noqa
                                  LayerPlan, NetworkPlan, ShardingConfig,
                                  im2col_patches, plan_layer, plan_network,
                                  run_network, run_network_reference)
from repro.runtime.program import (BatchBuckets, BoundProgram,  # noqa
                                   CIMProgram, SharedInputBind,
                                   SharedInputProgram, clear_program_cache,
                                   compile_program, program_cache_stats,
                                   program_for_plan, request_noise_ids)
from repro.runtime.scheduler import (CIMDecodeLM, DecodeBlock,  # noqa
                                     InflightScheduler, Request,
                                     RequestRecord, SlotMap,
                                     decode_sequential)
