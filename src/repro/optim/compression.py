"""Gradient compression for the cross-pod all-reduce (DESIGN.md §5).

Error-feedback int8 quantization: each leaf is quantized to int8 with a
per-leaf scale before the (simulated) all-reduce; the quantization residual
is carried in an error buffer and added back the next step, which keeps
SGD-style convergence (Karimireddy et al., 2019).

On the real mesh this halves-to-quarters the cross-pod gradient bytes —
exactly the term the multi-pod roofline shows to be ICI-bound.  The
transform is collective-agnostic: it wraps the gradient pytree before
psum/all-reduce, so it composes with pjit (XLA sees int8 all-reduce inputs).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_buffer(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress_leaf(g: jnp.ndarray, err: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """-> (int8 codes, scale, new_error)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def compress(grads, err_buf):
    """Quantize a gradient pytree; returns (codes, scales, new_err)."""
    flat, treedef = jax.tree.flatten(grads)
    errs = treedef.flatten_up_to(err_buf)
    out = [compress_leaf(g, e) for g, e in zip(flat, errs)]
    codes = treedef.unflatten([o[0] for o in out])
    scales = treedef.unflatten([o[1] for o in out])
    new_err = treedef.unflatten([o[2] for o in out])
    return codes, scales, new_err


def decompress(codes, scales):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        codes, scales)


def compressed_grads(grads, err_buf):
    """The full round-trip as used inside train_step: quantize -> (the
    all-reduce happens on the int8 codes under pjit) -> dequantize."""
    codes, scales, new_err = compress(grads, err_buf)
    return decompress(codes, scales), new_err
