"""AdamW (+ global-norm clipping) as pure pytree transforms — no optax here.

State pytree mirrors params: {"m": ..., "v": ..., "step": scalar}.
Supports a per-leaf mask (e.g. no weight decay on norms / ABN params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr_scale: jnp.ndarray | float = 1.0,
                 decay_mask: Optional[Any] = None) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                         state["v"], grads)

    if decay_mask is None:
        decay_mask = jax.tree.map(lambda p: p.ndim >= 2, params)

    def upd(p, m, v, wd):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if wd:
            u = u + cfg.weight_decay * p
        return p - lr * u

    new_params = jax.tree.map(upd, params, new_m, new_v, decay_mask)
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
