"""Sharded, atomic, elastic checkpointing.

Design (no orbax in this container — built from primitives):
  * every pytree leaf is saved as one .npy inside a step directory, with a
    JSON manifest (tree structure, dtypes, shapes, step, timestamp);
  * writes go to  <dir>/step_<n>.tmp  and are atomically renamed to
    <dir>/step_<n>  after the manifest fsync — a crash mid-save never
    corrupts the latest checkpoint (the restore scans for the newest
    *complete* directory);
  * arrays are saved in *logical* (unsharded) layout, so a restore onto a
    different mesh (elastic up-/down-scaling) just reshards on load;
  * optional async mode hands the (host-copied) arrays to a writer thread
    so the training loop is not blocked;
  * retention: keep the last `keep` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(_path_str(p) for p in path)
        out.append((name, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> str:
    """Atomic synchronous save; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "time": time.time(),
                "extra": extra or {}, "leaves": []}
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "dtype": str(arr.dtype),
             "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(directory: str, template: Any,
                    step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Restore into the structure of `template` (leaves replaced by the
    stored arrays).  Mesh-independent: caller re-device_puts with its own
    shardings afterwards (elastic restore)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    stored = {}
    for entry in manifest["leaves"]:
        stored[entry["name"]] = np.load(os.path.join(path, entry["file"]))

    names = [n for n, _ in _flatten_with_paths(template)]
    flat, treedef = jax.tree.flatten(template)
    if set(names) != set(stored.keys()):
        missing = set(names) - set(stored)
        extra = set(stored) - set(names)
        raise ValueError(f"checkpoint/template mismatch: missing={missing} "
                         f"unexpected={extra}")
    new_leaves = [stored[n] for n in names]
    return treedef.unflatten(new_leaves), manifest


class CheckpointManager:
    """Async save + retention, mirroring a production manager's surface."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            def work():
                try:
                    save_checkpoint(self.directory, step, host_tree, extra)
                    self._gc()
                except BaseException as e:   # surfaced on next wait()
                    self._error = e
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            save_checkpoint(self.directory, step, host_tree, extra)
            self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, template: Any, step: Optional[int] = None):
        self.wait()
        return load_checkpoint(self.directory, template, step)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(s for s in [latest_step(self.directory)] if s is not None)
        all_steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in all_steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
