from repro.checkpoint.ckpt import (CheckpointManager, load_checkpoint,  # noqa
                                   save_checkpoint)
