"""Serving launcher: batched prefill + decode with KV/state caches.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
      --prompt-len 32 --gen-len 16 --batch 4

`--cim-mode engine` routes every CIM linear through the plan-once/serve-many
compiled-program runtime (runtime/program.py): the first prefill + decode
step builds one persistent program set in the module-level program cache
(one program per distinct layer geometry x batch bucket), and every later
decode step is a pure cache hit — zero re-planning, zero re-tracing.  The
launcher counts plans/traces across the decode loop and reports them;
`--assert-no-recompile` turns any post-warmup growth into a failure (the
serving-smoke CI job runs exactly that).  With `--engine-devices D > 1`
each layer's macro schedule additionally shards across a D-device mesh
(ShardingConfig) — on CPU-only hosts emulate the bank of macros with
XLA_FLAGS=--xla_force_host_platform_device_count=D.

`--inflight` switches the decode loop to continuous (in-flight) batching
over a slot-mapped KV cache (models/transformer.init_slot_cache): requests
admit (solo prefill, one scatter) and retire (cursor reset, gather-free)
between fused decode steps, `--batch` is the slot capacity, and in engine
mode every slot is its own activation-quantization segment
(CIMConfig.isolate_rows) so batchmates cannot perturb each other's
numerics.  Attention-cache families (dense/moe) only.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.cim_layers import CIMConfig
from repro.launch.steps import make_serve_step
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--cim-mode", default="bypass",
                    choices=["bypass", "fakequant", "engine"])
    ap.add_argument("--engine-devices", type=int, default=0,
                    help="shard the engine-mode macro schedule across this "
                         "many devices (0 = no sharding; engine mode only)")
    ap.add_argument("--engine-axis", default="macro",
                    help="mesh axis name for the sharded engine dispatch")
    ap.add_argument("--assert-no-recompile", action="store_true",
                    help="fail if any decode step after the first re-plans "
                         "or re-traces the engine (the plan-once contract "
                         "of the compiled-program runtime)")
    ap.add_argument("--inflight", action="store_true",
                    help="continuous in-flight batching over a slot-mapped "
                         "KV cache: --batch slots, requests admit/retire "
                         "between fused decode steps (dense/moe only)")
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests for --inflight (default 2x slots)")
    ap.add_argument("--precision-policy", default="off",
                    choices=["off", "mixed", "quality", "balanced",
                             "throughput"],
                    help="workload-adaptive precision serving demo "
                         "(engine + inflight only): calibrate a per-layer "
                         "sensitivity profile, plan a precision ladder, "
                         "and serve per-request operating points through "
                         "the in-flight scheduler ('mixed' alternates "
                         "quality/throughput requests)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.precision_policy != "off":
        if args.cim_mode != "engine" or not args.inflight:
            ap.error("--precision-policy requires --cim-mode engine "
                     "--inflight")
        return _run_precision_inflight(args)

    sharding = None
    if args.engine_devices:
        if args.cim_mode != "engine":
            ap.error("--engine-devices requires --cim-mode engine")
        from repro.runtime import ShardingConfig
        sharding = ShardingConfig(devices=args.engine_devices,
                                  axis=args.engine_axis)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(cim=CIMConfig(mode=args.cim_mode, max_gamma=2.0**16,
                                    sharding=sharding,
                                    isolate_rows=args.inflight))
    key = jax.random.PRNGKey(args.seed)
    params = tf.init_params(cfg, key)
    if args.inflight:
        return _run_inflight(ap, args, cfg, params)
    max_len = args.prompt_len + args.gen_len + 8
    cache = tf.init_cache(cfg, args.batch, max_len=max_len)

    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    kwargs = {}
    if cfg.family == "audio":
        kwargs["encoder_frames"] = jax.random.normal(
            key, (args.batch, max_len, cfg.d_model))
        prompt = prompt[:, :1]
    if cfg.family == "vlm":
        kwargs["prefix_embeds"] = jax.random.normal(
            key, (args.batch, cfg.vision_tokens, cfg.d_model))

    t0 = time.time()
    logits, cache, _ = tf.forward(cfg, params, prompt, cache=cache, **kwargs)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    print(f"prefill({prompt.shape[1]} tokens): {time.time()-t0:.2f}s")

    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    out = [tok]

    # warmup decode step: compiles the serve_step graph (and, in engine
    # mode, fills the persistent program set the remaining steps reuse)
    from repro.runtime import engine as rt_engine
    t_warm = 0.0
    if args.gen_len > 0:
        t0 = time.time()
        tok, cache = serve_step(params, cache, tok)
        tok.block_until_ready()
        out.append(tok)
        t_warm = time.time() - t0
    plans0, traces0 = rt_engine.PLAN_COUNT["n"], rt_engine.TRACE_COUNT["n"]

    steps = max(args.gen_len - 1, 0)
    t0 = time.time()
    for _ in range(steps):
        tok, cache = serve_step(params, cache, tok)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    gen.block_until_ready()
    dt = time.time() - t0
    d_plans = rt_engine.PLAN_COUNT["n"] - plans0
    d_traces = rt_engine.TRACE_COUNT["n"] - traces0
    if steps:
        print(f"decode {steps} steps: {dt:.2f}s "
              f"({steps * args.batch / dt:.1f} tok/s, "
              f"{dt / steps * 1e3:.1f} ms/step; warmup {t_warm:.2f}s)")
    print(f"decode recompiles after warmup: plans={d_plans} "
          f"traces={d_traces}")
    if args.cim_mode == "engine":
        from repro.runtime import program_cache_stats
        print(f"engine program cache: {program_cache_stats()}")
    if args.assert_no_recompile and (d_plans or d_traces):
        raise SystemExit(
            f"FAIL: decode loop re-entered the planner/compiler after "
            f"warmup (plans +{d_plans}, traces +{d_traces}) — the "
            f"plan-once/serve-many contract is broken")
    print("sample:", gen[0].tolist())


def _run_inflight(ap, args, cfg, params):
    """Continuous-batching decode loop: solo prefill into a slot-mapped
    cache, fused single-token decode over all slots, retire on budget —
    reporting per-request latency percentiles, throughput, and the
    post-warmup recompile counters (`--assert-no-recompile` gates them)."""
    if cfg.family not in ("dense", "moe"):
        ap.error(f"--inflight supports dense/moe families, not "
                 f"{cfg.family!r}")
    from repro.runtime import engine as rt_engine
    from repro.runtime.scheduler import SlotMap

    slots = args.batch
    max_len = args.prompt_len + args.gen_len + 8
    cache = tf.init_slot_cache(cfg, slots, max_len)
    rng = np.random.default_rng(args.seed)
    n_req = args.requests or 2 * slots
    # fixed-length prompts keep the prefill executable set at one trace;
    # generation budgets and arrivals are ragged (the in-flight dynamics)
    reqs = [{"uid": u,
             "prompt": rng.integers(0, cfg.vocab_size,
                                    size=args.prompt_len),
             "gen": int(rng.integers(1, args.gen_len + 1)),
             "arrival": int(rng.integers(0, args.gen_len))}
            for u in range(n_req)]
    reqs.sort(key=lambda r: r["arrival"])

    def prefill(prompt):
        c1 = tf.init_cache(cfg, 1, max_len=max_len)
        logits, c1, _ = tf.forward(cfg, params, prompt[None], cache=c1)
        return c1, jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    @jax.jit
    def step(params, cache, tok):
        # explicit (B, 1) positions: every slot decodes at its own offset
        pos = cache["pos"][:, None]
        logits, cache, _ = tf.forward(cfg, params, tok[:, None],
                                      positions=pos, cache=cache)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    smap = SlotMap(slots)
    live, done, queue = {}, [], list(reqs)
    cur = jnp.zeros((slots,), jnp.int32)
    clock, decode_steps, snap, t_decode = 0, 0, None, 0.0
    t_start = time.time()
    while queue or live:
        while queue and smap.n_free and queue[0]["arrival"] <= clock:
            r = queue.pop(0)
            s = smap.alloc()
            c1, tok = prefill(jnp.asarray(r["prompt"], jnp.int32))
            cache = tf.write_slot_cache(cache, s, c1)
            cur = cur.at[s].set(tok[0])
            r.update(slot=s, admitted=clock, tokens=[int(tok[0])])
            if len(r["tokens"]) >= r["gen"]:
                smap.free(s)
                cache = tf.free_slot_cache(cache, s)
                r["finished"] = clock
                done.append(r)
            else:
                live[s] = r
        if live:
            t0 = time.time()
            nxt, cache = step(params, cache, cur)
            nxt = jax.device_get(nxt)
            t_decode += time.time() - t0
            decode_steps += 1
            if snap is None:        # post-warmup recompile baseline
                snap = (rt_engine.PLAN_COUNT["n"],
                        rt_engine.TRACE_COUNT["n"])
            for s in sorted(live):
                r = live[s]
                r["tokens"].append(int(nxt[s]))
                cur = cur.at[s].set(int(nxt[s]))
                if len(r["tokens"]) >= r["gen"]:
                    smap.free(s)
                    cache = tf.free_slot_cache(cache, s)
                    r["finished"] = clock
                    del live[s]
                    done.append(r)
        clock += 1

    lat = np.asarray([r["finished"] - r["arrival"] for r in done], float)
    toks = sum(len(r["tokens"]) for r in done)
    wall = time.time() - t_start
    print(f"inflight: {len(done)} requests, {toks} tokens, "
          f"{decode_steps} fused steps over {slots} slots in {wall:.2f}s")
    print(f"latency steps p50/p99: {np.percentile(lat, 50):.1f}/"
          f"{np.percentile(lat, 99):.1f}; "
          f"decode {toks / t_decode:.1f} tok/s" if t_decode else "")
    d_plans = rt_engine.PLAN_COUNT["n"] - (snap or (0, 0))[0]
    d_traces = rt_engine.TRACE_COUNT["n"] - (snap or (0, 0))[1]
    if snap is not None:
        print(f"decode recompiles after warmup: plans={d_plans} "
              f"traces={d_traces}")
        if args.assert_no_recompile and (d_plans or d_traces):
            raise SystemExit(
                f"FAIL: in-flight loop re-entered the planner/compiler "
                f"after warmup (plans +{d_plans}, traces +{d_traces})")
    print("sample:", done[0]["tokens"])


def _run_precision_inflight(args):
    """Workload-adaptive precision serving demo: calibrate, plan the
    ladder, serve mixed per-request operating points in flight.

    Pipeline (the PR 10 tentpole end to end): (1) `precision.calibrate`
    profiles the toy decode-LM's four projection GEMMs; (2)
    `precision.assign` turns quality budgets into per-layer (r_in, r_w)
    assignments; (3) `CIMDecodeLM.toy(points=...)` compiles + binds one
    block stack per operating point over the SAME weights; (4) the
    in-flight scheduler fuses same-point requests per decode step.  The
    demo then proves the serving contracts: zero post-warmup recompiles
    (under --assert-no-recompile), every fused request bit-identical to
    its solo decode at the same point, and the per-point projected
    TOPS/W echoed next to measured token counts."""
    from repro.precision import DEFAULT_BUDGETS, assign, calibrate
    from repro.core import mapping
    from repro.runtime import engine as rt_engine
    from repro.runtime.engine import EngineConfig
    from repro.runtime.program import program_cache_stats
    from repro.runtime.scheduler import (CIMDecodeLM, InflightScheduler,
                                         Request, decode_sequential)

    d, depth, vocab, d_ff = 48, 2, 61, 96
    base = (8, 4)
    specs = (mapping.LayerSpec(m=8, k=d, n=3 * d, r_in=base[0],
                               r_w=base[1]),
             mapping.LayerSpec(m=8, k=d, n=d, r_in=base[0], r_w=base[1]),
             mapping.LayerSpec(m=8, k=d, n=2 * d_ff, r_in=base[0],
                               r_w=base[1]),
             mapping.LayerSpec(m=8, k=d_ff, n=d, r_in=base[0],
                               r_w=base[1]))
    t0 = time.time()
    prof = calibrate(specs, EngineConfig(), n_trials=2, batch=4,
                     seed=args.seed, label="serve-demo")
    names = (["quality", "throughput"] if args.precision_policy == "mixed"
             else [args.precision_policy])
    points = {}
    for name in names:
        asg, delta = assign(prof, specs, DEFAULT_BUDGETS[name])
        points[name] = asg
        print(f"precision: point {name!r} -> "
              f"{[(ri, rw) for ri, rw in asg]} "
              f"(predicted quality delta {delta:.4f})")
    print(f"precision: profile + plan in {time.time() - t0:.1f}s")

    key = jax.random.PRNGKey(args.seed)
    model = CIMDecodeLM.toy(key, d=d, depth=depth, vocab=vocab,
                            r_in=base[0], r_w=base[1], points=points)
    rng = np.random.default_rng(args.seed)
    n_req = args.requests or 2 * args.batch
    gen_hi = max(args.gen_len, 2)
    reqs = [Request(uid=u,
                    prompt=tuple(int(t) for t in rng.integers(
                        0, vocab, size=max(args.prompt_len, 1))),
                    max_new_tokens=int(rng.integers(1, gen_hi + 1)),
                    point=names[u % len(names)])
            for u in range(n_req)]

    # warmup: dispatch one decode per operating point at every bucket
    # extent the scheduler can reach — the executable set the measured
    # run must then serve entirely from cache
    buckets = model.bound.program.buckets
    ext_set = sorted({min(buckets.bucket_for(x), args.batch)
                      for x in range(1, args.batch + 1)})
    st_full = model.init_state(args.batch)
    for nm in names:
        for e_w in ext_set:
            rows = jax.tree_util.tree_map(lambda a: a[:e_w], st_full)
            model.step_rows(rows, jnp.zeros((e_w,), jnp.int32), None,
                            None, point=nm)
    plans0 = rt_engine.PLAN_COUNT["n"]
    traces0 = rt_engine.TRACE_COUNT["n"]

    sched = InflightScheduler(model, capacity=args.batch)
    out = sched.run([(int(rng.integers(0, gen_hi)), r) for r in reqs])
    m = sched.metrics()
    d_plans = rt_engine.PLAN_COUNT["n"] - plans0
    d_traces = rt_engine.TRACE_COUNT["n"] - traces0

    bad = [r.uid for r in reqs if out[r.uid] != decode_sequential(model, r)]
    print(f"inflight: {int(m['requests'])} requests, "
          f"{int(m['tokens'])} tokens, {int(m['decode_steps'])} fused "
          f"steps over {args.batch} slots "
          f"({m['tokens_per_s']:.1f} tok/s decode)")
    for name in names:
        op = sched.point_report(name)["operating_point"]
        toks = m["tokens_by_point"].get(name, 0.0)
        print(f"point {name!r}: {int(toks)} tokens served, projected "
              f"{op['tops_per_w']:.2f} TOPS/W")
    print(f"decode recompiles after warmup: plans={d_plans} "
          f"traces={d_traces}")
    print(f"engine program cache: {program_cache_stats()}")
    print("per-request bit-exactness vs solo decode: "
          + ("PASS" if not bad else f"FAIL {bad}"))
    if bad:
        raise SystemExit("FAIL: fused decode diverged from solo decode "
                         f"for uids {bad}")
    if args.assert_no_recompile and (d_plans or d_traces):
        raise SystemExit(
            f"FAIL: precision serving re-entered the planner/compiler "
            f"after warmup (plans +{d_plans}, traces +{d_traces})")


if __name__ == "__main__":
    main()
