"""Training launcher.

CPU/host-scale entry point used by the examples and integration tests; on a
real cluster the same code runs under the production mesh (the dry-run
proves the sharding).  Supports CIM execution modes, checkpoint/restart via
the fault-tolerant driver, and gradient compression.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 20 --cim-mode fakequant
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.cim_layers import CIMConfig
from repro.core.noise_model import NoiseConfig
from repro.data.lm_data import LMDataConfig, SyntheticLM
from repro.launch.steps import init_train_state, make_train_step
from repro.optim import AdamWConfig
from repro.runtime.fault_tolerance import FTConfig, TrainDriver


def build(args):
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    noise = NoiseConfig() if args.cim_noise else NoiseConfig(enabled=False)
    cfg = cfg.replace(cim=CIMConfig(mode=args.cim_mode, noise=noise,
                                    max_gamma=2.0**16))
    data = SyntheticLM(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch))

    def batch_fn(step: int):
        toks, labels = data.batch_at(step)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    step_fn = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=args.lr), total_steps=args.steps,
        warmup=min(20, args.steps // 10 + 1),
        compress_grads=args.compress_grads), donate_argnums=(0,))
    state = init_train_state(cfg, jax.random.PRNGKey(args.seed),
                             compress_grads=args.compress_grads)
    return cfg, state, step_fn, batch_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced per-arch config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cim-mode", default="bypass",
                    choices=["bypass", "fakequant"])
    ap.add_argument("--cim-noise", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg, state, step_fn, batch_fn = build(args)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M cim={cfg.cim.mode}")

    if args.ckpt_dir:
        driver = TrainDriver(
            FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
            step_fn, batch_fn, state_template=state)
        state, history = driver.run(state, args.steps)
        print(f"final loss={history[-1].loss:.4f} "
              f"(restarts={driver.restarts})")
    else:
        t0 = time.time()
        for step in range(args.steps):
            state, metrics = step_fn(state, batch_fn(step))
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
