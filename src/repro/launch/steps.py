"""train_step / serve_step builders shared by the trainer, the server and
the dry-run."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.models.sharding import BATCH, TP, shard
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim import compression as gc
from repro.optim.schedules import cosine_schedule

AUX_LOSS_WEIGHT = 0.01


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token CE; logits (B, S, V) bf16-safe."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def loss_fn(cfg: ModelConfig, params, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
    kwargs = {}
    if "prefix_embeds" in batch:
        kwargs["prefix_embeds"] = batch["prefix_embeds"]
    if "encoder_frames" in batch:
        kwargs["encoder_frames"] = batch["encoder_frames"]
    logits, _, aux = tf.forward(cfg, params, batch["tokens"], **kwargs)
    if cfg.family == "vlm" and "prefix_embeds" in batch:
        logits = logits[:, batch["prefix_embeds"].shape[1]:]
    ce = cross_entropy(logits, batch["labels"])
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    total_steps: int = 10000, warmup: int = 100,
                    compress_grads: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", "err"?}; donate-able."""

    def train_step(state, batch):
        params = state["params"]
        (loss, parts), grads = jax.value_and_grad(
            functools.partial(loss_fn, cfg), has_aux=True)(params, batch)
        if compress_grads:
            # error-feedback int8 gradient compression before the
            # (XLA-inserted) cross-replica reduction (DESIGN.md §5)
            grads, new_err = gc.compressed_grads(grads, state["err"])
        lr_scale = cosine_schedule(state["opt"]["step"], warmup, total_steps)
        new_params, new_opt, om = adamw_update(
            params, grads, state["opt"], opt_cfg, lr_scale)
        new_state = {"params": new_params, "opt": new_opt}
        if compress_grads:
            new_state["err"] = new_err
        metrics = {"loss": loss, **parts, **om}
        return new_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, key: jax.Array,
                     compress_grads: bool = False) -> Dict:
    params = tf.init_params(cfg, key)
    state = {"params": params, "opt": adamw_init(params)}
    if compress_grads:
        state["err"] = gc.init_error_buffer(params)
    return state


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        kwargs = {k: batch[k] for k in ("prefix_embeds", "encoder_frames")
                  if k in batch}
        logits, _, _ = tf.forward(cfg, params, batch["tokens"], **kwargs)
        return logits[:, -1, :]
    return prefill_step


def make_serve_step(cfg: ModelConfig, *, greedy: bool = True):
    """One decode step: (params, cache, tokens (B,1)) -> (next, cache)."""

    def serve_step(params, cache, tokens):
        logits, new_cache, _ = tf.forward(cfg, params, tokens, cache=cache)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], new_cache

    return serve_step
