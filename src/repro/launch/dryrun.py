import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
record memory/cost/collective analysis (EXPERIMENTS.md §Dry-run, §Roofline).

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
Results land in experiments/dryrun/<arch>_<shape>_<mesh>[_cim].json.
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import all_archs, get_config
from repro.configs.base import SHAPES, shape_applicable
from repro.core.cim_layers import CIMConfig
from repro.launch import hlo_analysis, specs
from repro.jax_compat import set_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (init_train_state, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models import transformer as tf
from repro.optim import AdamWConfig

ALIAS = {a: a for a in all_archs()}
ALIAS.update({
    "phi3.5-moe-42b-a6.6b": "phi35_moe", "mixtral-8x22b": "mixtral_8x22b",
    "minitron-4b": "minitron_4b", "qwen2-7b": "qwen2_7b",
    "olmo-1b": "olmo_1b", "granite-8b": "granite_8b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "internvl2-76b": "internvl2_76b", "mamba2-1.3b": "mamba2_1_3b",
    "whisper-medium": "whisper_medium",
})

PRETTY = {v: k for k, v in ALIAS.items() if k != v}


def _mem_dict(compiled) -> Dict[str, Any]:
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    out = {}
    for k in keys:
        if hasattr(m, k):
            out[k] = int(getattr(m, k))
    return out


def _cost_dict(compiled) -> Dict[str, float]:
    try:
        c = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return {k: float(v) for k, v in dict(c).items()
            if isinstance(v, (int, float))}


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             cim_mode: str = "bypass", out_dir: str = "experiments/dryrun",
             attn_impl: str = "jnp", tag: str = "",
             remat_policy: str = "full",
             compress_grads: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    cfg = cfg.replace(cim=CIMConfig(mode=cim_mode, max_gamma=2.0**16),
                      attn_impl=attn_impl, remat_policy=remat_policy)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg.name, shape_name, cfg.family)
    result: Dict[str, Any] = {
        "arch": cfg.name, "shape": shape_name, "mesh": mesh_kind,
        "cim_mode": cim_mode, "kind": shape.kind, "attn_impl": attn_impl,
        "tag": tag,
    }
    if not ok:
        result["status"] = "skipped"
        result["reason"] = why
        _dump(result, out_dir)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        with set_mesh(mesh):
            inputs = specs.input_specs(cfg, shape)
            in_specs = specs.batch_specs(inputs, mesh)
            in_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), in_specs)

            if shape.kind == "train":
                state = jax.eval_shape(
                    lambda: init_train_state(cfg, jax.random.PRNGKey(0),
                                             compress_grads=compress_grads))
                pspec = specs.param_specs(state["params"], mesh)
                sspec = {"params": pspec,
                         "opt": {"m": pspec, "v": pspec, "step": P()}}
                if compress_grads:
                    sspec["err"] = pspec
                sshard = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec)
                step = make_train_step(cfg, AdamWConfig(),
                                       compress_grads=compress_grads)
                jitted = jax.jit(step, in_shardings=(sshard, in_shard),
                                 out_shardings=(sshard, None),
                                 donate_argnums=(0,))
                lowered = jitted.lower(state, inputs)
            elif shape.kind == "prefill":
                params = jax.eval_shape(
                    lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
                pspec = specs.param_specs(params, mesh)
                pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
                step = make_prefill_step(cfg)
                jitted = jax.jit(step, in_shardings=(pshard, in_shard))
                lowered = jitted.lower(params, inputs)
            else:  # decode
                def _mk_params():
                    p = tf.init_params(cfg, jax.random.PRNGKey(0))
                    if cim_mode == "deploy":
                        from repro.core.cim_layers import \
                            quantize_params_for_serving
                        p = quantize_params_for_serving(p, cfg.cim.r_w)
                    return p
                params = jax.eval_shape(_mk_params)
                pspec = specs.param_specs(params, mesh)
                pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
                cache = inputs["cache"]
                cshard = in_shard["cache"]
                tshard = in_shard["tokens"]
                step = make_serve_step(cfg)
                jitted = jax.jit(step, in_shardings=(pshard, cshard, tshard),
                                 out_shardings=(None, cshard),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params, cache, inputs["tokens"])

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            result["status"] = "ok"
            result["lower_s"] = round(t_lower, 1)
            result["compile_s"] = round(t_compile, 1)
            result["memory"] = _mem_dict(compiled)
            result["cost"] = _cost_dict(compiled)
            try:
                hlo = compiled.as_text()
                result.update(hlo_analysis.analyze(hlo))
            except Exception as e:   # pragma: no cover
                result["collectives_error"] = str(e)
    except Exception as e:
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    _dump(result, out_dir)
    return result


def _dump(result: Dict[str, Any], out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    tag = "" if result.get("cim_mode", "bypass") == "bypass" else \
        f"_{result['cim_mode']}"
    if result.get("attn_impl", "jnp") != "jnp":
        tag += f"_{result['attn_impl']}"
    if result.get("tag"):
        tag += f"_{result['tag']}"
    name = (f"{ALIAS.get(result['arch'], result['arch'])}"
            f"_{result['shape']}_{result['mesh']}{tag}.json")
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(result, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--cim-mode", default="bypass",
                    choices=["bypass", "fakequant", "deploy"])
    ap.add_argument("--attn-impl", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--remat-policy", default="full", choices=["full", "dots"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = all_archs() if args.arch is None else [ALIAS.get(args.arch,
                                                             args.arch)]
    shapes = list(SHAPES) if args.shape is None else [args.shape]

    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                t0 = time.time()
                r = run_cell(arch, shape, mesh_kind, cim_mode=args.cim_mode,
                             attn_impl=args.attn_impl, tag=args.tag,
                             remat_policy=args.remat_policy,
                             compress_grads=args.compress_grads,
                             out_dir=args.out)
                status = r["status"]
                extra = ""
                if status == "ok":
                    flops = r.get("hlo_flops", r.get("cost", {}).get("flops", 0))
                    extra = (f" flops/dev={flops:.3e}"
                             f" coll={r.get('collective_bytes', 0):.3e}B"
                             f" compile={r.get('compile_s')}s")
                elif status == "error":
                    extra = " " + r.get("error", "")[:160]
                print(f"[{mesh_kind:6s}] {arch:20s} {shape:12s} {status}{extra}",
                      flush=True)


if __name__ == "__main__":
    main()
