"""Roofline terms from compiled HLO text.

XLA's cost_analysis() counts while-loop bodies ONCE, so for scan-over-layers
models it underestimates FLOPs/bytes by ~the layer count.  This module walks
the scheduled HLO itself:

  * computations are parsed into (op name -> shape / opcode / operands);
  * the call graph (while body/condition, to_apply, calls) is traversed and
    each computation gets an execution multiplier = product of enclosing
    while-loop trip counts (trip count = the comparison constant inside the
    loop condition — the standard lax.scan lowering);
  * FLOPs  : sum over dot ops of 2 * prod(result dims) * prod(contracted
    lhs dims), weighted;
  * bytes  : scheduled HLO materializes every top-level op's result, so HBM
    traffic ~= sum of (result + operand buffer bytes) over compute ops
    (view-like ops excluded), weighted;
  * collective bytes: result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (incl. -start forms),
    weighted.

All numbers are per device (the SPMD module is per-device).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# view-like / free ops excluded from the bytes estimate
_FREE_OPS = {"get-tuple-element", "tuple", "parameter", "constant", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}

# elementwise ops: the TPU backend fuses these into their consumers (loop
# fusion), so they do not materialize HBM buffers.  The CPU backend we
# compile on is less aggressive — leaving them in would overstate the
# memory term by the backend difference, not by anything intrinsic to the
# program (documented in EXPERIMENTS.md §Roofline).
_ELEMENTWISE = {"convert", "multiply", "add", "subtract", "divide", "select",
                "compare", "and", "or", "not", "xor", "exponential", "log",
                "rsqrt", "sqrt", "tanh", "logistic", "maximum", "minimum",
                "abs", "negate", "sign", "floor", "ceil", "round",
                "broadcast", "power", "remainder", "clamp",
                "exponential-minus-one", "log-plus-one"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
# first ` opcode(` after the result type; types are always `dtype[...]`,
# never `word(`, so the first such match is the opcode
_OPCODE_RE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALL_RE = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    n_total, b_total = 0, 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_total += n
        b_total += n * _DTYPE_BYTES[dtype]
    return n_total, b_total


@dataclasses.dataclass
class Op:
    name: str
    result: str          # result type text (may be a tuple)
    opcode: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symbols: Dict[str, str]   # op name -> result type text


class HLOModule:
    def __init__(self, text: str):
        self.computations: Dict[str, Computation] = {}
        self._parse(text)
        self.multipliers = self._compute_multipliers()

    # -- parsing -----------------------------------------------------------
    def _parse(self, text: str):
        cur: Optional[Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" "):
                # computation header: `%name (args) -> type {` or `ENTRY ...`
                if "->" in line and "{" in line:
                    m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
                    if m:
                        cur = Computation(m.group(1), [], {})
                        self.computations[cur.name] = cur
                continue
            if cur is None:
                continue
            m = _NAME_RE.match(line)
            if not m:
                continue
            name = m.group(1)
            rest = line[m.end():]
            mo = _OPCODE_RE.search(" " + rest)
            if not mo:
                continue
            opcode = mo.group(1)
            op_pos = mo.start(1) - 1        # account for the " " prefix
            result = rest[:op_pos].strip()
            # operands: everything inside the first (...) after the opcode
            start = op_pos + len(opcode) + 1
            depth, end = 1, start
            while end < len(rest) and depth:
                if rest[end] == "(":
                    depth += 1
                elif rest[end] == ")":
                    depth -= 1
                end += 1
            operand_text = rest[start:end - 1]
            operands = _OPERAND_RE.findall(operand_text)
            op = Op(name, result, opcode, operands, line)
            cur.ops.append(op)
            cur.symbols[name] = result

    # -- call graph / multipliers -------------------------------------------
    def _trip_count(self, cond_name: str) -> int:
        comp = self.computations.get(cond_name)
        best = 1
        if comp is None:
            return best
        names = [cond_name] + [c for op in comp.ops
                               for c in _CALL_RE.findall(op.line)]
        for n in names:
            c = self.computations.get(n)
            if not c:
                continue
            for op in c.ops:
                for v in _CONST_RE.findall(op.line):
                    best = max(best, int(v))
        return best

    def _compute_multipliers(self) -> Dict[str, float]:
        referenced = set()
        for comp in self.computations.values():
            for op in comp.ops:
                referenced.update(_CALL_RE.findall(op.line))
        entries = [n for n in self.computations if n not in referenced]
        mult: Dict[str, float] = defaultdict(lambda: 0.0)
        stack = [(n, 1.0) for n in entries]
        visited = set()
        while stack:
            name, m = stack.pop()
            if mult[name] >= m and name in visited:
                continue
            visited.add(name)
            mult[name] = max(mult[name], m)
            comp = self.computations.get(name)
            if comp is None:
                continue
            for op in comp.ops:
                callees = _CALL_RE.findall(op.line)
                if not callees:
                    continue
                if op.opcode == "while":
                    cond = body = None
                    mc = re.search(r"condition=%?([\w.\-]+)", op.line)
                    mb = re.search(r"body=%?([\w.\-]+)", op.line)
                    cond = mc.group(1) if mc else None
                    body = mb.group(1) if mb else None
                    # prefer XLA's own annotation when present
                    mt = re.search(r'known_trip_count..:..n.:.(\d+)', op.line)
                    if mt:
                        trips = int(mt.group(1))
                    else:
                        trips = self._trip_count(cond) if cond else 1
                    if cond:
                        stack.append((cond, m * (trips + 1)))
                    if body:
                        stack.append((body, m * trips))
                else:
                    for c in callees:
                        stack.append((c, m))
        return dict(mult)

    # -- metrics -------------------------------------------------------------
    def flops(self) -> float:
        total = 0.0
        for comp in self.computations.values():
            m = self.multipliers.get(comp.name, 1.0)
            for op in comp.ops:
                if op.opcode != "dot":
                    continue
                r_elems, _ = _shape_elems_bytes(op.result)
                k = self._contracted_size(comp, op)
                total += 2.0 * r_elems * k * m
        return total

    def _contracted_size(self, comp: Computation, op: Op) -> int:
        mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        if not mdims or not op.operands:
            return 1
        dims = [int(d) for d in mdims.group(1).split(",") if d]
        lhs_type = comp.symbols.get(op.operands[0], "")
        shapes = _SHAPE_RE.findall(lhs_type)
        if not shapes:
            return 1
        lhs_dims = [int(d) for d in shapes[0][1].split(",") if d]
        k = 1
        for d in dims:
            if d < len(lhs_dims):
                k *= lhs_dims[d]
        return k

    def hbm_bytes(self) -> float:
        """HBM traffic estimate: every materialized buffer is written once
        and read ~once downstream -> 2 x result bytes per op, with aliasing
        exceptions (while carries, in-place dynamic-update-slice, slices of
        big buffers only move the slice).  scatter writes in place — only
        its update rows move, never the whole operand — and gather /
        dynamic-slice move the gathered rows (the in-flight scheduler's
        slot state updates and KV-cache reads).

        Pallas-kernel awareness: ops whose metadata op_name contains
        "vmem_kernel" (our named_scope marker around pl.pallas_call in
        interpret mode) model VMEM-resident compute; computations where the
        majority of ops carry the marker (the interpreter grid loop — XLA
        strips metadata from its carry copies) are treated the same.  In
        VMEM context only block streaming counts as HBM traffic:
        dynamic-slice reads (HBM->VMEM DMA) and dynamic-update-slice
        update writes (VMEM->HBM DMA) — exactly the BlockSpec-declared
        I/O of the kernel on a real TPU."""
        # a computation is VMEM-resident when it contains marked kernel ops
        # and every *unmarked* op is interpreter carry plumbing (XLA strips
        # metadata from the copies it inserts around while carries)
        plumbing = {"copy", "get-tuple-element", "tuple", "parameter",
                    "constant", "bitcast", "select", "add", "subtract",
                    "multiply", "divide", "compare", "and", "or", "not",
                    "convert", "broadcast", "reshape", "iota",
                    "dynamic-slice", "dynamic-update-slice", "fusion"}
        # NOTE: "fusion" is safe here — real model computations always
        # contain dots / whiles / collectives, which are not plumbing, so
        # only interpreter grid-loop bodies (whose fusions are carry
        # plumbing fused by the CPU backend) can classify as VMEM.
        mostly_vmem = {}
        for name, comp in self.computations.items():
            if not comp.ops:
                mostly_vmem[name] = False
                continue
            marked = sum(1 for op in comp.ops if "vmem_kernel" in op.line)
            unmarked_ok = all(op.opcode in plumbing for op in comp.ops
                              if "vmem_kernel" not in op.line)
            mostly_vmem[name] = (marked > 0.5 * len(comp.ops)
                                 or (marked > 0 and unmarked_ok))

        total = 0.0
        for comp in self.computations.values():
            m = self.multipliers.get(comp.name, 1.0)
            vmem_comp = mostly_vmem[comp.name]
            for op in comp.ops:
                if op.opcode in _FREE_OPS or op.opcode in _ELEMENTWISE:
                    continue
                if op.opcode in ("while", "conditional", "call"):
                    continue   # bodies are accounted via multipliers
                in_vmem = vmem_comp or "vmem_kernel" in op.line
                if op.opcode == "dynamic-update-slice":
                    if in_vmem:
                        # the interpreter DS-reads every block it later
                        # DUS-writes (read-modify-write), so the DS stream
                        # already counts both directions; skip the DUS.
                        continue
                    if len(op.operands) > 1:
                        t = comp.symbols.get(op.operands[1])
                        ub = _shape_elems_bytes(t)[1] if t else 0
                        total += 2.0 * ub * m
                    continue
                if op.opcode == "scatter":
                    # scatter(operand, indices, updates) writes in place:
                    # only the update rows move (the scheduler's slot
                    # state[slot] := row), never the whole operand — the
                    # generic 2 x result-bytes rule would charge the full
                    # state buffer per decode step
                    ub = 0
                    if len(op.operands) > 2:
                        t = comp.symbols.get(op.operands[2])
                        ub = _shape_elems_bytes(t)[1] if t else 0
                    total += (ub if in_vmem else 2.0 * ub) * m
                    continue
                if op.opcode in ("gather", "dynamic-slice"):
                    # gathered/sliced rows move, not the source buffer; in
                    # VMEM context this is the HBM->VMEM DMA read stream
                    _, rb = _shape_elems_bytes(op.result)
                    total += (rb if in_vmem else 2.0 * rb) * m
                    continue
                if in_vmem:
                    continue                      # VMEM-resident compute
                _, wb = _shape_elems_bytes(op.result)
                total += 2.0 * wb * m
        return total

    def collective_bytes(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = defaultdict(
            lambda: {"count": 0, "bytes": 0.0})
        for comp in self.computations.values():
            m = self.multipliers.get(comp.name, 1.0)
            for op in comp.ops:
                base = op.opcode.replace("-start", "")
                if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                    _, b = _shape_elems_bytes(op.result)
                    out[base]["count"] += 1
                    out[base]["bytes"] += b * m
        return dict(out)


def analyze(hlo_text: str) -> Dict[str, object]:
    mod = HLOModule(hlo_text)
    coll = mod.collective_bytes()
    return {
        "hlo_flops": mod.flops(),
        "hlo_bytes": mod.hbm_bytes(),
        "collectives": coll,
        "collective_bytes": sum(v["bytes"] for v in coll.values()),
    }


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    return HLOModule(hlo_text).collective_bytes()
