"""Production mesh definitions (DESIGN.md §5).

Functions, not module-level constants: importing this module never touches
jax device state (required by the dry-run's XLA_FLAGS ordering).
"""
from __future__ import annotations

import jax

from repro.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host offers (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))
