"""Production mesh definitions (DESIGN.md §5).

Functions, not module-level constants: importing this module never touches
jax device state (required by the dry-run's XLA_FLAGS ordering).
"""
from __future__ import annotations

import jax

from repro.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host offers (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))


def make_engine_mesh(devices: int = 0, axis: str = "macro"):
    """1-D mesh over the first `devices` host devices for the CIM engine's
    sharded multi-macro dispatch (runtime.engine.ShardingConfig).

    `devices=0` takes every visible device.  CPU-only dev/CI emulates a
    bank of macros with XLA_FLAGS=--xla_force_host_platform_device_count=N
    (set before jax import).  Raises ValueError when asking for more
    devices than jax reports."""
    import numpy as np

    devs = jax.devices()
    n = devices if devices > 0 else len(devs)
    if n > len(devs):
        raise ValueError(
            f"sharded engine dispatch wants {n} devices but jax reports "
            f"{len(devs)}; on CPU, relaunch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n}")
    return jax.sharding.Mesh(np.asarray(devs[:n]), (axis,))
