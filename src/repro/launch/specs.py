"""Sharding rules + ShapeDtypeStruct input specs for every (arch x shape).

`param_specs` maps the param pytree to PartitionSpecs by leaf path
(Megatron TP on "model"; DP replication elsewhere).  `input_specs` builds
allocation-free stand-ins for the dry-run (the shannon/kernels pattern).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf

# (path regex, spec builder given leaf ndim) — first match wins.
# Specs are written for the *stacked* (leading layer axis) layout.
#
# 2-D weight matrices are FULLY sharded: TP ("model") on the Megatron axis
# AND FSDP/ZeRO ("pod","data") on the other matrix axis — without the FSDP
# axis, mixtral-8x22b/internvl2-76b fp32 masters + Adam moments exceed HBM
# (the dry-run's memory_analysis catches this).  XLA auto-inserts the
# per-layer weight all-gathers this implies, exactly like FSDP.
_FSDP = ("pod", "data")

_RULES = [
    # embeddings / lm head
    (r"embed$", lambda nd: P("model", _FSDP)),
    (r"lm_head/w(_q)?$", lambda nd: P(_FSDP, "model")),
    (r"pos_dec$", lambda nd: P(None, None)),
    # attention projections (stacked: L leading)
    (r"(attn|xattn)/w[qkv]/w(_q)?$",
     lambda nd: P(*([None] * (nd - 2)), _FSDP, "model")),
    (r"(attn|xattn)/wo/w(_q)?$",
     lambda nd: P(*([None] * (nd - 2)), "model", _FSDP)),
    (r"(attn|xattn)/b[qkv]$", lambda nd: P(*([None] * (nd - 1)), "model")),
    (r"(attn|xattn)/w[qkv]/abn_", lambda nd: P(*([None] * (nd - 1)), "model")),
    # MLP
    (r"mlp/w_(up|gate)/w(_q)?$",
     lambda nd: P(*([None] * (nd - 2)), _FSDP, "model")),
    (r"mlp/w_down/w(_q)?$",
     lambda nd: P(*([None] * (nd - 2)), "model", _FSDP)),
    (r"mlp/w_(up|gate)/abn_", lambda nd: P(*([None] * (nd - 1)), "model")),
    # MoE experts: (L, E, D, F) / (L, E, F, D); router replicated
    (r"moe/w_(up|gate)(_q)?$",
     lambda nd: P(*([None] * (nd - 2)), _FSDP, "model")),
    (r"moe/w_down(_q)?$", lambda nd: P(*([None] * (nd - 2)), "model", _FSDP)),
    (r"moe/w_\w+_scale$", lambda nd: P(*([None] * (nd - 1)), "model")),
    (r"moe/router$", lambda nd: P()),
    # Mamba-2
    (r"mixer/in_proj/w(_q)?$",
     lambda nd: P(*([None] * (nd - 2)), _FSDP, "model")),
    (r"mixer/in_proj/abn_", lambda nd: P(*([None] * (nd - 1)), "model")),
    (r"mixer/out_proj/w(_q)?$",
     lambda nd: P(*([None] * (nd - 2)), "model", _FSDP)),
    (r"mixer/conv_w$", lambda nd: P(*([None] * (nd - 1)), "model")),
    (r"mixer/conv_b$", lambda nd: P(*([None] * (nd - 1)), "model")),
    (r"mixer/gate_norm$", lambda nd: P(*([None] * (nd - 1)), "model")),
    # RG-LRU
    (r"rec/w_(gelu|rnn)/w(_q)?$",
     lambda nd: P(*([None] * (nd - 2)), _FSDP, "model")),
    (r"rec/w_(gelu|rnn)/abn_", lambda nd: P(*([None] * (nd - 1)), "model")),
    (r"rec/w_(a|x)$", lambda nd: P(*([None] * (nd - 2)), _FSDP, "model")),
    (r"rec/b_(a|x)$", lambda nd: P(*([None] * (nd - 1)), "model")),
    (r"rec/(conv_w|conv_b|lam)$", lambda nd: P(*([None] * (nd - 1)), "model")),
    (r"rec/w_out/w(_q)?$",
     lambda nd: P(*([None] * (nd - 2)), "model", _FSDP)),
]


def _path_to_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _spec_for(path: str, leaf) -> P:
    for pat, builder in _RULES:
        if re.search(pat, path):
            return builder(leaf.ndim)
    return P()   # replicated


def _validate(spec: P, shape, mesh) -> P:
    """Filter spec axes that are absent from the mesh; keep the largest
    prefix of each tuple that still divides the dim (odd vocabs, tiny
    dims, missing 'pod' axis on the single-pod mesh)."""
    elems = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, elems):
        if e is None:
            out.append(None)
            continue
        axes = (e,) if isinstance(e, str) else tuple(e)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        kept, prod = [], 1
        for a in axes:
            if dim % (prod * mesh.shape[a]) == 0:
                kept.append(a)
                prod *= mesh.shape[a]
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def param_specs(params, mesh) -> Any:
    """Pytree of PartitionSpecs matching `params`."""
    def one(path, leaf):
        spec = _spec_for(_path_to_str(path), leaf)
        return _validate(spec, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(one, params)


def tree_shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — never allocate)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Stand-ins for one step's inputs.

    train  : tokens/labels (B, S) (+ modality stubs)
    prefill: tokens (B, S)
    decode : tokens (B, 1) + cache
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    sds = jax.ShapeDtypeStruct

    if shape.kind == "train":
        if cfg.family == "audio":
            lt = min(cfg.max_target_len, s // 8)
            return {"encoder_frames": sds((b, s, cfg.d_model), bf16),
                    "tokens": sds((b, lt), i32),
                    "labels": sds((b, lt), i32)}
        if cfg.family == "vlm":
            st = s - cfg.vision_tokens
            return {"prefix_embeds": sds((b, cfg.vision_tokens, cfg.d_model),
                                         bf16),
                    "tokens": sds((b, st), i32),
                    "labels": sds((b, st), i32)}
        return {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}

    if shape.kind == "prefill":
        if cfg.family == "audio":
            lt = min(cfg.max_target_len, 448)
            return {"encoder_frames": sds((b, s, cfg.d_model), bf16),
                    "tokens": sds((b, lt), i32)}
        if cfg.family == "vlm":
            st = s - cfg.vision_tokens
            return {"prefix_embeds": sds((b, cfg.vision_tokens, cfg.d_model),
                                         bf16),
                    "tokens": sds((b, st), i32)}
        return {"tokens": sds((b, s), i32)}

    if shape.kind == "decode":
        cache = jax.eval_shape(
            lambda: tf.init_cache(cfg, b, max_len=s))
        return {"tokens": sds((b, 1), i32), "cache": cache}

    raise ValueError(shape.kind)


def batch_specs(inputs: Dict[str, Any], mesh) -> Dict[str, Any]:
    """PartitionSpecs for the input pytree."""
    ba = batch_axes(mesh)

    def spec_of(path, leaf):
        p = _path_to_str(path)
        nd = len(leaf.shape)
        if p.startswith("cache"):
            if re.search(r"/k$|/v$", p) and nd == 5:
                # (L, B, S, G, hd): seq-sharded over model (DESIGN.md §5)
                sp = P(None, ba, "model", None, None)
            elif re.search(r"/ssm$", p) and nd == 5:
                sp = P(None, ba, "model", None, None)
            elif re.search(r"/conv$", p) and nd == 4:
                sp = P(None, ba, None, "model")
            elif re.search(r"/h$", p) and nd == 3:
                sp = P(None, ba, "model")
            else:
                sp = P()
        elif nd >= 2:
            sp = P(ba, *([None] * (nd - 1)))
        else:
            sp = P()
        return _validate(sp, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_of, inputs)
