"""Versioned on-disk autotune cache (winners persist, plans stay one-shot).

The cache is one JSON file: `{"schema": N, "entries": {key: entry}}`.
Keys encode everything a winner depends on — tile geometry (m, k, n),
precision (r_in, r_w, r_out), conv/dense kind, device count, and macro
geometry — plus the schema version at the file level, so a model change
invalidates every stale winner at once.

Degradation policy (the contract tests/test_tuner.py pins): a corrupt
file, a schema/version mismatch, or an invalid individual entry NEVER
crashes compilation — the affected layers fall back to the heuristic
schedule with a single `TuneCacheWarning`, and a degraded cache neither
searches nor writes (so a bad file cannot grow).  A *missing* entry is
normal operation: the search runs once and the winner is written back
atomically (tmp + rename).  A valid hit skips the search entirely —
observable through `search.SEARCH_COUNT`.
"""
from __future__ import annotations

import json
import os
import warnings
from typing import Dict, Optional, Tuple

from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO
from repro.core.mapping import LayerSpec
from repro.tuner.cost import ScheduleChoice

SCHEMA_VERSION = 1

# statuses TuneCache.get can report for a key
HIT, MISS, INVALID = "hit", "miss", "invalid"

_ENTRY_INT_FIELDS = ("bm", "bn", "bk")
_KINDS = (None, "col", "rows")


class TuneCacheWarning(UserWarning):
    """A cache file or entry was unusable; the heuristic schedule ran."""


def default_cache_path() -> str:
    """The cache location: $REPRO_AUTOTUNE_CACHE or
    ~/.cache/repro-cim/autotune.json."""
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-cim",
                        "autotune.json")


def cache_key(spec: LayerSpec, devices: int,
              macro: CIMMacroConfig = DEFAULT_MACRO) -> str:
    """The string key one layer's winner is stored under: tile geometry,
    precision, conv/dense kind, device count, macro geometry.  The schema
    version lives at the file level, not in the key."""
    kind = "conv" if spec.conv is not None else "dense"
    return (f"m{spec.m}k{spec.k}n{spec.n}"
            f"r{spec.r_in}x{spec.r_w}x{spec.r_out}"
            f"{kind}d{int(devices)}g{macro.n_rows}x{macro.n_cols}")


def _valid_entry(entry) -> bool:
    if not isinstance(entry, dict):
        return False
    for f in _ENTRY_INT_FIELDS:
        v = entry.get(f)
        if not isinstance(v, int) or v < 1:
            return False
    return entry.get("shard_kind") in _KINDS


class TuneCache:
    """One autotune cache file, loaded once per compile.

    `degraded` is True when the file was corrupt or schema-mismatched: the
    cache then answers INVALID for every key and refuses writes.  `stats`
    counts hits/misses/invalid lookups (test observability)."""

    def __init__(self, path: str, entries: Optional[Dict] = None,
                 degraded: bool = False):
        self.path = path
        self.entries: Dict[str, dict] = dict(entries or {})
        self.degraded = degraded
        self.stats = {"hits": 0, "misses": 0, "invalid": 0, "writes": 0}

    @classmethod
    def load(cls, path: str) -> "TuneCache":
        """Read the cache file; any unreadable/corrupt/stale state warns
        once and returns a degraded cache (heuristic fallback, no
        searching, no writes) instead of raising."""
        if not os.path.exists(path):
            return cls(path)
        try:
            with open(path, encoding="utf-8") as fh:
                raw = json.load(fh)
        except (OSError, ValueError) as e:
            warnings.warn(
                f"autotune cache {path} is unreadable ({e}); falling back "
                "to heuristic schedules", TuneCacheWarning, stacklevel=2)
            return cls(path, degraded=True)
        if not isinstance(raw, dict) or raw.get("schema") != SCHEMA_VERSION:
            warnings.warn(
                f"autotune cache {path} has schema "
                f"{raw.get('schema') if isinstance(raw, dict) else '?'} "
                f"(expected {SCHEMA_VERSION}); falling back to heuristic "
                "schedules", TuneCacheWarning, stacklevel=2)
            return cls(path, degraded=True)
        entries = raw.get("entries")
        if not isinstance(entries, dict):
            warnings.warn(
                f"autotune cache {path} has no entries table; falling "
                "back to heuristic schedules", TuneCacheWarning,
                stacklevel=2)
            return cls(path, degraded=True)
        return cls(path, entries=entries)

    def get(self, key: str) -> Tuple[str, Optional[ScheduleChoice]]:
        """Look one key up: (HIT, choice), (MISS, None) — search and
        store — or (INVALID, None) — warn and run the heuristic."""
        if self.degraded:
            self.stats["invalid"] += 1
            return INVALID, None
        entry = self.entries.get(key)
        if entry is None:
            self.stats["misses"] += 1
            return MISS, None
        if not _valid_entry(entry):
            self.stats["invalid"] += 1
            warnings.warn(
                f"autotune cache entry {key!r} in {self.path} is invalid; "
                "using the heuristic schedule for that layer",
                TuneCacheWarning, stacklevel=2)
            return INVALID, None
        self.stats["hits"] += 1
        return HIT, ScheduleChoice(entry["bm"], entry["bn"], entry["bk"],
                                   entry.get("shard_kind"))

    def put(self, key: str, choice: ScheduleChoice, *, mode: str,
            total_s: float) -> None:
        """Record one winner (no-op on a degraded cache)."""
        if self.degraded:
            return
        self.entries[key] = {
            "bm": int(choice.bm), "bn": int(choice.bn),
            "bk": int(choice.bk), "shard_kind": choice.shard_kind,
            "mode": mode, "total_s": float(total_s),
        }
        self.stats["writes"] += 1

    def save(self) -> None:
        """Atomically persist the entries (tmp + rename); degraded caches
        never write.  Directory creation is implicit."""
        if self.degraded:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as fh:
            json.dump({"schema": SCHEMA_VERSION, "entries": self.entries},
                      fh, indent=1, sort_keys=True)
        os.replace(tmp, self.path)
