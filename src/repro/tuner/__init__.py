"""Roofline-driven schedule autotuner for compiled CIM programs.

Three pieces, one invariant:

  * `cost` — the analytic per-layer roofline model (macro evals, kernel
    DMA bytes, collective bytes) on the shared `core.hw` tables.
  * `search` — the plan-time candidate scan (`tune_network`), heuristic
    candidate scored first so tuned cost <= heuristic cost always.
  * `cache` — the versioned on-disk winner store; corrupt or stale files
    degrade to the heuristic with a warning, never a crash.

The invariant: tuning NEVER changes numerics.  Block sizes only move DMA
traffic (exact int32 accumulation), shard kinds are bit-exact partitions,
and noise draws are keyed per global row block — so a tuned program's
outputs are bit-identical to the heuristic program's, fuzzed and gated by
tests/test_tuner.py.

Entry points: `runtime.program.compile_program(..., tune="analytic")`
for the integrated path, or `search.tune_network` directly.
"""
from repro.tuner.cache import (SCHEMA_VERSION, TuneCache, TuneCacheWarning,
                               cache_key, default_cache_path)
from repro.tuner.cost import (LayerCost, ScheduleChoice, kernel_dma_bytes,
                              layer_cost)
from repro.tuner.search import (SEARCH_COUNT, heuristic_choice,
                                layer_candidates, tune_layer, tune_network)

__all__ = [
    "SCHEMA_VERSION", "TuneCache", "TuneCacheWarning", "cache_key",
    "default_cache_path", "LayerCost", "ScheduleChoice", "kernel_dma_bytes",
    "layer_cost", "SEARCH_COUNT", "heuristic_choice", "layer_candidates",
    "tune_layer", "tune_network",
]
