"""Analytic roofline cost model for CIM schedule candidates.

One `ScheduleChoice` — a (bm, bn, bk) kernel block triple plus an optional
explicit shard kind — is scored per layer with the same hardware tables the
rest of the repo uses (one source of truth each):

  * macro time: per-device macro evaluations x `macro_perf.cim_eval_time_ns`
    (the Sec. III.C/D phase sequence).  The eval counts agree EXACTLY with
    `macro_perf.AcceleratorPerfModel.layer_report["macro_evals"]` and with
    `schedule_report`'s per-device shard counts — tested, not assumed.
  * DMA time: the host-side HBM<->VMEM bytes the Pallas kernel's BlockSpecs
    declare, divided by `hw.TPU_V5E.hbm_bw`.  The byte model mirrors the
    kernel's grid (M/bm, N/bn, P*K/bk): the x tile re-streams once per
    column block, the w tile once per row block and per input plane, the
    int32 out tile writes once — the same dynamic-slice/DUS traffic
    `launch/hlo_analysis.hbm_bytes` counts on the lowered module.  This is
    the only term the block sizes move, which is exactly why tuning them is
    numerics-neutral.
  * collective time: the all-gather bytes a sharded layer exchanges
    (output columns under "col", output rows under "rows"), divided by
    `hw.EFFECTIVE_LINKS * hw.TPU_V5E.ici_bw_per_link` — the identical
    expression `benchmarks/roofline.py` uses.

The score is the roofline bound max(t_macro, t_dma, t_collective); ties
break toward lower DMA traffic and then toward the heuristic choice (the
search guarantees tuned cost <= heuristic cost by always scoring the
heuristic candidate itself).

Everything here is pure integer/float geometry — no jax, no arrays — so
plan-time search over a few hundred candidates costs microseconds.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.core import mapping
from repro.core.hw import (CIMMacroConfig, DEFAULT_MACRO, EFFECTIVE_LINKS,
                           TPU_V5E, TPUSpec)
from repro.kernels.cim_mbiw.kernel import plane_layout
from repro.perfmodel.macro_perf import cim_eval_time_ns


@dataclasses.dataclass(frozen=True)
class ScheduleChoice:
    """One candidate schedule for a layer: kernel blocks + shard kind.

    `shard_kind` is None for unsharded plans (and for "keep the heuristic
    kind" on sharded ones); "col"/"rows" forces the partition.  Choices
    are hashable — they key the autotune cache entries."""
    bm: int
    bn: int
    bk: int
    shard_kind: Optional[str] = None

    @property
    def blocks(self) -> Tuple[int, int, int]:
        """The (bm, bn, bk) triple, the kernel-variant knob."""
        return (self.bm, self.bn, self.bk)


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """Analytic cost of one (layer, ScheduleChoice, device count) point.

    Counts are exact geometry (macro_evals matches macro_perf's
    layer_report bit for bit); times are roofline terms on the shared
    hardware tables.  `total_s` is the roofline bound max(macro, dma,
    collective) — the scalar the search minimizes."""
    macro_evals: int              # total macro invocations (all devices)
    macro_evals_per_device: int   # critical-path invocations on one device
    adc_conversions: int          # column conversions (evals x tile chans)
    dma_bytes: int                # per-device kernel HBM<->VMEM traffic
    collective_bytes: int         # per-device all-gather bytes received
    t_macro_s: float
    t_dma_s: float
    t_collective_s: float
    total_s: float

    def score(self) -> Tuple[float, float, int]:
        """Lexicographic comparison key: roofline bound, then DMA time,
        then raw DMA bytes (stable tie-breaking across candidates)."""
        return (self.total_s, self.t_dma_s, self.dma_bytes)


def _pad_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def kernel_dma_bytes(rows: int, k: int, n: int, bm: int, bn: int, bk: int,
                     n_planes: int) -> int:
    """HBM<->VMEM bytes one kernel dispatch of a (rows, k) x (k, n) tile
    moves at the given block sizes.

    Mirrors the kernel's BlockSpecs on the padded operands (grid
    (M/bm, N/bn, P*K/bk), plane-major K innermost): the int8 x tile is
    re-fetched for every column block, the int8 w tile for every row block
    and every plane (the kernel's documented P-redundant w traffic), the
    (1, bn) gamma/beta rows per (i, j) step, and the int32 out tile is
    written once per (i, j) — its block index is constant across the
    innermost K axis, so it stays resident in VMEM."""
    mp_ = _pad_up(max(rows, 1), bm)
    kp = _pad_up(max(k, 1), bk)          # per-plane padded K
    np_ = _pad_up(max(n, 1), bn)
    x_bytes = mp_ * n_planes * kp * (np_ // bn)          # int8
    w_bytes = (mp_ // bm) * n_planes * kp * np_          # int8
    out_bytes = mp_ * np_ * 4                            # int32, one write
    gb_bytes = 2 * (mp_ // bm) * np_ * 4                 # gamma + beta rows
    return x_bytes + w_bytes + out_bytes + gb_bytes


def layer_cost(spec: mapping.LayerSpec, choice: ScheduleChoice, *,
               devices: int = 1, macro: CIMMacroConfig = DEFAULT_MACRO,
               tpu: TPUSpec = TPU_V5E) -> LayerCost:
    """Score one layer under one schedule choice on `devices` macros.

    The macro term uses the per-device critical-path eval count (the same
    shard arithmetic `macro_perf.schedule_report` reports); the DMA term
    sums the per-device kernel dispatches' declared traffic; the
    collective term charges the output all-gather of the chosen shard
    kind.  devices=1 has no collective and the full schedule on the one
    device, whatever `choice.shard_kind` says."""
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    mp = mapping.map_layer(spec, macro)
    kt, nt = mp.row_tiles, mp.col_tiles
    tile_n = math.ceil(spec.n / nt)      # uniform col-tile width
    _, n_planes = plane_layout(spec.r_in)
    evals_total = mp.macro_evals * spec.m
    if devices == 1:
        rows_local, nt_local = spec.m, nt
        evals_dev = evals_total
        coll_bytes = 0
    else:
        shard = mapping.shard_layer(spec, mp, devices,
                                    kind=choice.shard_kind)
        if shard.kind == "col":
            rows_local = spec.m
            nt_local = shard.tiles_per_device
            evals_dev = kt * nt_local * spec.m
            # all-gather of the output columns: each device receives the
            # other devices' (m, tiles_per_device * tile_n) int32 slabs
            n_tot = shard.devices * nt_local * tile_n
            coll_bytes = spec.m * (n_tot - nt_local * tile_n) * 4
        else:
            rows_local = shard.rows_per_device
            nt_local = nt
            evals_dev = mp.macro_evals * rows_local
            # all-gather of the output rows (padded col extent)
            m_tot = shard.devices * rows_local
            coll_bytes = (m_tot - rows_local) * nt * tile_n * 4
    t_eval_ns = cim_eval_time_ns(spec.r_in, spec.r_w, spec.r_out, macro)
    t_macro = evals_dev * t_eval_ns * 1e-9
    # per-device DMA: one kernel dispatch per (row tile, local col tile);
    # every row tile spans mp.rows_per_tile rows (the last may be smaller —
    # charging it full keeps the model monotone and upper-bounding)
    dma = nt_local * kt * kernel_dma_bytes(
        rows_local, mp.rows_per_tile, tile_n, choice.bm, choice.bn,
        choice.bk, n_planes)
    t_dma = dma / tpu.hbm_bw
    t_coll = coll_bytes / (EFFECTIVE_LINKS * tpu.ici_bw_per_link)
    return LayerCost(
        macro_evals=evals_total, macro_evals_per_device=evals_dev,
        adc_conversions=evals_dev * min(tile_n, spec.n),
        dma_bytes=dma, collective_bytes=coll_bytes,
        t_macro_s=t_macro, t_dma_s=t_dma, t_collective_s=t_coll,
        total_s=max(t_macro, t_dma, t_coll))
