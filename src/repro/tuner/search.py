"""Plan-time schedule search: score every candidate, keep the winner.

`tune_network` is the tuner's entry point (what
`runtime.program.compile_program(tune=...)` calls): for each layer it
enumerates the legal (bm, bn, bk) block triples from
`kernels.cim_mbiw.ops.block_candidates` crossed with the legal shard
kinds, scores each with `cost.layer_cost`, and keeps the strict-best —
the heuristic candidate (the EngineConfig blocks + automatic shard kind)
is scored FIRST, so the tuned schedule's analytic cost is <= the
heuristic's by construction.  In "measure" mode the analytic top-k
candidates are additionally wall-clock timed on synthetic tile data and
the fastest measured one wins.

Winners that exactly match the heuristic fold to `None` in the schedule
handed to `plan_network`, so a no-win layer produces a plan that hashes
(and caches) identically to the untuned one.

`SEARCH_COUNT` counts layers actually searched (cache hits skip it) —
the tuner-side mirror of `engine.PLAN_COUNT`, asserted by
tests/test_tuner.py's cache round-trip.

Tuning is numerics-neutral end to end: block sizes never change bits
(exact int32 accumulation — see `kernel_variant_for_tile`) and both
shard kinds are bit-exact partitions of the same schedule, so the search
is free to chase the roofline without a single output bit moving.
"""
from __future__ import annotations

import math
import time
from typing import List, Optional, Sequence, Tuple

from repro.core import mapping
from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO
from repro.kernels.cim_mbiw import ops as kops
from repro.tuner import cache as tcache
from repro.tuner.cost import LayerCost, ScheduleChoice, layer_cost

# layers searched (cache misses that ran the candidate scan); a cache hit
# or a degraded/invalid cache entry does NOT increment it
SEARCH_COUNT = {"n": 0}

MEASURE_TOP_K = 3       # candidates wall-clock timed in "measure" mode
_MEASURE_ITERS = 3      # timing repeats (min taken)

MODES = ("analytic", "measure")


def heuristic_choice(spec: mapping.LayerSpec, cfg,
                     macro: CIMMacroConfig = DEFAULT_MACRO) -> ScheduleChoice:
    """The schedule the engine would run untuned: the EngineConfig block
    sizes clamped to the layer's dispatched tile geometry, automatic
    shard kind (shard_kind=None)."""
    mp = mapping.map_layer(spec, macro)
    tile_n = math.ceil(spec.n / mp.col_tiles)
    return ScheduleChoice(
        kops._clamp_block(getattr(cfg, "bm", 128), spec.m),
        kops._clamp_block(getattr(cfg, "bn", 128), tile_n),
        kops._clamp_block(getattr(cfg, "bk", 256), mp.rows_per_tile),
        None)


def layer_candidates(spec: mapping.LayerSpec, cfg, devices: int,
                     macro: CIMMacroConfig = DEFAULT_MACRO
                     ) -> List[ScheduleChoice]:
    """Every candidate the search scores for one layer, heuristic first.

    Blocks come from the ops palette clamped to (rows, rows_per_tile,
    tile_n); shard kinds are {None} unsharded and {auto-kind-first
    "col"/"rows"} on multi-device plans.  Deduplicated, order-stable."""
    mp = mapping.map_layer(spec, macro)
    tile_n = math.ceil(spec.n / mp.col_tiles)
    if devices <= 1:
        kinds: Tuple[Optional[str], ...] = (None,)
    else:
        auto = "col" if mp.col_tiles >= devices else "rows"
        kinds = (auto, "rows" if auto == "col" else "col")
    out = [heuristic_choice(spec, cfg, macro)]
    seen = {out[0]}
    for kind in kinds:
        rows_local = spec.m
        if kind == "rows":
            rows_local = mapping.shard_layer(spec, mp, devices,
                                             kind=kind).rows_per_device
        for bm, bn, bk in kops.block_candidates(rows_local, mp.rows_per_tile,
                                                tile_n):
            c = ScheduleChoice(bm, bn, bk, kind)
            if c not in seen:
                seen.add(c)
                out.append(c)
    return out


def _measure_choice_s(spec: mapping.LayerSpec, choice: ScheduleChoice,
                      macro: CIMMacroConfig, interpret: bool) -> float:
    """Wall-clock one candidate: run the real kernel on deterministic
    synthetic data for one (row tile, col tile) dispatch and take the min
    of a few repeats.  Used only for ranking — never for numerics."""
    import numpy as np
    import jax

    mp = mapping.map_layer(spec, macro)
    k_tile = min(spec.k, mp.rows_per_tile)
    tile_n = math.ceil(spec.n / mp.col_tiles)
    rng = np.random.default_rng(0)
    x_q = rng.integers(0, 2 ** spec.r_in, (spec.m, k_tile), dtype=np.int32)
    w_q = 2 * rng.integers(0, 2 ** (spec.r_w - 1), (k_tile, tile_n),
                           dtype=np.int32) + 1
    gamma = np.ones((tile_n,), np.float32)
    beta = np.zeros((tile_n,), np.float32)

    def run():
        out = kops.cim_matmul(
            jax.numpy.asarray(x_q), jax.numpy.asarray(w_q),
            jax.numpy.asarray(gamma), jax.numpy.asarray(beta),
            r_in=spec.r_in, r_out=spec.r_out, g0=1.0,
            bm=choice.bm, bn=choice.bn, bk=choice.bk, interpret=interpret)
        jax.block_until_ready(out)

    run()                              # compile outside the timed region
    best = float("inf")
    for _ in range(_MEASURE_ITERS):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return best


def tune_layer(spec: mapping.LayerSpec, cfg, devices: int, *,
               mode: str = "analytic",
               cache: Optional[tcache.TuneCache] = None,
               macro: CIMMacroConfig = DEFAULT_MACRO
               ) -> Tuple[ScheduleChoice, dict]:
    """Pick one layer's schedule: cache hit -> stored winner (no search);
    miss -> full candidate scan (SEARCH_COUNT += 1) + write-back;
    invalid/degraded cache entry -> heuristic with the cache's warning.

    Returns (choice, report); the report echoes the cache status, the
    heuristic and tuned analytic costs, and the candidate count."""
    heur = heuristic_choice(spec, cfg, macro)
    heur_cost = layer_cost(spec, heur, devices=devices, macro=macro)
    key = tcache.cache_key(spec, devices, macro)
    report = {"key": key, "mode": mode, "heuristic": heur,
              "heuristic_s": heur_cost.total_s}

    status = tcache.MISS
    if cache is not None:
        status, cached = cache.get(key)
        if status == tcache.HIT:
            c_cost = layer_cost(spec, cached, devices=devices, macro=macro)
            report.update(cache=tcache.HIT, choice=cached,
                          predicted_s=c_cost.total_s, candidates=0)
            return cached, report
        if status == tcache.INVALID:
            report.update(cache=tcache.INVALID, choice=heur,
                          predicted_s=heur_cost.total_s, candidates=0)
            return heur, report

    SEARCH_COUNT["n"] += 1
    cands = layer_candidates(spec, cfg, devices, macro)
    scored = [(layer_cost(spec, c, devices=devices, macro=macro), c)
              for c in cands]
    best_cost, best = scored[0]        # the heuristic — ties keep it
    for lc, c in scored[1:]:
        if lc.score() < best_cost.score():
            best_cost, best = lc, c

    if mode == "measure":
        ranked = sorted(scored, key=lambda sc: sc[0].score())
        top = ranked[:MEASURE_TOP_K]
        interpret = bool(getattr(cfg, "interpret", True))
        timed = [(_measure_choice_s(spec, c, macro, interpret), lc, c)
                 for lc, c in top]
        _, best_cost, best = min(timed, key=lambda t: t[0])

    if cache is not None:
        cache.put(key, best, mode=mode, total_s=best_cost.total_s)
    report.update(cache=status, choice=best,
                  predicted_s=best_cost.total_s, candidates=len(cands))
    return best, report


def _fold(choice: ScheduleChoice, heur: ScheduleChoice
          ) -> Optional[Tuple[Tuple[int, int, int], Optional[str]]]:
    """Collapse a no-win choice to None so the tuned plan hashes (and
    program-caches) identically to the heuristic plan."""
    if choice == heur:
        return None
    return (choice.blocks, choice.shard_kind)


def tune_network(specs: Sequence[mapping.LayerSpec], cfg,
                 activations: Optional[Sequence[str]] = None,
                 pools: Optional[Sequence[int]] = None, *,
                 mode: str = "analytic",
                 cache_path: Optional[str] = None):
    """Tune every layer and build the (single PLAN_COUNT) tuned plan.

    Returns (NetworkPlan, reports): the plan comes from one
    `engine.plan_network(..., schedule=...)` call with no-win layers
    folded to None, and `reports` is the per-layer tune_layer echo list
    (consumed by `perfmodel.macro_perf.schedule_report`).  Passing
    cache_path="" disables the persistent cache entirely."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    from repro.runtime import engine  # avoid a module-load cycle

    devices = (cfg.sharding.resolve_devices()
               if getattr(cfg, "sharding", None) is not None else 1)
    macro = getattr(cfg, "macro", DEFAULT_MACRO)

    cache = None
    if cache_path != "":
        path = cache_path or tcache.default_cache_path()
        cache = tcache.TuneCache.load(path)

    schedule, reports = [], []
    wrote = False
    for spec in specs:
        choice, rep = tune_layer(spec, cfg, devices, mode=mode,
                                 cache=cache, macro=macro)
        wrote = wrote or rep.get("cache") == tcache.MISS
        schedule.append(_fold(choice, rep["heuristic"]))
        reports.append(rep)
    if cache is not None and wrote:
        cache.save()

    plan = engine.plan_network(specs, cfg, activations, pools,
                               schedule=tuple(schedule))
    return plan, reports
