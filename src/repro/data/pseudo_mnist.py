"""Procedural pseudo-MNIST (offline container: real MNIST unavailable).

Ten stroke-template digit classes rendered at 28x28 with random affine
jitter, stroke-thickness variation and pixel noise.  Classes are visually
distinct but overlapping enough that quantization / ABN effects change test
accuracy — which is what the paper's Fig. 3(b) experiment needs.
All claims in EXPERIMENTS.md compare against a full-precision baseline on
*this* data, never against the paper's MNIST numbers (DESIGN.md §8).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

# 7-segment-like templates on a 4x7 grid of strokes, per digit
_SEGS = {
    #        top  tl   tr   mid  bl   br   bot  diag
    0: (1, 1, 1, 0, 1, 1, 1, 0),
    1: (0, 0, 1, 0, 0, 1, 0, 0),
    2: (1, 0, 1, 1, 1, 0, 1, 0),
    3: (1, 0, 1, 1, 0, 1, 1, 0),
    4: (0, 1, 1, 1, 0, 1, 0, 0),
    5: (1, 1, 0, 1, 0, 1, 1, 0),
    6: (1, 1, 0, 1, 1, 1, 1, 0),
    7: (1, 0, 1, 0, 0, 1, 0, 1),
    8: (1, 1, 1, 1, 1, 1, 1, 0),
    9: (1, 1, 1, 1, 0, 1, 1, 0),
}


def _render(digit: int, rng: np.random.Generator) -> np.ndarray:
    img = np.zeros((28, 28), np.float32)
    x0, x1 = 7, 20
    y0, ym, y1 = 5, 14, 23
    th = rng.integers(1, 3)

    def hline(y, xa, xb):
        img[max(y - th, 0):y + th, xa:xb] = 1.0

    def vline(x, ya, yb):
        img[ya:yb, max(x - th, 0):x + th] = 1.0

    top, tl, tr, mid, bl, br, bot, diag = _SEGS[digit]
    if top:
        hline(y0, x0, x1)
    if mid:
        hline(ym, x0, x1)
    if bot:
        hline(y1, x0, x1)
    if tl:
        vline(x0, y0, ym)
    if tr:
        vline(x1, y0, ym)
    if bl:
        vline(x0, ym, y1)
    if br:
        vline(x1, ym, y1)
    if diag:
        for i in range(y0, y1):
            x = int(x1 - (x1 - x0) * (i - y0) / (y1 - y0))
            img[i, max(x - th, 0):x + th] = 1.0

    # random affine jitter: shift + slight scale
    sx, sy = rng.integers(-3, 4, 2)
    img = np.roll(np.roll(img, sy, axis=0), sx, axis=1)
    img += rng.normal(0.0, 0.15, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def make_dataset(n_train: int = 8000, n_test: int = 2000, seed: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    def gen(n):
        ys = rng.integers(0, 10, n)
        xs = np.stack([_render(int(y), rng) for y in ys])
        return xs.astype(np.float32), ys.astype(np.int32)
    xtr, ytr = gen(n_train)
    xte, yte = gen(n_test)
    return xtr, ytr, xte, yte
