"""Synthetic LM token pipeline.

Deterministic, seekable, shardable token stream — the properties a
production loader needs for fault-tolerant training:
  * `batch_at(step)` is a pure function of (seed, step, shard), so restarts
    resume mid-epoch with no state files and elastic re-sharding is exact;
  * tokens follow a Zipfian unigram mixed with short Markov motifs so the
    loss is learnable (not uniform noise) — smoke tests assert loss drops.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234


def _zipf_probs(v: int, alpha: float = 1.1) -> np.ndarray:
    r = np.arange(1, v + 1, dtype=np.float64)
    p = 1.0 / r ** alpha
    return p / p.sum()


class SyntheticLM:
    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg
        self._probs = _zipf_probs(cfg.vocab_size)
        # fixed motif table: next-token jump patterns
        rng = np.random.default_rng(cfg.seed)
        self._motif = rng.integers(0, cfg.vocab_size,
                                   size=(min(4096, cfg.vocab_size),))

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Deterministic (tokens, labels) for a given step/shard."""
        cfg = self.cfg
        per_shard = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            (cfg.seed, step, shard))
        toks = rng.choice(cfg.vocab_size, p=self._probs,
                          size=(per_shard, cfg.seq_len + 1)).astype(np.int32)
        # inject learnable motifs: with p=0.5 the next token is a function
        # of the previous one
        mask = rng.random((per_shard, cfg.seq_len)) < 0.5
        nxt = self._motif[toks[:, :-1] % len(self._motif)]
        toks[:, 1:] = np.where(mask, nxt, toks[:, 1:])
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
