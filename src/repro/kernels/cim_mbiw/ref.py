"""Pure-jnp oracles for the cim_mbiw kernel.

Semantics: one macro row-tile (K <= 1152) of the digital-equivalent CIM
matmul, ADC conversion fused in the epilogue:

    code[m, n] = clip( floor( 2^(r_out-1)
                              + gamma[n] * g0 * sum_k x[m,k] * w[k,n]
                              + beta[n] ),  0, 2^r_out - 1 )

x: unsigned ints < 2^r_in, w: odd ints in +/-(2^r_w - 1), g0 the unity-gain
code gain of digital_ref.adc_gain_factor.

Two oracles:
  * `cim_matmul_ref`        — direct integer matmul + epilogue (any r).
  * `cim_matmul_ref_serial` — the literal per-precision datapath: input
    planes walked at the precision's serial layout (bit-serial at 1-2b,
    nibble-serial at 3-8b) with the accumulator shift, weight bits combined
    spatially at 2^p column weights.  Bit-exact equal to the direct oracle;
    this is the per-precision reference the kernel dispatch is tested
    against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import digital_ref
from repro.kernels.cim_mbiw.kernel import plane_layout


def _adc_epilogue(dp: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
                  g0: float, r_out: int) -> jnp.ndarray:
    # beta may be (N,) per channel or (M, N) per GEMM row (segment-wise
    # quantization folds per-row zero-points into the ADC offset); either
    # broadcasts identically per element against the (M, N) dp
    beta_b = beta if beta.ndim >= 2 else beta[None, :]
    mid = 2.0 ** (r_out - 1)
    # barriered in float-op lockstep with the kernel epilogue (kernel.py):
    # pinning gain and gain*dp forbids context-dependent FMA contraction
    gain = jax.lax.optimization_barrier(gamma[None, :] * g0)
    t = jax.lax.optimization_barrier(gain * dp.astype(jnp.float32))
    code = jnp.floor(mid + t + beta_b)
    return jnp.clip(code, 0.0, 2.0 ** r_out - 1.0).astype(jnp.int32)


def cim_matmul_ref(x_q: jnp.ndarray, w_q: jnp.ndarray, gamma: jnp.ndarray,
                   beta: jnp.ndarray, *, g0: float, r_out: int
                   ) -> jnp.ndarray:
    dp = x_q.astype(jnp.int32) @ w_q.astype(jnp.int32)
    return _adc_epilogue(dp, gamma, beta, g0, r_out)


def cim_matmul_ref_serial(x_q: jnp.ndarray, w_q: jnp.ndarray,
                          gamma: jnp.ndarray, beta: jnp.ndarray, *,
                          r_in: int, r_w: int, r_out: int, g0: float
                          ) -> jnp.ndarray:
    """Per-precision serial walk:
        dp = sum_p 2^(shift*p) * sum_b 2^b * (plane_p(x) . S_b(w))
    with plane_p the precision's input plane slices and S_b the +/-1 weight
    bit-planes (weight-parallel column combination)."""
    shift, n_planes = plane_layout(r_in)
    x = x_q.astype(jnp.int32)
    mask = 2**shift - 1
    w_planes = digital_ref.encode_weight_planes(
        w_q.astype(jnp.int32), r_w)                       # (r_w, K, N)
    dp = jnp.zeros(x.shape[:-1] + (w_q.shape[-1],), jnp.int32)
    for p in range(n_planes):
        xp = (x >> (shift * p)) & mask
        per_plane = jnp.zeros_like(dp)
        for b in range(r_w):
            per_plane = per_plane + (2**b) * (
                xp @ w_planes[b].astype(jnp.int32))
        dp = dp + (2 ** (shift * p)) * per_plane
    return _adc_epilogue(dp, gamma, beta, g0, r_out)
