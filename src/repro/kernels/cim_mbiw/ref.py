"""Pure-jnp oracle for the cim_mbiw kernel.

Semantics: one macro row-tile (K <= 1152) of the digital-equivalent CIM
matmul, ADC conversion fused in the epilogue:

    code[m, n] = clip( floor( 2^(r_out-1)
                              + gamma[n] * g0 * sum_k x[m,k] * w[k,n]
                              + beta[n] ),  0, 2^r_out - 1 )

x: unsigned ints < 2^r_in, w: odd ints in +/-(2^r_w - 1), g0 the unity-gain
code gain of digital_ref.adc_gain_factor.
"""
from __future__ import annotations

import jax.numpy as jnp


def cim_matmul_ref(x_q: jnp.ndarray, w_q: jnp.ndarray, gamma: jnp.ndarray,
                   beta: jnp.ndarray, *, g0: float, r_out: int
                   ) -> jnp.ndarray:
    dp = x_q.astype(jnp.int32) @ w_q.astype(jnp.int32)
    mid = 2.0 ** (r_out - 1)
    code = jnp.floor(mid + gamma[None, :] * g0 * dp.astype(jnp.float32)
                     + beta[None, :])
    return jnp.clip(code, 0.0, 2.0 ** r_out - 1.0).astype(jnp.int32)
