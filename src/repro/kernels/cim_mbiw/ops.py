"""jit'd public wrappers around the cim_mbiw Pallas kernel.

Handles everything the kernel does not: plane decomposition of unsigned
inputs (bit-serial at 1-2b, nibble-serial at 3-8b), padding to MXU-aligned
blocks, the macro's K<=1152 row-tiling with per-tile ADC conversion, and
dequantization back to real units (mirroring core/cim_layers).

Precision dispatch
------------------
`KernelPrecision` names one of the macro's operating points (r_in, r_w,
r_out); `kernel_variant` returns a jit-compiled kernel specialized to that
point (plane walk + accumulator shift from r_in, ADC epilogue from r_out)
and caches it, so a network executes through a small table of compiled
variants instead of re-tracing per layer.  `kernel_variant_for_tile`
additionally keys the cache on the dispatched tile geometry — block sizes
clamped to one (rows, k, n) macro tile — so the smaller per-device tiles
of a sharded schedule do not pad up to full-macro blocks.  The runtime
engine (repro/runtime/engine.py) is the intended caller.

Units: inputs/weights are integer codes (unsigned < 2^r_in / odd ints in
+/-(2^r_w - 1)); outputs are int32 ADC codes in [0, 2^r_out) — or raw
int32 dp (integer dot-product units) with `fuse_adc=False`; gamma/beta are
the per-channel ABN gain (unitless) and offset (ADC code units); `g0` is
the unity-gain code gain in codes per dp unit.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax.numpy as jnp

from repro.core import digital_ref
from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO
from repro.kernels.cim_mbiw.kernel import cim_mbiw_matmul_planes, plane_layout

_PLANE_SHIFT = 4  # legacy nibble-plane default (r_in > 7 inputs)

SUPPORTED_R_IN = (1, 2, 3, 4, 5, 6, 7, 8)
SUPPORTED_R_W = (1, 2, 3, 4)
SUPPORTED_R_OUT = (1, 2, 3, 4, 5, 6, 7, 8)


@dataclasses.dataclass(frozen=True)
class KernelPrecision:
    """One (r_in, r_w, r_out) operating point of the macro."""
    r_in: int = 8
    r_w: int = 4
    r_out: int = 8

    def __post_init__(self):
        if self.r_in not in SUPPORTED_R_IN:
            raise ValueError(f"r_in={self.r_in} not in {SUPPORTED_R_IN}")
        if self.r_w not in SUPPORTED_R_W:
            raise ValueError(f"r_w={self.r_w} not in {SUPPORTED_R_W}")
        if self.r_out not in SUPPORTED_R_OUT:
            raise ValueError(f"r_out={self.r_out} not in {SUPPORTED_R_OUT}")

    @property
    def plane_shift(self) -> int:
        """Bits per input plane of the serial walk (1 bit-serial at
        r_in <= 2, 4 nibble-serial above)."""
        return plane_layout(self.r_in)[0]

    @property
    def n_planes(self) -> int:
        """Number of input planes the kernel walks (ceil(r_in/shift))."""
        return plane_layout(self.r_in)[1]


def _pad_to(x: jnp.ndarray, mult: Tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, (-s) % m) for s, m in zip(x.shape, mult)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def split_planes(x_q: jnp.ndarray, r_in: int,
                 plane_shift: Optional[int] = None
                 ) -> Tuple[jnp.ndarray, int]:
    """Unsigned ints < 2^r_in -> plane-major int8 layout (M, P*K).

    With `plane_shift=None` (legacy): a single plane whenever the values fit
    in int8 (r_in <= 7), nibble planes above.  With an explicit shift the
    decomposition is ceil(r_in / shift) planes of `shift` bits each — the
    precision-specialized walk of `KernelPrecision`.
    """
    x = x_q.astype(jnp.int32)
    if plane_shift is None:
        if r_in <= 7:
            return x.astype(jnp.int8), 1
        plane_shift = _PLANE_SHIFT
    n_planes = -(-r_in // plane_shift)
    if n_planes == 1:
        return x.astype(jnp.int8), 1
    mask = 2**plane_shift - 1
    planes = [((x >> (plane_shift * p)) & mask).astype(jnp.int8)
              for p in range(n_planes)]
    return jnp.concatenate(planes, axis=-1), n_planes


def kernel_variant(prec: KernelPrecision, bm: int = 256, bn: int = 256,
                   bk: int = 512, interpret: bool = True,
                   fuse_adc: bool = True) -> Callable:
    """Precision-specialized kernel callable (cached per operating point).

    Returned fn: (x_q (M,K) uint<2^r_in, w_q (K,N) odd ints, gamma (N,),
    beta (N,), g0) -> (M,N) int32 ADC codes.  Shapes need not be padded.
    With `fuse_adc=False` the fn returns the raw int32 dp instead (gamma/
    beta/g0 ignored): the noise-injected engine epilogue owns the ADC.

    The cache is keyed on what the compiled kernel actually depends on —
    the (plane_shift, n_planes) input walk and the r_out epilogue — so
    operating points differing only in r_w (weights arrive pre-decoded)
    or sharing a plane layout (e.g. r_in 5-8) reuse one variant.
    """
    shift, n_planes = plane_layout(prec.r_in)
    return _kernel_variant(shift, n_planes, prec.r_out, bm, bn, bk,
                           interpret, fuse_adc)


def _clamp_block(pref: int, dim: int, align: int = 8) -> int:
    """Largest useful block for `dim`: `pref` capped at dim rounded up to
    `align` (Pallas blocks must tile the padded array)."""
    return max(align, min(pref, -(-dim // align) * align))


# preferred block-size palette the schedule autotuner (repro.tuner) searches;
# every entry is clamped per tile, so the palette over-covers small tiles
# harmlessly (duplicates collapse after clamping)
BM_PALETTE = (32, 64, 128, 256)
BN_PALETTE = (32, 64, 128, 256)
BK_PALETTE = (128, 256, 512, 1024)


def block_candidates(rows: int, k: int, n: int,
                     bms: Tuple[int, ...] = BM_PALETTE,
                     bns: Tuple[int, ...] = BN_PALETTE,
                     bks: Tuple[int, ...] = BK_PALETTE
                     ) -> Tuple[Tuple[int, int, int], ...]:
    """Deduplicated legal (bm, bn, bk) block choices for one dispatched
    tile of GEMM shape (rows, k) x (k, n).

    Each palette entry is clamped to the tile geometry exactly like
    `kernel_variant_for_tile` clamps its preferred blocks, so every
    returned choice names a real compiled variant — and because the kernel
    is numerically identical at any block size (exact int32 accumulation +
    elementwise epilogue), choosing among them can never change a bit.
    The schedule autotuner enumerates this set per layer."""
    out: list = []
    seen = set()
    for bm in bms:
        for bn in bns:
            for bk in bks:
                c = (_clamp_block(bm, rows), _clamp_block(bn, n),
                     _clamp_block(bk, k))
                if c not in seen:
                    seen.add(c)
                    out.append(c)
    return tuple(out)


def kernel_variant_for_tile(prec: KernelPrecision, rows: int, k: int, n: int,
                            *, bm: int = 256, bn: int = 256, bk: int = 512,
                            interpret: bool = True,
                            fuse_adc: bool = True) -> Callable:
    """Kernel variant fitted to one dispatched tile's geometry.

    Args:
      prec: the (r_in, r_w, r_out) operating point.
      rows, k, n: the tile's GEMM shape — stream-chunk rows x row-tile K x
        col-tile N.  Under a sharded schedule these are the *per-device*
        extents, so each device compiles blocks sized to its own tile
        instead of padding to the full-macro defaults.
      bm, bn, bk: preferred (maximum) block sizes; clamped per dimension.
    Returns:
      The cached callable of `kernel_variant` at the clamped block sizes —
      numerically identical at any block size (exact int32 accumulation +
      elementwise epilogue), so geometry clamping never changes a bit.
    """
    return kernel_variant(prec, bm=_clamp_block(bm, rows),
                          bn=_clamp_block(bn, n), bk=_clamp_block(bk, k),
                          interpret=interpret, fuse_adc=fuse_adc)


@functools.lru_cache(maxsize=None)
def _kernel_variant(shift: int, n_planes: int, r_out: int, bm: int, bn: int,
                    bk: int, interpret: bool, fuse_adc: bool) -> Callable:
    r_eff = shift * n_planes          # widest r_in with this plane layout

    def run(x_q, w_q, gamma, beta, g0: float):
        return cim_matmul(x_q, w_q, gamma, beta, r_in=r_eff, r_out=r_out,
                          g0=g0, plane_shift=shift, bm=bm, bn=bn, bk=bk,
                          interpret=interpret, fuse_adc=fuse_adc)
    run.plane_shift = shift
    run.n_planes = n_planes
    run.r_out = r_out
    run.fuse_adc = fuse_adc
    return run


def cim_matmul(x_q: jnp.ndarray, w_q: jnp.ndarray, gamma: jnp.ndarray,
               beta: jnp.ndarray, *, r_in: int, r_out: int, g0: float,
               plane_shift: Optional[int] = None,
               bm: int = 256, bn: int = 256, bk: int = 512,
               interpret: bool = True, fuse_adc: bool = True) -> jnp.ndarray:
    """One macro row-tile (K <= n_rows recommended): int inputs -> ADC codes.

    x_q: (M, K) unsigned ints < 2^r_in; w_q: (K, N) odd ints; gamma (N,);
    beta (N,) — or (M, N) for a per-GEMM-row offset (segment-wise
    activation quantization folds per-row zero-points into beta).
    Returns (M, N) int32 codes (raw int32 dp when `fuse_adc=False`).
    """
    m, k_dim = x_q.shape
    _, n = w_q.shape
    x_planes, n_planes = split_planes(x_q, r_in, plane_shift)
    shift = _PLANE_SHIFT if plane_shift is None else plane_shift

    # pad: K to bk multiple (per-plane), M to bm, N to bn.  Padding K with
    # zero inputs/weights adds 0 to the dp — same trick the macro uses when
    # a layer does not fill its 36-row units.
    k_pad = (-k_dim) % bk
    if k_pad:
        xp = x_planes.reshape(m, n_planes, k_dim)
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, k_pad)))
        x_planes = xp.reshape(m, n_planes * (k_dim + k_pad))
        w_q = jnp.pad(w_q, ((0, k_pad), (0, 0)))
    x_planes = _pad_to(x_planes, (bm, 1))
    w_q = _pad_to(w_q.astype(jnp.int8), (1, bn))
    gamma2 = _pad_to(gamma.reshape(1, -1).astype(jnp.float32), (1, bn))
    if beta.ndim == 2 and beta.shape[0] == m and m != 1:
        # per-row offset: pad rows in lockstep with x (pad rows discarded)
        beta2 = _pad_to(beta.astype(jnp.float32), (bm, bn))
    else:
        beta2 = _pad_to(beta.reshape(1, -1).astype(jnp.float32), (1, bn))

    codes = cim_mbiw_matmul_planes(
        x_planes, w_q, gamma2, beta2, plane_shift=shift, g0=g0,
        r_out=r_out, bm=bm, bn=bn, bk=bk, interpret=interpret,
        fuse_adc=fuse_adc)
    return codes[:m, :n]


def cim_linear(x_q: jnp.ndarray, w_q: jnp.ndarray, gamma: jnp.ndarray,
               beta: jnp.ndarray, *, r_in: int, r_w: int, r_out: int,
               cfg: CIMMacroConfig = DEFAULT_MACRO, adaptive_swing: bool = True,
               interpret: bool = True) -> jnp.ndarray:
    """Full layer: row-tiled kernel calls with per-tile ADC, digital
    partial-sum recombination in dp units (host side, like the chip).

    Returns (M, N) float32 dp_hat (caller applies act/weight scales)."""
    m, k_dim = x_q.shape
    n = w_q.shape[1]
    n_rows = cfg.n_rows
    if adaptive_swing:
        rows = min(k_dim, n_rows)
        units = cfg.units_for_rows(rows)
    else:
        units = cfg.n_units
    n_dp = units * cfg.rows_per_unit
    g0 = digital_ref.adc_gain_factor(r_in, r_w, r_out, n_dp,
                                     cfg.swing_efficiency(units),
                                     cfg.alpha_adc())
    mid = 2.0 ** (r_out - 1)
    row_tiles = -(-k_dim // n_rows)
    dp_hat = jnp.zeros((m, n), jnp.float32)
    for t in range(row_tiles):
        ks, ke = t * n_rows, min((t + 1) * n_rows, k_dim)
        codes = cim_matmul(x_q[:, ks:ke], w_q[ks:ke], gamma, beta,
                           r_in=r_in, r_out=r_out, g0=g0, interpret=interpret)
        dp_hat += (codes.astype(jnp.float32) + 0.5 - mid - beta[None, :]) \
            / (gamma[None, :] * g0)
    return dp_hat
