"""jit'd public wrappers around the cim_mbiw Pallas kernel.

Handles everything the kernel does not: nibble-plane decomposition of
unsigned inputs, padding to MXU-aligned blocks, the macro's K<=1152
row-tiling with per-tile ADC conversion, and dequantization back to real
units (mirroring core/cim_layers._fakequant_forward).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import digital_ref
from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO
from repro.kernels.cim_mbiw.kernel import cim_mbiw_matmul_planes

_PLANE_SHIFT = 4  # nibble planes


def _pad_to(x: jnp.ndarray, mult: Tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, (-s) % m) for s, m in zip(x.shape, mult)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def split_planes(x_q: jnp.ndarray, r_in: int) -> Tuple[jnp.ndarray, int]:
    """Unsigned ints < 2^r_in -> plane-major int8 layout (M, P*K)."""
    x = x_q.astype(jnp.int32)
    if r_in <= 7:
        return x.astype(jnp.int8), 1
    n_planes = -(-r_in // _PLANE_SHIFT)
    planes = [((x >> (_PLANE_SHIFT * p)) & (2**_PLANE_SHIFT - 1)).astype(jnp.int8)
              for p in range(n_planes)]
    return jnp.concatenate(planes, axis=-1), n_planes


def cim_matmul(x_q: jnp.ndarray, w_q: jnp.ndarray, gamma: jnp.ndarray,
               beta: jnp.ndarray, *, r_in: int, r_out: int, g0: float,
               bm: int = 256, bn: int = 256, bk: int = 512,
               interpret: bool = True) -> jnp.ndarray:
    """One macro row-tile (K <= n_rows recommended): int inputs -> ADC codes.

    x_q: (M, K) unsigned ints < 2^r_in; w_q: (K, N) odd ints; gamma/beta (N,).
    Returns (M, N) int32 codes.
    """
    m, k_dim = x_q.shape
    _, n = w_q.shape
    x_planes, n_planes = split_planes(x_q, r_in)

    # pad: K to bk multiple (per-plane), M to bm, N to bn.  Padding K with
    # zero inputs/weights adds 0 to the dp — same trick the macro uses when
    # a layer does not fill its 36-row units.
    k_pad = (-k_dim) % bk
    if k_pad:
        xp = x_planes.reshape(m, n_planes, k_dim)
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, k_pad)))
        x_planes = xp.reshape(m, n_planes * (k_dim + k_pad))
        w_q = jnp.pad(w_q, ((0, k_pad), (0, 0)))
    x_planes = _pad_to(x_planes, (bm, 1))
    w_q = _pad_to(w_q.astype(jnp.int8), (1, bn))
    gamma2 = _pad_to(gamma.reshape(1, -1).astype(jnp.float32), (1, bn))
    beta2 = _pad_to(beta.reshape(1, -1).astype(jnp.float32), (1, bn))

    codes = cim_mbiw_matmul_planes(
        x_planes, w_q, gamma2, beta2, plane_shift=_PLANE_SHIFT, g0=g0,
        r_out=r_out, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return codes[:m, :n]


def cim_linear(x_q: jnp.ndarray, w_q: jnp.ndarray, gamma: jnp.ndarray,
               beta: jnp.ndarray, *, r_in: int, r_w: int, r_out: int,
               cfg: CIMMacroConfig = DEFAULT_MACRO, adaptive_swing: bool = True,
               interpret: bool = True) -> jnp.ndarray:
    """Full layer: row-tiled kernel calls with per-tile ADC, digital
    partial-sum recombination in dp units (host side, like the chip).

    Returns (M, N) float32 dp_hat (caller applies act/weight scales)."""
    m, k_dim = x_q.shape
    n = w_q.shape[1]
    n_rows = cfg.n_rows
    if adaptive_swing:
        rows = min(k_dim, n_rows)
        units = cfg.units_for_rows(rows)
    else:
        units = cfg.n_units
    n_dp = units * cfg.rows_per_unit
    g0 = digital_ref.adc_gain_factor(r_in, r_w, r_out, n_dp,
                                     cfg.swing_efficiency(units),
                                     cfg.alpha_adc())
    mid = 2.0 ** (r_out - 1)
    row_tiles = -(-k_dim // n_rows)
    dp_hat = jnp.zeros((m, n), jnp.float32)
    for t in range(row_tiles):
        ks, ke = t * n_rows, min((t + 1) * n_rows, k_dim)
        codes = cim_matmul(x_q[:, ks:ke], w_q[ks:ke], gamma, beta,
                           r_in=r_in, r_out=r_out, g0=g0, interpret=interpret)
        dp_hat += (codes.astype(jnp.float32) + 0.5 - mid - beta[None, :]) \
            / (gamma[None, :] * g0)
    return dp_hat
