"""Pallas TPU kernel for the CIM-MBIW quantized matmul with fused DSCI-ADC.

TPU adaptation of the macro's analog pipeline (DESIGN.md §3):
  * the DP array's charge accumulation    ->  int8 x int8 MXU matmul with an
    int32 VMEM accumulator (exact; the charge domain is linear, so is this);
  * the MBIW *input-serial* accumulation  ->  input planes walked by the K
    grid dimension, each plane's partial dp scaled by 2^(plane_shift*plane)
    into the same accumulator — the kernel literally performs the paper's
    input-serial, weight-parallel accumulation.  The plane granularity is
    the precision lever (paper Fig. 22): bit-serial (plane_shift=1) at
    r_in <= 2 where the macro runs its fastest/most-efficient modes,
    nibble-serial (plane_shift=4) at r_in >= 3 where the MXU makes 4b
    groups free and serialising to single bits would only waste it;
  * the DSCI-ADC with in-conversion ABN   ->  per-output-channel gamma/beta
    + floor + clip epilogue applied in VMEM before writeback, so the
    paper's "no post-ADC rescaling pass" maps to "no second pass over the
    output in HBM".

Grid: (M/bm, N/bn, P*K/bk) with the plane-major K axis innermost, so the
accumulator tile stays resident in VMEM across all planes and K blocks
(weight-stationary within a tile, like the macro).  The weight BlockSpec
re-reads the same w tile for every plane: w traffic is P-times redundant in
exchange for zero extra accumulator state — the right trade at P<=2.

VMEM at the default bm=bn=256, bk=512: x 128 KiB + w 128 KiB + acc 256 KiB
+ out 256 KiB < 1 MiB << 128 MiB VMEM; all dims MXU-aligned (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.jax_compat import tpu_compiler_params


def plane_layout(r_in: int) -> tuple[int, int]:
    """(plane_shift, n_planes) of the input-serial walk at a given r_in.

    Bit-serial below 3b (the macro's high-throughput binary modes),
    nibble-serial at 3-8b.  Weights stay *parallel* at every r_w — the
    MBIW combines weight bits spatially across adjacent columns, so the
    kernel sees them as pre-decoded odd integers.
    """
    if not 1 <= r_in <= 8:
        raise ValueError(f"r_in={r_in} outside the macro's 1-8b range")
    shift = 1 if r_in <= 2 else 4
    return shift, -(-r_in // shift)


def _cim_mbiw_kernel(x_ref, w_ref, gamma_ref, beta_ref, o_ref, acc_ref, *,
                     n_k_total: int, n_k_inner: int, plane_shift: int,
                     g0: float, r_out: int, fuse_adc: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    plane = k // n_k_inner
    scale = (jnp.int32(1) << (plane_shift * plane)).astype(jnp.int32)
    part = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc_ref[...] += scale * part

    @pl.when(k == n_k_total - 1)
    def _epilogue():
        if not fuse_adc:
            # raw-dp mode: the caller owns the ADC conversion (the engine's
            # noise epilogue injects pre-floor terms it cannot fuse here)
            o_ref[...] = acc_ref[...]
            return
        dp = acc_ref[...].astype(jnp.float32)
        gamma = gamma_ref[...].astype(jnp.float32)      # (1, bn)
        beta = beta_ref[...].astype(jnp.float32)        # (1, bn) or (bm, bn)
        mid = 2.0 ** (r_out - 1)
        # Pin both float intermediates of the floor argument: XLA may
        # FMA-contract `gain*dp + (mid+beta)` in some fusion contexts (e.g.
        # inside a scan body) but not others, flipping codes where the
        # product needs rounding.  ref.py computes the identical barriered
        # chain — the float-op lockstep contract.
        gain = jax.lax.optimization_barrier(gamma * g0)
        t = jax.lax.optimization_barrier(gain * dp)
        code = jnp.floor(mid + t + beta)
        o_ref[...] = jnp.clip(code, 0.0, 2.0 ** r_out - 1.0
                              ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "plane_shift", "g0", "r_out", "bm", "bn", "bk", "interpret", "fuse_adc"))
def cim_mbiw_matmul_planes(x_planes: jnp.ndarray, w_q: jnp.ndarray,
                           gamma: jnp.ndarray, beta: jnp.ndarray, *,
                           plane_shift: int, g0: float, r_out: int,
                           bm: int = 256, bn: int = 256, bk: int = 512,
                           interpret: bool = True,
                           fuse_adc: bool = True) -> jnp.ndarray:
    """CIM matmul over input planes; shapes pre-padded to block multiples.

    x_planes : (M, P*K) int8 — P nibble planes laid out plane-major along
               the last axis; plane p carries bits [p*plane_shift, ...).
    w_q      : (K, N) int8 odd weights (+/-(2^r_w - 1))
    gamma    : (1, N) float32 ABN gain
    beta     : (1, N) float32 ABN offset in ADC codes — or (M, N) for a
               *per-GEMM-row* offset (segment-wise activation quantization
               folds a per-row zero-point into beta; the epilogue
               broadcasts either shape identically per element)
    returns  : (M, N) int32 ADC codes in [0, 2^r_out - 1], or the raw int32
               dp accumulator when `fuse_adc=False` (the noise-injected
               engine applies its own ADC epilogue after the kernel)
    """
    m, pk = x_planes.shape
    k_dim, n = w_q.shape
    assert pk % k_dim == 0, (pk, k_dim)
    n_planes = pk // k_dim
    assert m % bm == 0 and n % bn == 0 and k_dim % bk == 0, (m, n, k_dim)
    assert beta.shape in ((1, n), (m, n)), (beta.shape, m, n)
    n_k_inner = k_dim // bk
    n_k_total = n_planes * n_k_inner

    beta_spec = (pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
                 if beta.shape[0] == m and m != 1 else
                 pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
    kernel = functools.partial(
        _cim_mbiw_kernel, n_k_total=n_k_total, n_k_inner=n_k_inner,
        plane_shift=plane_shift, g0=g0, r_out=r_out, fuse_adc=fuse_adc)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k_total),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k % n_k_inner, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            beta_spec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x_planes, w_q, gamma, beta)
