"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool, window: int = 0,
                  sk_valid: int = 0) -> jnp.ndarray:
    """q (B,H,Sq,D), k/v (B,G,Sk,D); returns (B,H,Sq,D)."""
    b, h, sq, d = q.shape
    g, sk = k.shape[1], k.shape[2]
    rep = h // g
    sk_valid = sk_valid or sk
    qf = q.astype(jnp.float32) / (d ** 0.5)
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    keep = k_pos < sk_valid
    if causal:
        keep &= q_pos >= k_pos
    if window > 0:
        keep &= (q_pos - k_pos) < window
    s = jnp.where(keep[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
