"""Wrapper: (B,S,H,D)-layout entry, padding, full flash custom VJP, and the
shard_map context-parallel entry used under the production mesh.

Forward AND backward run as Pallas kernels (online-softmax fwd emitting the
row logsumexp; Dao-style bwd recomputing p from (q,k,lse)), so attention
never materializes an S^2 buffer in HBM in either direction.

Distribution (DESIGN.md §5): under a mesh the kernel runs inside shard_map
with q sequence-sharded over "model" (context parallelism — head counts of
the assigned archs are not uniformly divisible by 16) and k/v replicated
over "model" (one all-gather per layer).  Each shard passes its global
q-position offset into the kernel for causal/window masking; dk/dv
cotangents are psum'd automatically by shard_map's transpose of the
replicated k/v inputs.

All kernel calls are wrapped in jax.named_scope("vmem_kernel"): the dry-run
HLO analyzer uses the marker to account only the BlockSpec block streaming
as HBM traffic (launch/hlo_analysis.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.sharding import PartitionSpec as P

from repro.jax_compat import get_abstract_mesh, shard_map
from repro.kernels.flash_attn.kernel import (flash_attention_bhsd,
                                             flash_attention_bwd_bhsd)
from repro.kernels.flash_attn.ref import attention_ref

_FLOAT0 = jax.dtypes.float0


def _pad_axis(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _block_sizes(sq, sk, bq, bk):
    bq = min(bq, max(64, sq))
    bk = min(bk, max(64, sk))
    return bq, bk


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash_core(q, k, v, q_off, causal: bool, window: int,
                bq: int, bk: int):
    out, _ = _fwd_impl(q, k, v, q_off, causal, window, bq, bk)
    return out


def _prep(q, k, v, bq, bk):
    qt = _pad_axis(jnp.swapaxes(q, 1, 2), 2, bq)       # (B,H,Sq',D)
    kt = _pad_axis(jnp.swapaxes(k, 1, 2), 2, bk)
    vt = _pad_axis(jnp.swapaxes(v, 1, 2), 2, bk)
    return qt, kt, vt


def _fwd_impl(q, k, v, q_off, causal, window, bq, bk):
    b, sq, h, d = q.shape
    sk, g = k.shape[1], k.shape[2]
    rep = h // g
    qt, kt, vt = _prep(q, k, v, bq, bk)
    with jax.named_scope("vmem_kernel"):
        out, lse = flash_attention_bhsd(
            qt, kt, vt, q_off, causal=causal, window=window, sk_valid=sk,
            rep=rep, bq=bq, bk=bk)
    return jnp.swapaxes(out[:, :, :sq], 1, 2), lse


def _fwd(q, k, v, q_off, causal, window, bq, bk):
    out, lse = _fwd_impl(q, k, v, q_off, causal, window, bq, bk)
    return out, (q, k, v, q_off, out, lse)


def _bwd(causal, window, bq, bk, res, g_out):
    q, k, v, q_off, out, lse = res
    b, sq, h, d = q.shape
    sk, g = k.shape[1], k.shape[2]
    rep = h // g
    qt, kt, vt = _prep(q, k, v, bq, bk)
    dot = _pad_axis(jnp.swapaxes(g_out, 1, 2), 2, bq)
    # delta_i = rowsum(dO * O)  (cheap, O(S*D))
    delta = jnp.sum(jnp.swapaxes(g_out, 1, 2).astype(jnp.float32)
                    * jnp.swapaxes(out, 1, 2).astype(jnp.float32),
                    axis=-1, keepdims=True)
    delta = _pad_axis(delta, 2, bq)
    with jax.named_scope("vmem_kernel"):
        dq, dk_h, dv_h = flash_attention_bwd_bhsd(
            qt, kt, vt, dot, lse, delta, q_off, causal=causal, window=window,
            sk_valid=sk, rep=rep, bq=bq, bk=bk)
    dq = jnp.swapaxes(dq[:, :, :sq], 1, 2).astype(q.dtype)
    # reduce per-q-head dk/dv over each kv group's rep heads
    dk_h = dk_h[:, :, :sk].reshape(b, g, rep, sk, d).sum(axis=2)
    dv_h = dv_h[:, :, :sk].reshape(b, g, rep, sk, d).sum(axis=2)
    dk = jnp.swapaxes(dk_h, 1, 2).astype(k.dtype)
    dv = jnp.swapaxes(dv_h, 1, 2).astype(v.dtype)
    d_off = np.zeros((1, 1), _FLOAT0)      # int input -> float0 cotangent
    return dq, dk, dv, d_off


_flash_core.defvjp(_fwd, _bwd)


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512,
                    q_offset: Optional[jnp.ndarray] = None):
    """q (B, Sq, H, D); k/v (B, Sk, G, D).  Returns (B, Sq, H, D)."""
    bq, bk = _block_sizes(q.shape[1], k.shape[1], bq, bk)
    if q_offset is None:
        q_offset = jnp.zeros((1, 1), jnp.int32)
    return _flash_core(q, k, v, q_offset, causal, window, bq, bk)


def flash_attention_sharded(q, k, v, causal: bool = True, window: int = 0,
                            bq: int = 512, bk: int = 512):
    """Context-parallel entry: q seq-sharded over "model", k/v replicated
    over "model", batch over ("pod","data").  Falls back to the plain call
    when the ambient mesh is empty or does not divide the shapes."""
    mesh = get_abstract_mesh()
    b, sq = q.shape[0], q.shape[1]
    if mesh.empty:
        return flash_attention(q, k, v, causal, window, bq, bk)
    names = set(mesh.axis_names)
    ba = tuple(a for a in ("pod", "data") if a in names)
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    if b % max(n_b, 1):
        ba = ()
        n_b = 1
    tp = "model" if "model" in names else None
    n_tp = mesh.shape[tp] if tp else 1
    if tp is None or sq % n_tp or (sq // n_tp) < 128:
        tp = None
        n_tp = 1

    q_spec = P(ba if ba else None, tp, None, None)
    kv_spec = P(ba if ba else None, None, None, None)

    def body(q_l, k_l, v_l):
        if tp is not None:
            idx = jax.lax.axis_index(tp).astype(jnp.int32)
            off = (idx * (sq // n_tp)).reshape(1, 1)
        else:
            off = jnp.zeros((1, 1), jnp.int32)
        bq_l, bk_l = _block_sizes(q_l.shape[1], k_l.shape[1], bq, bk)
        return _flash_core(q_l, k_l, v_l, off, causal, window, bq_l, bk_l)

    return shard_map(body, mesh=mesh,
                     in_specs=(q_spec, kv_spec, kv_spec),
                     out_specs=q_spec, check_vma=False)(q, k, v)


# ---------------------------------------------------------------------------
# ring-buffer decode attention (the CIMDecodeLM serving step)
# ---------------------------------------------------------------------------
#
# Decode-time attention over per-row ring-buffer KV state is a different
# shape class from the prefill kernel above: one query per row, each row
# attending only to its OWN (L, H, hd) ring, with ring-slot validity
# expressed as a precomputed additive bias (slots the row has not written
# yet sit out of positional order, so the index-generated causal/window
# masks of `flash_attention` cannot describe them).  The whole working set
# is tiny (R <= slot capacity, L = KV window), so the kernel holds it in
# one VMEM-resident block — no online softmax, no KV grid — and performs
# literally the op sequence of the digital reference, which keeps it
# bit-exact with `ring_decode_attention_ref` (tests/test_scheduler.py
# asserts equality, not closeness).


@jax.jit
def ring_decode_attention_ref(q, k, v, bias) -> jnp.ndarray:
    """Pure-jnp digital oracle of ring-buffer decode attention.

    q (R, H, hd); k/v (R, L, H, hd) — each row's own KV ring; bias (R, L)
    additive scores mask (0 for valid ring slots, -1e9 for unwritten).
    Returns (R, H, hd).  The op sequence is exactly the digital path
    CIMDecodeLM computed inline before the kernel existed; oracle and
    kernel are both jitted as one unit so their graphs fuse identically
    and the bit-exactness contract is equality, not closeness."""
    hd = q.shape[-1]
    scores = jnp.einsum("rhd,rlhd->rhl", q, k) / np.sqrt(hd)
    probs = jax.nn.softmax(scores + bias[:, None, :], axis=-1)
    return jnp.einsum("rhl,rlhd->rhd", probs, v)


def _ring_decode_kernel(q_ref, k_ref, v_ref, b_ref, o_ref, *,
                        scale: float):
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    bias = b_ref[...]
    scores = jnp.einsum("rhd,rlhd->rhl", q, k) / scale
    probs = jax.nn.softmax(scores + bias[:, None, :], axis=-1)
    o_ref[...] = jnp.einsum("rhl,rlhd->rhd", probs, v)


@jax.jit
def ring_decode_attention(q, k, v, bias) -> jnp.ndarray:
    """Pallas ring-buffer decode attention (bit-exact with
    `ring_decode_attention_ref`).

    Same shapes as the ref: q (R, H, hd), k/v (R, L, H, hd), bias (R, L).
    One pallas_call over the whole (VMEM-resident) decode working set;
    the kernel body is the identical einsum/softmax/einsum sequence, so
    interpretation executes the same graph and the outputs match the
    digital path bit for bit."""
    scale = float(np.sqrt(q.shape[-1]))
    with jax.named_scope("vmem_kernel"):
        return pl.pallas_call(
            functools.partial(_ring_decode_kernel, scale=scale),
            out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
            interpret=True,
        )(q, k, v, bias)
