"""Pallas TPU flash attention (beyond-paper optimization, EXPERIMENTS §Perf).

The dry-run roofline shows every training/prefill cell is memory-bound on
materialized S^2 score buffers.  This kernel keeps the whole
softmax(QK^T/sqrt(d))V inner loop VMEM-resident: HBM traffic collapses from
O(S^2 * H) to the BlockSpec-declared O(S * D * H) of q/k/v/out.

Grid: (B, H, Sq/bq, Sk/bk) with the KV axis innermost ("arbitrary"), online
softmax running in VMEM scratch (acc/m/l) across KV steps.  GQA is handled
by the k/v index_map (kv head = q head // rep).  Causal and sliding-window
masks are generated from program_ids — no mask operand traffic.

VMEM at bq=bk=512, D=128: q 128 KiB + k/v 256 KiB + scores 1 MiB (f32)
+ acc 256 KiB  << 128 MiB, MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.jax_compat import tpu_compiler_params

NEG_INF = -1e30


def _mask(i, j, bq, bk, causal, window, sk_valid, q_off=0):
    q_pos = q_off + i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    keep = k_pos < sk_valid
    if causal:
        keep &= q_pos >= k_pos
    if window > 0:
        keep &= (q_pos - k_pos) < window
    return keep


def _flash_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref,
                  m_ref, l_ref, *, n_kv: int, bq: int, bk: int, causal: bool,
                  window: int, sk_valid: int, scale: float):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block
    q_off = off_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    s = jnp.where(_mask(i, j, bq, bk, causal, window, sk_valid, q_off),
                  s, NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                             # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                     # (bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == n_kv - 1)
    def _finish():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(l_safe)).astype(lse_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "sk_valid", "rep", "bq", "bk", "interpret"))
def flash_attention_bhsd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         q_off: jnp.ndarray = None, *,
                         causal: bool, window: int = 0, sk_valid: int = 0,
                         rep: int = 1, bq: int = 512, bk: int = 512,
                         interpret: bool = True) -> jnp.ndarray:
    """q (B, H, Sq, D); k/v (B, G, Sk, D) with H = G * rep; pre-padded to
    block multiples.  sk_valid masks KV padding (0 -> all valid).
    q_off: (1,1) int32 — global position of q row 0 (context parallelism:
    each sequence shard passes its own offset)."""
    if q_off is None:
        q_off = jnp.zeros((1, 1), jnp.int32)
    b, h, sq, d = q.shape
    _, g, sk, _ = k.shape
    assert h == g * rep, (h, g, rep)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    n_kv = sk // bk
    sk_valid = sk_valid or sk
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_kernel, n_kv=n_kv, bq=bq, bk=bk, causal=causal,
        window=window, sk_valid=sk_valid, scale=scale)
    grid = (b, h, sq // bq, n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h_, i, j: (0, 0)),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, i, j, rep=rep: (b_, h_ // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, i, j, rep=rep: (b_, h_ // rep, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b_, h_, i, j: (b_, h_, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
                   jax.ShapeDtypeStruct((b, h, sq, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q_off, q, k, v)


# ---------------------------------------------------------------------------
# backward kernels (flash bwd, Dao 2022 alg. 2 adapted to TPU grids)
# ---------------------------------------------------------------------------

def _flash_bwd_dq_kernel(off_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                         dl_ref, dq_ref, acc_ref, *, n_kv: int, bq: int,
                         bk: int, causal: bool, window: int, sk_valid: int,
                         scale: float):
    i = pl.program_id(2)
    j = pl.program_id(3)
    q_off = off_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)            # (bq, 1)
    delta = dl_ref[0, 0].astype(jnp.float32)           # (bq, 1)

    s = scale * jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    keep = _mask(i, j, bq, bk, causal, window, sk_valid, q_off)
    p = jnp.where(keep, jnp.exp(s - lse), 0.0)         # (bq, bk)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta) * scale
    acc_ref[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == n_kv - 1)
    def _finish():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(off_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                          dl_ref, dk_ref, dv_ref, dk_acc, dv_acc, *,
                          n_q: int, bq: int, bk: int, causal: bool,
                          window: int, sk_valid: int, scale: float):
    j = pl.program_id(2)          # kv block
    i = pl.program_id(3)          # q block (innermost)
    q_off = off_ref[0, 0]

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)
    delta = dl_ref[0, 0].astype(jnp.float32)

    s = scale * jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    keep = _mask(i, j, bq, bk, causal, window, sk_valid, q_off)
    p = jnp.where(keep, jnp.exp(s - lse), 0.0)         # (bq, bk)
    dv_acc[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta) * scale                      # (bq, bk)
    dk_acc[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(i == n_q - 1)
    def _finish():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "sk_valid", "rep", "bq", "bk", "interpret"))
def flash_attention_bwd_bhsd(q, k, v, do, lse, delta, q_off=None, *,
                             causal: bool, window: int = 0, sk_valid: int = 0,
                             rep: int = 1, bq: int = 512, bk: int = 512,
                             interpret: bool = True):
    if q_off is None:
        q_off = jnp.zeros((1, 1), jnp.int32)
    """Backward: q/do (B,H,Sq,D), k/v (B,G,Sk,D), lse/delta (B,H,Sq,1).
    Returns (dq (B,H,Sq,D), dk/dv per q-head (B,H,Sk,D) — caller reduces
    over the rep q-heads of each kv group)."""
    b, h, sq, d = q.shape
    _, g, sk, _ = k.shape
    n_kv, n_q = sk // bk, sq // bq
    sk_valid = sk_valid or sk
    scale = 1.0 / (d ** 0.5)

    off_spec = pl.BlockSpec((1, 1), lambda b_, h_, i, j: (0, 0))
    q_spec = pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0))
    kv_spec = pl.BlockSpec((1, 1, bk, d),
                           lambda b_, h_, i, j, rep=rep: (b_, h_ // rep, j, 0))
    stat_spec = pl.BlockSpec((1, 1, bq, 1), lambda b_, h_, i, j: (b_, h_, i, 0))

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, n_kv=n_kv, bq=bq, bk=bk,
                          causal=causal, window=window, sk_valid=sk_valid,
                          scale=scale),
        grid=(b, h, n_q, n_kv),
        in_specs=[off_spec, q_spec, kv_spec, kv_spec, q_spec, stat_spec,
                  stat_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q_off, q, k, v, do, lse, delta)

    # dk/dv: grid transposed, q innermost; outputs per q-head
    q_spec2 = pl.BlockSpec((1, 1, bq, d), lambda b_, h_, j, i: (b_, h_, i, 0))
    kv_spec2 = pl.BlockSpec((1, 1, bk, d),
                            lambda b_, h_, j, i, rep=rep: (b_, h_ // rep, j, 0))
    kvh_spec2 = pl.BlockSpec((1, 1, bk, d), lambda b_, h_, j, i: (b_, h_, j, 0))
    stat_spec2 = pl.BlockSpec((1, 1, bq, 1),
                              lambda b_, h_, j, i: (b_, h_, i, 0))
    off_spec2 = pl.BlockSpec((1, 1), lambda b_, h_, j, i: (0, 0))
    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, n_q=n_q, bq=bq, bk=bk,
                          causal=causal, window=window, sk_valid=sk_valid,
                          scale=scale),
        grid=(b, h, n_kv, n_q),
        in_specs=[off_spec2, q_spec2, kv_spec2, kv_spec2, q_spec2, stat_spec2,
                  stat_spec2],
        out_specs=[kvh_spec2, kvh_spec2],
        out_shape=[jax.ShapeDtypeStruct((b, h, sk, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, h, sk, d), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q_off, q, k, v, do, lse, delta)
    return dq, dk, dv
