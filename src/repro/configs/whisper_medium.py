"""whisper-medium  [arXiv:2212.04356].  Enc-dec; conv frontend stubbed.

24L (enc) + 24L (dec) d_model=1024 16H d_ff=4096 vocab=51865.  input_specs()
provides precomputed mel-frame embeddings (B, T, d_model) per the assignment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, encoder_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    max_target_len=448,
    norm_type="layernorm", mlp_act="gelu", gated_mlp=False,
    rope_theta=1e4,
    source="arXiv:2212.04356 (unverified)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, encoder_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
                          max_target_len=32, remat=False)
