"""internvl2-76b  [arXiv:2404.16821].  InternViT frontend (stub) + InternLM2.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  The vision
frontend is a stub per the assignment: input_specs() provides precomputed
patch embeddings (vision_tokens x d_model) prepended to the text sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256,
    vision_tokens=256,
    norm_type="rmsnorm", mlp_act="silu", gated_mlp=True,
    rope_theta=1e6,
    source="arXiv:2404.16821 (unverified)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=512, vision_tokens=8,
                          remat=False)
