"""recurrentgemma-2b  [arXiv:2402.19427].  RG-LRU + local attn, 1:2 pattern.

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256000,
    head_dim=256,
    attn_every=3, local_window=2048, lru_width=2560,
    norm_type="rmsnorm", mlp_act="gelu", gated_mlp=True,
    rope_theta=1e4,
    tie_embeddings=True,
    source="arXiv:2402.19427",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=5, d_model=64, n_heads=2, n_kv_heads=1,
                          head_dim=32, d_ff=128, vocab_size=512,
                          local_window=16, lru_width=64, remat=False)
