"""phi3.5-moe-42b-a6.6b  [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab_size=32064,
    moe_experts=16, moe_top_k=2,
    norm_type="layernorm", mlp_act="silu", gated_mlp=True,
    rope_theta=1e4,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=96, vocab_size=256, moe_experts=4, remat=False)
