"""qwen2-7b  [arXiv:2407.10671].  GQA kv=4, QKV bias.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True,
    norm_type="rmsnorm", mlp_act="silu", gated_mlp=True,
    rope_theta=1e6,
    source="arXiv:2407.10671",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=56, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=512, remat=False)
