"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig, shape_applicable

ARCH_IDS = [
    "phi35_moe",
    "mixtral_8x22b",
    "minitron_4b",
    "qwen2_7b",
    "olmo_1b",
    "granite_8b",
    "recurrentgemma_2b",
    "internvl2_76b",
    "mamba2_1_3b",
    "whisper_medium",
]

_ALIASES = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "mixtral-8x22b": "mixtral_8x22b",
    "minitron-4b": "minitron_4b",
    "qwen2-7b": "qwen2_7b",
    "olmo-1b": "olmo_1b",
    "granite-8b": "granite_8b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "internvl2-76b": "internvl2_76b",
    "mamba2-1.3b": "mamba2_1_3b",
    "whisper-medium": "whisper_medium",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = _ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod_name = _ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config()


def all_archs() -> List[str]:
    return list(ARCH_IDS)
