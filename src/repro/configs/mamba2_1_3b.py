"""mamba2-1.3b  [arXiv:2405.21060].  SSD (state-space duality), attn-free.

48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    norm_type="rmsnorm",
    source="arXiv:2405.21060 (unverified)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, vocab_size=512,
                          ssm_state=16, ssm_headdim=16, ssm_chunk=16,
                          remat=False)
