"""olmo-1b  [arXiv:2402.00838].  Non-parametric LayerNorm, untied heads=kv.

16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=50304,
    norm_type="nonparam_ln", mlp_act="silu", gated_mlp=True,
    rope_theta=1e4,
    tie_embeddings=True,              # OLMo-1B ties the LM head
    source="arXiv:2402.00838",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab_size=512, remat=False)
