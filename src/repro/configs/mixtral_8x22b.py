"""mixtral-8x22b  [arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2, SWA.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=32768,
    moe_experts=8, moe_top_k=2,
    sliding_window=4096,              # SWA per the assignment
    norm_type="rmsnorm", mlp_act="silu", gated_mlp=True,
    rope_theta=1e6,
    source="arXiv:2401.04088",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=256, moe_experts=4,
                          sliding_window=16, remat=False)
