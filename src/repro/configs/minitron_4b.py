"""minitron-4b (pruned nemotron)  [arXiv:2407.14679].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab_size=256000,
    head_dim=128,                     # nemotron uses 128-dim heads
    norm_type="rmsnorm", mlp_act="relu2", gated_mlp=False,  # squared-relu MLP
    rope_theta=1e4,
    source="arXiv:2407.14679",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=192, vocab_size=512, remat=False)
