"""granite-8b (code)  [arXiv:2405.04324].  Llama-arch.

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=49152,
    norm_type="rmsnorm", mlp_act="silu", gated_mlp=True,
    rope_theta=1e4,
    source="arXiv:2405.04324",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=512, remat=False)
