"""Model/config schema shared by all architectures and the launcher."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.cim_layers import CIMConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # attention flavour
    qkv_bias: bool = False
    norm_type: str = "rmsnorm"    # rmsnorm | layernorm | nonparam_ln
    sliding_window: int = 0       # SWA (mixtral); 0 = full attention
    rope_theta: float = 1e6
    mlp_act: str = "silu"
    gated_mlp: bool = True
    tie_embeddings: bool = False
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 2
    moe_capacity_factor: float = 1.25
    # hybrid (recurrentgemma / griffin)
    attn_every: int = 0           # every k-th layer is local attention
    local_window: int = 2048
    lru_width: int = 0
    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # enc-dec (whisper)
    encoder_layers: int = 0
    max_target_len: int = 448
    # vlm
    vision_tokens: int = 0        # prefix patch embeddings (stub frontend)
    # execution
    cim: CIMConfig = CIMConfig(mode="bypass")
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"    # full | dots (save dot outputs in bwd)
    attn_impl: str = "jnp"        # jnp | pallas (fused flash kernels)
    # source provenance (paper/hf tag from the assignment)
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs with at least one sub-quadratic decode path run long_500k
SUBQUADRATIC = {"mixtral-8x22b", "recurrentgemma-2b", "mamba2-1.3b"}


def shape_applicable(arch: str, shape: str, family: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "pure full-attention arch: 500k decode skipped (DESIGN.md §4)"
    return True, ""
