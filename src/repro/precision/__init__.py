"""Workload-adaptive precision serving: the profile -> plan -> ladder ->
per-request dispatch pipeline (docs/ARCHITECTURE.md §11).

The paper's 0.15-8 POPS/W range is a *precision* axis — this package
turns the repo's full r_in x r_w grid from a test matrix into a serving
feature.  Three layers:

* `sensitivity` — offline per-layer precision/noise sensitivity
  calibration (Monte-Carlo quality deltas vs. the 8b-class reference),
  persisted in a versioned on-disk profile cache;
* `planner` — greedy accuracy-budget assignment of per-layer precisions
  and compilation of the named operating-point ladder (`quality` /
  `balanced` / `throughput`) through the global program cache;
* per-request selection lives in `runtime/scheduler.py`: requests carry
  an operating-point tag, and the in-flight scheduler fuses only
  same-point requests per decode step.
"""
from repro.precision.sensitivity import (BASE_POINT, CALIBRATION_RUNS,
                                         PRECISION_CHAIN, LayerSensitivity,
                                         ProfileCache, ProfileCacheWarning,
                                         SensitivityProfile, calibrate,
                                         default_profile_path, profile_key)
from repro.precision.planner import (DEFAULT_BUDGETS, OperatingPoint,
                                     PrecisionLadder, assign, plan_ladder)

__all__ = [
    "BASE_POINT", "CALIBRATION_RUNS", "PRECISION_CHAIN",
    "LayerSensitivity", "ProfileCache", "ProfileCacheWarning",
    "SensitivityProfile", "calibrate", "default_profile_path",
    "profile_key", "DEFAULT_BUDGETS", "OperatingPoint", "PrecisionLadder",
    "assign", "plan_ladder",
]
