"""Offline per-layer precision/noise sensitivity calibration (adaptive
precision serving, layer 1 of 3 — see docs/ARCHITECTURE.md §11).

The paper's headline is *workload-adaptive* 1-to-8b operation: peak
efficiency scales 0.15-8 POPS/W with computing precision.  Exploiting
that per layer needs to know, for every layer, how much output quality is
lost by dropping that layer to each (r_in, r_w) point.  This module
measures exactly that: hold every other layer at the 8b-class base point,
drop one layer to one grid point, and record the quality delta of the
final outputs vs. the all-base reference — logit MSE and top-1 agreement,
averaged over Monte-Carlo noise trials (`CIMInferenceEngine.monte_carlo`)
when the config models noise, or a single clean run otherwise.

Profiles persist in a versioned on-disk JSON cache with the exact
degradation contract of `tuner/cache.py`: schema-versioned file, atomic
tmp+rename writes, and corrupt/stale state degrading to a fresh
calibration with one `ProfileCacheWarning` — never an error.

Two network shapes are supported transparently:

* **chained** specs (layer i's n == layer i+1's k): one program end to
  end; the quality delta is measured at the final logits.
* **independent** specs (e.g. a decode block's qkv/o/gate_up/down
  projections, which never chain): each layer is its own single-layer
  program with its own input, and the delta is measured at that layer's
  output.  This is the mode the serving ladder for `CIMDecodeLM` uses.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import mapping
from repro.runtime import engine as rt
from repro.runtime.program import compile_program

SCHEMA_VERSION = 1

# statuses ProfileCache.get can report for a key
HIT, MISS, INVALID = "hit", "miss", "invalid"

# the canonical monotone precision chain, cheapest to most precise; the
# planner upgrades layers along this order, so it must be sorted by
# bit-serial cost (r_in * r_w phases).  The last entry is the base point.
PRECISION_CHAIN: Tuple[Tuple[int, int], ...] = (
    (1, 1), (2, 1), (2, 2), (4, 2), (4, 4), (8, 4))

BASE_POINT: Tuple[int, int] = (8, 4)

# calibration sweeps actually executed (cache-hit observability, the
# search.SEARCH_COUNT pattern)
CALIBRATION_RUNS = {"n": 0}


class ProfileCacheWarning(UserWarning):
    """A profile cache file or entry was unusable; calibration re-ran."""


def default_profile_path() -> str:
    """The profile cache location: $REPRO_PRECISION_PROFILES or
    ~/.cache/repro-cim/sensitivity.json."""
    env = os.environ.get("REPRO_PRECISION_PROFILES")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-cim",
                        "sensitivity.json")


def profile_key(specs: Sequence[mapping.LayerSpec], cfg: rt.EngineConfig,
                points: Sequence[Tuple[int, int]], n_trials: int,
                batch: int, seed: int, label: str = "") -> str:
    """The string key one calibration run is stored under.

    Encodes everything the measured deltas depend on: per-layer tile
    geometry and reference precision, the swept points, trial count,
    batch extent, PRNG seed, whether noise was modeled, and the device
    count.  Distinct *numeric* noise operating points at one geometry
    should distinguish themselves via `label`."""
    devices = (cfg.sharding.resolve_devices()
               if cfg.sharding is not None else 1)
    geo = "+".join(
        f"m{s.m}k{s.k}n{s.n}r{s.r_in}x{s.r_w}x{s.r_out}"
        + ("conv" if s.conv is not None else "dense") for s in specs)
    pts = "-".join(f"{a}x{b}" for a, b in points)
    return (f"{label}|{geo}|p{pts}|t{int(n_trials)}|b{int(batch)}"
            f"|s{int(seed)}|nz{int(cfg.noise.enabled)}|d{int(devices)}")


@dataclasses.dataclass(frozen=True)
class LayerSensitivity:
    """One layer's measured quality deltas across the precision grid.

    `entries` holds one (r_in, r_w, logit_mse, top1_agreement) tuple per
    swept point: the MSE of the network outputs (and the fraction of
    rows whose argmax agrees) vs. the all-base reference when only this
    layer runs at (r_in, r_w)."""
    index: int
    entries: Tuple[Tuple[int, int, float, float], ...]

    def delta(self, point: Tuple[int, int]) -> float:
        """Logit MSE vs. the base reference at one (r_in, r_w) point."""
        for ri, rw, mse, _ in self.entries:
            if (ri, rw) == tuple(point):
                return mse
        raise ValueError(f"layer {self.index} was not calibrated at "
                         f"{tuple(point)}")

    def agreement(self, point: Tuple[int, int]) -> float:
        """Top-1 agreement fraction vs. the base reference at one point."""
        for ri, rw, _, agree in self.entries:
            if (ri, rw) == tuple(point):
                return agree
        raise ValueError(f"layer {self.index} was not calibrated at "
                         f"{tuple(point)}")


@dataclasses.dataclass(frozen=True)
class SensitivityProfile:
    """A network's full per-layer precision sensitivity table.

    `points` is the swept chain in planner (cheapest-first) order with
    the base point last; `layers[i]` holds layer i's deltas.  `n_trials`
    records the Monte-Carlo trial count (1 for a clean, noise-free
    calibration); `chained` records whether the deltas were measured at
    the final logits of one chained program or per-layer on independent
    programs."""
    base: Tuple[int, int]
    points: Tuple[Tuple[int, int], ...]
    n_trials: int
    chained: bool
    layers: Tuple[LayerSensitivity, ...]

    def delta(self, layer: int, point: Tuple[int, int]) -> float:
        """Layer `layer`'s logit-MSE delta at one (r_in, r_w) point."""
        return self.layers[layer].delta(point)

    def agreement(self, layer: int, point: Tuple[int, int]) -> float:
        """Layer `layer`'s top-1 agreement at one (r_in, r_w) point."""
        return self.layers[layer].agreement(point)

    def max_total_delta(self) -> float:
        """The worst-case additive delta: every layer at the cheapest
        point.  Budget fractions are expressed against this scale."""
        return float(sum(l.delta(self.points[0]) for l in self.layers))

    def to_dict(self) -> dict:
        """JSON-serializable form (the profile-cache entry payload)."""
        return {
            "base": list(self.base),
            "points": [list(p) for p in self.points],
            "n_trials": int(self.n_trials),
            "chained": bool(self.chained),
            "layers": [{"index": l.index,
                        "entries": [list(e) for e in l.entries]}
                       for l in self.layers],
        }

    @classmethod
    def from_dict(cls, raw: dict) -> "SensitivityProfile":
        """Inverse of to_dict (raises KeyError/TypeError on bad shape —
        the cache validates before calling this)."""
        return cls(
            base=tuple(int(v) for v in raw["base"]),
            points=tuple(tuple(int(v) for v in p) for p in raw["points"]),
            n_trials=int(raw["n_trials"]),
            chained=bool(raw["chained"]),
            layers=tuple(
                LayerSensitivity(
                    index=int(l["index"]),
                    entries=tuple(
                        (int(e[0]), int(e[1]), float(e[2]), float(e[3]))
                        for e in l["entries"]))
                for l in raw["layers"]))


def _valid_entry(entry) -> bool:
    if not isinstance(entry, dict):
        return False
    try:
        prof = SensitivityProfile.from_dict(entry)
    except (KeyError, TypeError, ValueError, IndexError):
        return False
    return bool(prof.layers) and all(l.entries for l in prof.layers)


class ProfileCache:
    """One sensitivity-profile cache file (the TuneCache contract).

    `degraded` is True when the file was corrupt or schema-mismatched:
    the cache then answers INVALID for every key and refuses writes, so a
    bad file can neither crash calibration nor grow.  `stats` counts
    hits/misses/invalid lookups."""

    def __init__(self, path: str, entries: Optional[Dict] = None,
                 degraded: bool = False):
        self.path = path
        self.entries: Dict[str, dict] = dict(entries or {})
        self.degraded = degraded
        self.stats = {"hits": 0, "misses": 0, "invalid": 0, "writes": 0}

    @classmethod
    def load(cls, path: str) -> "ProfileCache":
        """Read the cache file; unreadable/corrupt/stale state warns once
        and returns a degraded cache instead of raising."""
        if not os.path.exists(path):
            return cls(path)
        try:
            with open(path, encoding="utf-8") as fh:
                raw = json.load(fh)
        except (OSError, ValueError) as e:
            warnings.warn(
                f"sensitivity profile cache {path} is unreadable ({e}); "
                "re-calibrating", ProfileCacheWarning, stacklevel=2)
            return cls(path, degraded=True)
        if not isinstance(raw, dict) or raw.get("schema") != SCHEMA_VERSION:
            warnings.warn(
                f"sensitivity profile cache {path} has schema "
                f"{raw.get('schema') if isinstance(raw, dict) else '?'} "
                f"(expected {SCHEMA_VERSION}); re-calibrating",
                ProfileCacheWarning, stacklevel=2)
            return cls(path, degraded=True)
        entries = raw.get("entries")
        if not isinstance(entries, dict):
            warnings.warn(
                f"sensitivity profile cache {path} has no entries table; "
                "re-calibrating", ProfileCacheWarning, stacklevel=2)
            return cls(path, degraded=True)
        return cls(path, entries=entries)

    def get(self, key: str) -> Tuple[str, Optional[SensitivityProfile]]:
        """Look one key up: (HIT, profile), (MISS, None) — calibrate and
        store — or (INVALID, None) — warn and calibrate fresh."""
        if self.degraded:
            self.stats["invalid"] += 1
            return INVALID, None
        entry = self.entries.get(key)
        if entry is None:
            self.stats["misses"] += 1
            return MISS, None
        if not _valid_entry(entry):
            self.stats["invalid"] += 1
            warnings.warn(
                f"sensitivity profile entry {key!r} in {self.path} is "
                "invalid; re-calibrating", ProfileCacheWarning,
                stacklevel=2)
            return INVALID, None
        self.stats["hits"] += 1
        return HIT, SensitivityProfile.from_dict(entry)

    def put(self, key: str, profile: SensitivityProfile) -> None:
        """Record one calibrated profile (no-op on a degraded cache)."""
        if self.degraded:
            return
        self.entries[key] = profile.to_dict()
        self.stats["writes"] += 1

    def save(self) -> None:
        """Atomically persist the entries (tmp + rename); degraded caches
        never write.  Directory creation is implicit."""
        if self.degraded:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as fh:
            json.dump({"schema": SCHEMA_VERSION, "entries": self.entries},
                      fh, indent=1, sort_keys=True)
        os.replace(tmp, self.path)


def _is_chain(specs: Sequence[mapping.LayerSpec]) -> bool:
    if any(s.conv is not None for s in specs):
        return True                       # conv nets only plan chained
    return all(specs[i + 1].k == specs[i].n
               for i in range(len(specs) - 1))


def _input_for(spec: mapping.LayerSpec, batch: int,
               key: jax.Array) -> jnp.ndarray:
    if spec.conv is not None:
        shape = (batch,) + spec.conv.spatial_in
    else:
        shape = (batch, spec.k)
    return jax.nn.relu(jax.random.normal(key, shape, jnp.float32)) + 0.1


def _trials(prog, params, x, key: jax.Array, n: int,
            noisy: bool) -> jnp.ndarray:
    if not noisy:
        return prog.run(params, x)[None]
    keys = jax.random.split(key, n)
    return jnp.stack([prog.run(params, x, k) for k in keys])


def _metrics(var: jnp.ndarray, ref: jnp.ndarray) -> Tuple[float, float]:
    mse = float(jnp.mean((var - ref) ** 2))
    agree = float(jnp.mean(
        (jnp.argmax(var, axis=-1) == jnp.argmax(ref, axis=-1))
        .astype(jnp.float32)))
    return mse, agree


def calibrate(specs: Sequence[mapping.LayerSpec],
              cfg: rt.EngineConfig = rt.EngineConfig(), *,
              points: Sequence[Tuple[int, int]] = PRECISION_CHAIN,
              base: Tuple[int, int] = BASE_POINT,
              n_trials: int = 4, batch: int = 8, seed: int = 0,
              activations: Optional[Sequence[str]] = None,
              pools: Optional[Sequence[int]] = None,
              cache_path: Optional[str] = None,
              label: str = "") -> SensitivityProfile:
    """Measure (or fetch from the profile cache) a network's per-layer
    precision sensitivity.

    For each layer i and each point p in `points`: run the network with
    every layer at `base` except layer i at p, and record the logit MSE
    and top-1 agreement vs. the all-base reference.  One fp32 parameter
    set (initialized from the base program) is shared across every
    variant, so the deltas isolate quantization/noise, not weights.
    Under a noise-enabled cfg each measurement averages `n_trials`
    seeded Monte-Carlo trials (monte_carlo semantics — same trial keys
    for variant and reference); clean configs run once.

    Args:
      specs: the network's LayerSpecs.  A chained list (k_{i+1} == n_i)
        calibrates end-to-end at the final logits; non-chaining specs
        (e.g. decode-block projections) calibrate per layer on
        independent single-layer programs.
      cfg: shared EngineConfig (noise model, sharding, macro).
      points: the swept (r_in, r_w) chain, cheapest first; `base` is
        appended if absent.
      base: the reference precision every non-dropped layer runs at.
      n_trials: Monte-Carlo trials per measurement (noise configs only).
      batch: calibration batch extent.
      seed: PRNG seed for params, inputs and noise trials (part of the
        cache key — same seed, same profile).
      activations/pools: per-layer epilogues for chained networks
        (plan_network defaults).
      cache_path: profile cache file; None uses default_profile_path(),
        "" disables persistence for this call.
      label: free-form cache-key prefix (distinguish numeric noise
        operating points at one geometry).
    Returns:
      The calibrated (or cached) SensitivityProfile.
    """
    specs = tuple(specs)
    if not specs:
        raise ValueError("calibrate needs at least one LayerSpec")
    base = (int(base[0]), int(base[1]))
    points = tuple((int(a), int(b)) for a, b in points)
    if base not in points:
        points = points + (base,)
    key_str = profile_key(specs, cfg, points, n_trials, batch, seed, label)
    cache = None
    if cache_path != "":
        cache = ProfileCache.load(
            default_profile_path() if cache_path is None else cache_path)
        status, prof = cache.get(key_str)
        if status == HIT:
            return prof
    CALIBRATION_RUNS["n"] += 1
    noisy = cfg.noise.enabled
    trials = int(n_trials) if noisy else 1
    if trials < 1:
        raise ValueError(f"n_trials must be >= 1, got {n_trials}")
    key = jax.random.PRNGKey(seed)
    mc_key = jax.random.fold_in(key, 2)
    chained = _is_chain(specs)
    layers = []
    if chained:
        base_specs = tuple(
            dataclasses.replace(s, r_in=base[0], r_w=base[1])
            for s in specs)
        ref_prog = compile_program(base_specs, cfg,
                                   activations=activations, pools=pools)
        params = list(ref_prog.init_params(jax.random.fold_in(key, 0)))
        x = _input_for(specs[0], batch, jax.random.fold_in(key, 1))
        ref = _trials(ref_prog, params, x, mc_key, trials, noisy)
        for i in range(len(specs)):
            entries = []
            for p in points:
                var_specs = (base_specs[:i]
                             + (dataclasses.replace(
                                 base_specs[i], r_in=p[0], r_w=p[1]),)
                             + base_specs[i + 1:])
                prog = compile_program(var_specs, cfg,
                                       activations=activations,
                                       pools=pools)
                out = _trials(prog, params, x, mc_key, trials, noisy)
                mse, agree = _metrics(out, ref)
                entries.append((p[0], p[1], mse, agree))
            layers.append(LayerSensitivity(index=i,
                                           entries=tuple(entries)))
    else:
        for i, spec in enumerate(specs):
            base_spec = dataclasses.replace(spec, r_in=base[0],
                                            r_w=base[1])
            ref_prog = compile_program((base_spec,), cfg)
            params = list(ref_prog.init_params(
                jax.random.fold_in(key, 10 + i)))
            x = _input_for(spec, batch, jax.random.fold_in(key, 50 + i))
            ref = _trials(ref_prog, params, x, mc_key, trials, noisy)
            entries = []
            for p in points:
                prog = compile_program(
                    (dataclasses.replace(base_spec, r_in=p[0],
                                         r_w=p[1]),), cfg)
                out = _trials(prog, params, x, mc_key, trials, noisy)
                mse, agree = _metrics(out, ref)
                entries.append((p[0], p[1], mse, agree))
            layers.append(LayerSensitivity(index=i,
                                           entries=tuple(entries)))
    prof = SensitivityProfile(base=base, points=points, n_trials=trials,
                              chained=chained, layers=tuple(layers))
    if cache is not None:
        cache.put(key_str, prof)
        cache.save()
    return prof
