"""Accuracy-budget precision planner (adaptive precision serving, layer 2
of 3 — see docs/ARCHITECTURE.md §11).

Given a `SensitivityProfile` and a quality budget, assign each layer an
(r_in, r_w) point along the monotone `PRECISION_CHAIN` so the predicted
total quality delta stays within budget while the cheapest (fastest,
highest-POPS/W) points carry as many layers as possible.

The assignment is greedy with a budget-independent upgrade trajectory:
every layer starts at the cheapest point, and upgrades (layer -> next
chain rung) are applied in decreasing delta-reduction-per-extra-cost
order until the predicted delta fits the allowance.  Because the
trajectory itself never depends on the allowance — only the stopping
prefix does — assignments are *nested*: a stricter budget's assignment
dominates a looser budget's per layer (the monotonicity property the
precision-smoke CI job pins).  Budgets are fractions of the profile's
worst-case delta (`max_total_delta`), so one budget dict works across
networks.

`plan_ladder` compiles each named budget into a `PrecisionLadder` of
`CIMProgram`s through the global keyed program cache — two ladders over
equal specs share plans and executables exactly like `BatchBuckets`
rungs — and attaches each operating point's perfmodel-projected time and
TOPS/W so `schedule_report`/fig22 can echo what was actually served.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import mapping
from repro.precision.sensitivity import SensitivityProfile
from repro.runtime import engine as rt
from repro.runtime.program import (DEFAULT_BUCKETS, BatchBuckets, CIMProgram,
                                   compile_program)

# quality-budget fractions of the profile's worst-case delta; insertion
# order is strictest first (the ladder report lists them in this order)
DEFAULT_BUDGETS: Dict[str, float] = {
    "quality": 0.02, "balanced": 0.2, "throughput": 0.6}


def _chain_cost(spec: mapping.LayerSpec, point: Tuple[int, int]) -> float:
    # bit-serial macro-eval proxy: r_in DP phases x r_w weight planes over
    # the layer's k x n cells — orders greedy upgrades; absolute time and
    # energy come from the compiled program's perf report afterwards
    return float(point[0] * point[1] * spec.m * spec.k * spec.n)


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One named rung of the precision ladder.

    `assignment[i]` is layer i's planned (r_in, r_w); `allowance` is the
    absolute logit-MSE budget the greedy assignment was stopped at
    (fraction x profile.max_total_delta) and `predicted_delta` the
    profile-additive delta of the final assignment (<= allowance unless
    even the all-base assignment exceeds it).  `predicted_time_s` /
    `predicted_tops_per_w` are perfmodel projections of the compiled
    programs."""
    name: str
    fraction: float
    allowance: float
    assignment: Tuple[Tuple[int, int], ...]
    predicted_delta: float
    predicted_time_s: float = 0.0
    predicted_tops_per_w: float = 0.0


def assign(profile: SensitivityProfile,
           specs: Sequence[mapping.LayerSpec],
           fraction: float) -> Tuple[Tuple[Tuple[int, int], ...], float]:
    """Greedy budgeted per-layer precision assignment.

    Returns (assignment, predicted_delta): each layer's (r_in, r_w) along
    `profile.points` plus the additive profile delta of the result.  The
    upgrade trajectory is independent of `fraction` (only the stopping
    point moves), so assignments nest monotonically across budgets."""
    specs = tuple(specs)
    if len(specs) != len(profile.layers):
        raise ValueError(
            f"profile covers {len(profile.layers)} layers, specs has "
            f"{len(specs)}")
    if not 0.0 <= fraction:
        raise ValueError(f"budget fraction must be >= 0, got {fraction}")
    chain = profile.points
    top = len(chain) - 1
    idx = [0] * len(specs)
    deltas = [profile.delta(i, chain[0]) for i in range(len(specs))]
    total = sum(deltas)
    allowance = float(fraction) * profile.max_total_delta()
    while total > allowance and any(j < top for j in idx):
        best, best_ratio = -1, None
        for i in range(len(specs)):
            if idx[i] >= top:
                continue
            nxt = chain[idx[i] + 1]
            gain = deltas[i] - profile.delta(i, nxt)
            cost = max(_chain_cost(specs[i], nxt)
                       - _chain_cost(specs[i], chain[idx[i]]), 1e-9)
            ratio = gain / cost
            if best_ratio is None or ratio > best_ratio:
                best, best_ratio = i, ratio
        idx[best] += 1
        new_d = profile.delta(best, chain[idx[best]])
        total += new_d - deltas[best]
        deltas[best] = new_d
    return tuple(chain[j] for j in idx), float(total)


@dataclasses.dataclass(frozen=True)
class PrecisionLadder:
    """A compiled ladder of named operating points over one network.

    `programs[name]` holds the point's compiled `CIMProgram`s — a single
    end-to-end program for chained specs, one single-layer program per
    layer for independent (non-chaining) specs.  All points share the
    global program cache, so equal (specs, cfg) rungs across ladders and
    across `BatchBuckets` reuse one plan each."""
    base_specs: Tuple[mapping.LayerSpec, ...]
    points: Tuple[OperatingPoint, ...]
    programs: Dict[str, Tuple[CIMProgram, ...]]
    chained: bool

    def names(self) -> Tuple[str, ...]:
        """The operating-point names, strictest budget first."""
        return tuple(op.name for op in self.points)

    def point(self, name: str) -> OperatingPoint:
        """The named OperatingPoint (ValueError on unknown names)."""
        for op in self.points:
            if op.name == name:
                return op
        raise ValueError(f"unknown operating point {name!r}; ladder has "
                         f"{list(self.names())}")

    def specs_for(self, name: str) -> Tuple[mapping.LayerSpec, ...]:
        """The per-layer LayerSpecs of one point (base specs re-tagged
        with the point's planned precisions)."""
        op = self.point(name)
        return tuple(
            dataclasses.replace(s, r_in=p[0], r_w=p[1])
            for s, p in zip(self.base_specs, op.assignment))

    def layer_programs(self, name: str) -> Tuple[CIMProgram, ...]:
        """The point's compiled programs (length 1 when chained)."""
        self.point(name)
        return self.programs[name]

    def program(self, name: str) -> CIMProgram:
        """The point's single chained program (ValueError for ladders
        over independent per-layer specs — use layer_programs)."""
        progs = self.layer_programs(name)
        if len(progs) != 1:
            raise ValueError(
                f"point {name!r} compiled {len(progs)} independent "
                "per-layer programs; use layer_programs()")
        return progs[0]

    def report(self) -> Dict[str, dict]:
        """Per-point summary for benchmarks/serving telemetry:
        {name: {assignment, allowance, predicted_delta, time_s,
        tops_per_w}}."""
        return {op.name: {
            "assignment": [list(p) for p in op.assignment],
            "allowance": op.allowance,
            "predicted_delta": op.predicted_delta,
            "time_s": op.predicted_time_s,
            "tops_per_w": op.predicted_tops_per_w,
        } for op in self.points}


def _point_perf(progs: Sequence[CIMProgram],
                name: str) -> Tuple[float, float]:
    total_s, total_j, ops_t = 0.0, 0.0, 0.0
    for prog in progs:
        tot = prog.perf_report(point=name)["total"]
        total_s += tot["time_s"]
        total_j += tot["energy_j"]
        ops_t += tot["tops"] * tot["time_s"]
    return total_s, (ops_t / total_j if total_j else 0.0)


def plan_ladder(profile: SensitivityProfile,
                specs: Sequence[mapping.LayerSpec],
                cfg: rt.EngineConfig = rt.EngineConfig(), *,
                budgets: Optional[Dict[str, float]] = None,
                activations: Optional[Sequence[str]] = None,
                pools: Optional[Sequence[int]] = None,
                buckets: BatchBuckets = DEFAULT_BUCKETS) -> PrecisionLadder:
    """Plan and compile the full operating-point ladder of a network.

    For each named budget fraction (DEFAULT_BUDGETS by default): run the
    greedy `assign`, compile the resulting per-layer-precision specs
    through the global program cache (chained specs compile one
    end-to-end program; independent specs one program per layer), and
    attach the point's perfmodel-projected time and TOPS/W.  Points are
    ordered strictest-budget-first in the returned ladder."""
    specs = tuple(specs)
    budgets = dict(DEFAULT_BUDGETS if budgets is None else budgets)
    if not budgets:
        raise ValueError("plan_ladder needs at least one named budget")
    ordered = sorted(budgets.items(), key=lambda kv: (kv[1], kv[0]))
    points: List[OperatingPoint] = []
    programs: Dict[str, Tuple[CIMProgram, ...]] = {}
    for name, fraction in ordered:
        assignment, delta = assign(profile, specs, fraction)
        point_specs = tuple(
            dataclasses.replace(s, r_in=p[0], r_w=p[1])
            for s, p in zip(specs, assignment))
        if profile.chained:
            progs = (compile_program(point_specs, cfg,
                                     activations=activations, pools=pools,
                                     buckets=buckets),)
        else:
            progs = tuple(compile_program((ps,), cfg, buckets=buckets)
                          for ps in point_specs)
        time_s, tops_per_w = _point_perf(progs, name)
        points.append(OperatingPoint(
            name=str(name), fraction=float(fraction),
            allowance=float(fraction) * profile.max_total_delta(),
            assignment=assignment, predicted_delta=delta,
            predicted_time_s=time_s, predicted_tops_per_w=tops_per_w))
        programs[str(name)] = progs
    return PrecisionLadder(base_specs=specs, points=tuple(points),
                           programs=programs, chained=profile.chained)
