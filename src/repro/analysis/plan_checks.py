"""Plan-validator pass (pass id ``plan``).

Re-derives the LayerSpec / ConvGeometry / macro-tiling invariants the
planner (`mapping.map_layer`, `engine.plan_network`) is supposed to
enforce and checks them against a finished `NetworkPlan`.  The planner
raises on most of these at construction time; the validator exists so a
plan that was built by hand, deserialized, or mutated by a refactor is
still provably inside the hardware envelope (1152x256 macro, the 1-8b /
{1,2,4}b precision grid) before it becomes a jit static argument.

Finding codes (all ERROR):

  * **PV001** — r_in outside 1..max_r_in (8);
  * **PV002** — r_w outside the power-of-two grid {1, 2, 4} or r_out
    outside 1..max_r_out;
  * **PV003** — row (K) tiles do not partition [0, k) contiguously;
  * **PV004** — a row tile exceeds the macro's 1152 physical rows;
  * **PV005** — a col tile exceeds the per-tile channel budget
    (n_blocks * cols_per_block / r_w columns);
  * **PV006** — conv geometry inconsistent with the GEMM view;
  * **PV007** — device shard does not cover the layer's tiles/rows;
  * **PV008** — the layer chain's feed-forward shapes do not compose.
"""
from __future__ import annotations

from typing import List

from repro.analysis.findings import Finding, Report, Severity

PASS_ID = "plan"


def _err(code: str, message: str, layer=None) -> Finding:
    return Finding(pass_id=PASS_ID, code=code, severity=Severity.ERROR,
                   message=message, layer=layer)


def check_layer(lp, macro, layer_index: int) -> List[Finding]:
    """Validate one LayerPlan against the macro envelope."""
    findings: List[Finding] = []
    spec = lp.spec
    i = layer_index
    if not 1 <= spec.r_in <= macro.max_r_in:
        findings.append(_err(
            "PV001", f"r_in={spec.r_in} outside the serial-input grid "
                     f"1..{macro.max_r_in}", i))
    if spec.r_w not in (1, 2, 4) or spec.r_w > macro.max_r_w:
        findings.append(_err(
            "PV002", f"r_w={spec.r_w} outside the weight-parallel grid "
                     f"{{1, 2, 4}} (max {macro.max_r_w})", i))
    if not 1 <= spec.r_out <= macro.max_r_out:
        findings.append(_err(
            "PV002", f"r_out={spec.r_out} outside 1..{macro.max_r_out}", i))
    # row (K) tiles: contiguous exact partition of [0, k), each within
    # the macro's physical rows
    pos = 0
    for start, size in lp.k_slices:
        if start != pos or size < 1:
            findings.append(_err(
                "PV003", f"row tiles do not partition [0, {spec.k}) "
                         f"contiguously: tile ({start}, {size}) at "
                         f"offset {pos}", i))
            break
        pos = start + size
    else:
        if pos != spec.k:
            findings.append(_err(
                "PV003", f"row tiles cover [0, {pos}) but the layer has "
                         f"k={spec.k}", i))
    for _, size in lp.k_slices:
        if size > macro.n_rows:
            findings.append(_err(
                "PV004", f"row tile of {size} rows exceeds the macro's "
                         f"{macro.n_rows} physical rows", i))
            break
    # col tiles: uniform, and within the per-tile channel budget
    ch_budget = macro.n_blocks * max(1, macro.cols_per_block // spec.r_w)
    sizes = {size for _, size in lp.n_slices}
    if len(sizes) != 1:
        findings.append(_err(
            "PV005", f"col tiles are not uniform: sizes {sorted(sizes)} "
                     "(uniformity is what keeps noise draws device-count "
                     "independent)", i))
    if lp.tile_n > ch_budget:
        findings.append(_err(
            "PV005", f"col tile of {lp.tile_n} channels exceeds the "
                     f"{ch_budget}-channel budget at r_w={spec.r_w} "
                     f"({macro.n_blocks} blocks x "
                     f"{max(1, macro.cols_per_block // spec.r_w)})", i))
    if lp.n_pad < spec.n:
        findings.append(_err(
            "PV005", f"col tiles cover {lp.n_pad} channels but the layer "
                     f"has n={spec.n}", i))
    # conv geometry vs the GEMM view
    g = spec.conv
    if g is not None:
        if spec.k != g.kh * g.kw * g.c_in or spec.n != g.c_out:
            findings.append(_err(
                "PV006", f"conv geometry {g.kh}x{g.kw}x{g.c_in}->"
                         f"{g.c_out} inconsistent with GEMM view "
                         f"k={spec.k} n={spec.n}", i))
        if spec.m != g.batch * g.out_h * g.out_w:
            findings.append(_err(
                "PV006", f"conv output map {g.batch}x{g.out_h}x{g.out_w} "
                         f"inconsistent with GEMM m={spec.m}", i))
        if lp.pool > 1 and (g.out_h % lp.pool or g.out_w % lp.pool):
            findings.append(_err(
                "PV006", f"pool {lp.pool} does not divide the conv output "
                         f"{g.out_h}x{g.out_w}", i))
    # device shard coverage
    sh = lp.shard
    if sh is not None:
        if sh.kind == "col":
            if sh.devices * sh.tiles_per_device < len(lp.n_slices):
                findings.append(_err(
                    "PV007", f"col shard covers {sh.devices}x"
                             f"{sh.tiles_per_device} tiles but the layer "
                             f"has {len(lp.n_slices)}", i))
        elif sh.kind == "rows":
            if sh.devices * sh.rows_per_device < spec.m:
                findings.append(_err(
                    "PV007", f"row shard covers {sh.devices}x"
                             f"{sh.rows_per_device} rows but the layer "
                             f"has m={spec.m}", i))
        else:
            findings.append(_err(
                "PV007", f"unknown shard kind {sh.kind!r}", i))
        if not 0.0 < sh.efficiency <= 1.0:
            findings.append(_err(
                "PV007", f"shard efficiency {sh.efficiency} outside "
                         "(0, 1]", i))
    return findings


def check_plan(plan) -> List[Finding]:
    """Validate a whole NetworkPlan: per-layer envelope + chain shapes."""
    findings: List[Finding] = []
    macro = plan.cfg.macro
    for i, lp in enumerate(plan.layers):
        findings.extend(check_layer(lp, macro, i))
    from repro.runtime import engine as rt
    try:
        rt._check_chain(plan.layers)
    except ValueError as e:
        findings.append(_err("PV008", str(e)))
    return findings


def run(plan) -> Report:
    """Run the plan validator; returns a Report."""
    report = Report()
    report.extend(check_plan(plan))
    return report
