"""Recompile-hazard pass (pass id ``recompile``).

`serve.py --assert-no-recompile` catches executable-cache misses at
runtime, after the damage; this pass bounds them at plan time.  Two
checks:

  * **RC001** — the statically-reachable executable-key set (the
    `BatchBuckets` ladder x every operand-presence flag combination the
    program's config allows) must be finite and within budget.  An
    uncapped ladder or a flag that multiplies the key space past the
    budget means steady-state serving keeps compiling.
  * **RC002** — key-function sensitivity: perturbing any single
    `EXEC_KEY_FIELDS` field must change the produced cache key.  A key
    function that drops a field (e.g. forgets ``segmented``) aliases two
    different trace signatures onto one cache entry — the cache reports a
    hit while jit silently retraces (the "weak cache key" bug
    `--assert-no-recompile` only sees in production).
"""
from __future__ import annotations

import itertools
from typing import Callable, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding, Report, Severity

PASS_ID = "recompile"

# a noise-enabled program reaches 24 flag combinations per ladder rung
# (noise x bound x reference x segmented x identity, key tied to noise);
# an 11-rung ladder (max_m=1024) is 264 keys per operating point, and a
# full precision ladder (base + quality/balanced/throughput) serves 4
# points = 1056 keys — budget leaves ~2x headroom over that
DEFAULT_KEY_BUDGET = 2048

# representative perturbation per EXEC_KEY_FIELDS field: (base, altered)
_FIELD_PROBES = {
    "kind": ("bucket", "exact"),
    "extent": (8, 16),
    "noise": (False, True),
    "keyed": (False, True),
    "devices": (1, 2),
    "bound": (False, True),
    "reference": (False, True),
    "segmented": (False, True),
    "identity": (False, True),
    "point": ("", "throughput"),
}

# the operating points a single-point program serves ("" = base); ladder
# checks pass the ladder's names explicitly
DEFAULT_POINTS = ("",)


def reachable_keys(buckets, max_m: int, *, devices: int,
                   noise_enabled: bool,
                   points: Sequence[str] = DEFAULT_POINTS) -> Set[tuple]:
    """Every executable key requests of extent 1..max_m can reach.

    Flag combinations follow the dispatch rules: a PRNG key travels with
    noise, identity ids only matter under noise, and bound/reference/
    segmented are free axes.  `points` enumerates the serving
    operating-point tags in play (the precision ladder multiplies the
    key set by its rung count; "" alone is the single-point default).
    """
    from repro.runtime.program import executable_key
    keys: Set[tuple] = set()
    noise_opts = (False, True) if noise_enabled else (False,)
    for m in buckets.ladder(max_m):
        for noise, bound, reference, segmented in itertools.product(
                noise_opts, (False, True), (False, True), (False, True)):
            id_opts = (False, True) if noise else (False,)
            for identity in id_opts:
                for point in points:
                    keys.add(executable_key(
                        "bucket", m, noise=noise, keyed=noise,
                        devices=devices, bound=bound, reference=reference,
                        segmented=segmented, identity=identity,
                        point=point))
    return keys


def check_key_budget(buckets, max_m: int, *, devices: int,
                     noise_enabled: bool,
                     budget: int = DEFAULT_KEY_BUDGET,
                     points: Sequence[str] = DEFAULT_POINTS
                     ) -> List[Finding]:
    """RC001: the reachable key set must be finite and within budget."""
    findings: List[Finding] = []
    ladder = buckets.ladder(max_m)
    if not ladder:
        findings.append(Finding(
            pass_id=PASS_ID, code="RC001", severity=Severity.ERROR,
            message=f"empty bucket ladder for max_m={max_m}; every request "
                    "extent would trace a fresh executable"))
        return findings
    # a sane ladder grows at most logarithmically (plus the cap grid)
    import math
    bound = int(math.log2(max(max_m, 1))) + 2
    if buckets.max_bucket:
        bound += -(-max_m // buckets.max_bucket)
    if len(ladder) > bound:
        findings.append(Finding(
            pass_id=PASS_ID, code="RC001", severity=Severity.ERROR,
            message=f"bucket ladder has {len(ladder)} rungs for "
                    f"max_m={max_m} (expected <= {bound}); the ladder is "
                    "not bounding the compile count"))
    n = len(reachable_keys(buckets, max_m, devices=devices,
                           noise_enabled=noise_enabled, points=points))
    if n > budget:
        findings.append(Finding(
            pass_id=PASS_ID, code="RC001", severity=Severity.ERROR,
            message=f"{n} statically-reachable executable keys exceed the "
                    f"budget of {budget}; steady-state serving would keep "
                    "compiling"))
    return findings


def check_key_sensitivity(key_fn: Optional[Callable] = None, *,
                          fields: Sequence[str] = ()) -> List[Finding]:
    """RC002: every key field must be discriminated by the key function.

    ``key_fn(kind, extent, **flags)`` defaults to the runtime's real
    `executable_key`; ``fields`` defaults to `EXEC_KEY_FIELDS`.
    """
    from repro.runtime import program as prog_mod
    if key_fn is None:
        key_fn = prog_mod.executable_key
    if not fields:
        fields = prog_mod.EXEC_KEY_FIELDS
    base_kw = {f: probes[0] for f, probes in _FIELD_PROBES.items()
               if f not in ("kind", "extent")}
    findings: List[Finding] = []

    def call(kind, extent, kw):
        return key_fn(kind, extent, **kw)

    base = call(_FIELD_PROBES["kind"][0], _FIELD_PROBES["extent"][0],
                base_kw)
    for field in fields:
        if field not in _FIELD_PROBES:
            findings.append(Finding(
                pass_id=PASS_ID, code="RC002", severity=Severity.ERROR,
                message=f"no perturbation probe for key field {field!r}; "
                        "extend recompile._FIELD_PROBES alongside "
                        "EXEC_KEY_FIELDS"))
            continue
        kind = (_FIELD_PROBES["kind"][1] if field == "kind"
                else _FIELD_PROBES["kind"][0])
        extent = (_FIELD_PROBES["extent"][1] if field == "extent"
                  else _FIELD_PROBES["extent"][0])
        kw = dict(base_kw)
        if field not in ("kind", "extent"):
            kw[field] = _FIELD_PROBES[field][1]
        if call(kind, extent, kw) == base:
            findings.append(Finding(
                pass_id=PASS_ID, code="RC002", severity=Severity.ERROR,
                message=f"executable cache key ignores the {field!r} "
                        "field: two different trace signatures alias one "
                        "cache entry and jit silently retraces"))
    return findings


def run(program, *, max_m: int = 1024,
        budget: int = DEFAULT_KEY_BUDGET,
        points: Sequence[str] = DEFAULT_POINTS) -> Report:
    """Run both recompile checks against a compiled `CIMProgram`.

    `points` lists the serving operating-point tags the program will be
    dispatched under (the precision ladder's names plus "" for the base
    point) — RC001 budgets the key set they multiply into."""
    report = Report()
    plan = program.plan
    devices = (plan.cfg.sharding.resolve_devices()
               if plan.cfg.sharding is not None else 1)
    report.extend(check_key_budget(
        program.buckets, max_m, devices=devices,
        noise_enabled=plan.cfg.noise.enabled, budget=budget,
        points=points))
    report.extend(check_key_sensitivity())
    return report
