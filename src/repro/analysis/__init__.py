"""cimcheck: plan-time static verification of compiled CIM programs.

The analysis package walks the jaxprs of a compiled `CIMProgram`'s
executables plus its plan-level metadata and reports contract violations
*before* they cost a production incident (see `docs/ARCHITECTURE.md` §9):

  * `barriers`    — numerics-barrier lint on rounding paths (NB0xx/NB1xx);
  * `noise_keys`  — fold_in-chain injectivity + noise-id range audit
    (NK0xx);
  * `recompile`   — executable-cache key budget and sensitivity (RC0xx);
  * `plan_checks` — LayerSpec/ConvGeometry/macro-envelope invariants
    (PV0xx).

Entry points: `check_program` (one Report over every pass),
`verify_program` (raise/warn per mode — what
``compile_program(..., verify=...)`` calls), `check_all_cached_programs`
(sweep the global program cache, e.g. after serving warmup), and
`lint_callable` (barrier-lint any traceable function).  The
`scripts/cimcheck.py` CLI sweeps the model zoo across the precision grid
and emits the findings as JSON.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

from repro.analysis import barriers, noise_keys, plan_checks, recompile
from repro.analysis.findings import (CimcheckError, Finding, Report,
                                     Severity, Suppression,
                                     parse_suppressions)

lint_callable = barriers.lint_callable
lint_hlo_text = barriers.lint_hlo_text

__all__ = [
    "CimcheckError", "Finding", "Report", "Severity", "Suppression",
    "barriers", "check_all_cached_programs", "check_program",
    "lint_callable", "lint_hlo_text", "noise_keys", "parse_suppressions",
    "plan_checks", "recompile", "verify_program",
]


def _traced_graphs(program, graphs: str = "all"):
    """(label, ClosedJaxpr) per executable variant the program can serve.

    Traces through `engine._exec_jit` with ShapeDtypeStruct operands at
    the smallest bucket rung — pure abstract tracing, no XLA compile.
    `TRACE_COUNT` is restored afterwards (a lint trace is not a compile).

    ``graphs="all"`` traces every variant: unbound serve (weight
    quantization in-graph), segmented, reference, and the noise-id path
    when noise is on.  ``graphs="serving"`` traces only the bound-weights
    serve path `BoundProgram.serve` dispatches (+ noise ids under noise)
    — the cheap subset inline `compile_program(verify=...)` runs.  Both
    modes trace stacks that repeat a layer plan once per *unique* layer:
    the barrier lint is per-layer local (inter-layer glue adds no
    rounding ops), so duplicate layers would only retrace identical eqns.
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.runtime import engine as rt

    plan = program.plan
    m = program.buckets.bucket_for(1)
    sds = lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)  # noqa: E731

    def x_struct(p):
        g = p.layers[0].spec.conv
        if g is not None:
            return jax.ShapeDtypeStruct((m,) + g.spatial_in, jnp.float32)
        return jax.ShapeDtypeStruct((m, p.layers[0].spec.k), jnp.float32)

    unique = list(dict.fromkeys(plan.layers))
    if len(unique) < len(plan.layers):
        plans = [(f"layer{plan.layers.index(lp)}",
                  dataclasses.replace(plan, layers=(lp,)))
                 for lp in unique]
    else:
        plans = [("", plan)]
    saved_traces = rt.TRACE_COUNT["n"]
    try:
        out = []
        for tag, p in plans:
            params = rt.init_network_params(p, jax.random.PRNGKey(0))
            p_sds = jax.tree_util.tree_map(sds, list(params))
            if graphs == "serving":
                # the bound payload, abstractly: eval_shape through the
                # jitted bind populates the same trace cache bind() hits
                from repro.runtime.program import _bind_jit
                p_sds = tuple(
                    jax.eval_shape(lambda pr: _bind_jit(p, pr), p_sds))
            bound = graphs == "serving"
            x_sds = x_struct(p)
            mv_sds = jax.ShapeDtypeStruct((), jnp.int32)
            ids_sds = jax.ShapeDtypeStruct((m,), jnp.int32)
            key_sds = sds(jax.random.PRNGKey(0))
            noisy = p.cfg.noise.enabled
            nz = rt._dispatch_noise(p, None)

            def trace(label, *, reference=False, seg=False, nid=False):
                def fn(payload, x, mv, key, segv, nidv):
                    return rt._exec_jit(p, payload, x, mv, key, nz, segv,
                                        nidv, bound, reference)
                closed = jax.make_jaxpr(fn)(
                    p_sds, x_sds, mv_sds,
                    key_sds if noisy else None,
                    ids_sds if seg else None,
                    ids_sds if nid else None)
                return (f"{label}@{tag}" if tag else label, closed)

            out.append(trace("serve"))
            if graphs == "all":
                out += [trace("serve+segments", seg=True),
                        trace("reference", reference=True)]
            if noisy:
                out.append(trace("serve+noise_ids", nid=True))
        return out
    finally:
        rt.TRACE_COUNT["n"] = saved_traces


def check_program(program, *, max_m: int = 1024,
                  suppressions: Tuple[Suppression, ...] = (),
                  lint_graphs: bool = True, graphs: str = "all",
                  key_budget: int = recompile.DEFAULT_KEY_BUDGET,
                  points: Tuple[str, ...] = recompile.DEFAULT_POINTS
                  ) -> Report:
    """Run every cimcheck pass over one compiled `CIMProgram`.

    Args:
      program: the compiled artifact (`compile_program(...)`).
      max_m: largest request extent the recompile pass budgets for.
      suppressions: fnmatch waivers applied to every pass's findings.
      lint_graphs: trace + barrier-lint the executables (the expensive
        part; plan-only checks run regardless).
      graphs: "all" lints every executable variant (segmented, reference,
        noise ids — the CLI / CI sweep); "serving" lints only the default
        serve path, whose trace jit warmup then reuses, so inline
        verification stays a few percent of one-time plan cost.
      key_budget: RC001 executable-key budget.
      points: serving operating-point tags the program dispatches under
        (precision-ladder rungs; ("",) is the single-point default).
    Returns:
      A `Report`; call `.raise_if(mode)` or inspect `.findings`.
    """
    report = Report(suppressions=tuple(suppressions))
    plan = program.plan
    report.merge(plan_checks.run(plan))
    m = program.buckets.bucket_for(1)
    report.merge(noise_keys.run(plan, m))
    report.merge(recompile.run(program, max_m=max_m, budget=key_budget,
                               points=points))
    if lint_graphs:
        for label, closed in _traced_graphs(program, graphs):
            report.extend(barriers.lint_jaxpr(closed, where_prefix=label))
    return report


def verify_program(program, mode: str = "strict", **kw) -> Report:
    """`check_program` + mode enforcement; the `compile_program(verify=)`
    hook.  "strict" raises `CimcheckError` on errors, "warn" prints."""
    return check_program(program, **kw).raise_if(mode)


def check_all_cached_programs(mode: str = "warn", **kw) -> Report:
    """Sweep every program in the global cache (e.g. post-warmup in a
    serving process) through `check_program`; returns the merged Report
    after mode enforcement."""
    from repro.runtime import program as prog_mod

    merged = Report()
    for prog in list(prog_mod._PLAN_PROGRAMS.values()):
        merged.merge(check_program(prog, **kw))
    return merged.raise_if(mode)
