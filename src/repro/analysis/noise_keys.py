"""Noise-key injectivity pass (pass id ``noise``).

The engine's determinism/isolation contracts rest on every PRNG draw
having a unique `fold_in` chain (see `engine._layer_noise`):

  * SA-residue draws:       key -> (layer, 0)
  * positional thermal:     key -> (layer, 1, row_tile, col_tile, row_block)
  * identity-keyed thermal: key -> (layer, 1, row_tile, col_tile,
                                    noise_id, sub)

Because `jax.random.fold_in` is an iterated hash, two draws collide
exactly when their complete integer chains are equal (cross-length
equality is cryptographically negligible).  This pass statically
enumerates every chain a plan can emit for a given row extent and proves
the set collision-free, and additionally audits the `NOISE_ID_STRIDE`
request-range allocator and the in-flight scheduler's id arithmetic.

Finding codes:

  * **NK001** — two enumerated fold chains collide (structural engine bug);
  * **NK002** — duplicate explicit noise id within one fused batch;
  * **NK003** — two requests' `NOISE_ID_STRIDE` id ranges overlap;
  * **NK004** — a request's id range exceeds int32 (`request_index >= 2048`
    wraps ``request_index * NOISE_ID_STRIDE`` — the
    `program.request_noise_ids` overflow class);
  * **NK005** — (WARNING) scheduler uid/call arithmetic can wrap its
    2**31 modulus, silently reusing another request's id range.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding, Report, Severity

PASS_ID = "noise"

INT32_MAX = 0x7FFFFFFF


def _stride() -> int:
    from repro.runtime.program import NOISE_ID_STRIDE
    return NOISE_ID_STRIDE


def _row_block() -> int:
    from repro.runtime.engine import NOISE_ROW_BLOCK
    return NOISE_ROW_BLOCK


def enumerate_fold_tuples(plan, m: int, *,
                          noise_ids: Optional[Sequence[int]] = None,
                          row_sub: Optional[Sequence[int]] = None
                          ) -> List[Tuple[int, ...]]:
    """Every complete fold_in chain the plan emits for row extent ``m``.

    With ``noise_ids`` the thermal draws are identity-keyed (chains fold
    (id, sub) per GEMM row); without, they are positional (chains fold the
    global NOISE_ROW_BLOCK block index).
    """
    block = _row_block()
    n_blocks = -(-max(m, 1) // block)
    chains: List[Tuple[int, ...]] = []
    for i, lp in enumerate(plan.layers):
        chains.append((i, 0))                       # SA residue draw
        for ki in range(len(lp.k_slices)):
            for ni in range(len(lp.n_slices)):
                if noise_ids is not None:
                    subs = (list(row_sub) if row_sub is not None
                            else [0] * len(noise_ids))
                    for rid, sub in zip(noise_ids, subs):
                        chains.append((i, 1, ki, ni, int(rid), int(sub)))
                else:
                    for b in range(n_blocks):
                        chains.append((i, 1, ki, ni, b))
    return chains


def check_injectivity(plan, m: int, *,
                      noise_ids: Optional[Sequence[int]] = None,
                      row_sub: Optional[Sequence[int]] = None
                      ) -> List[Finding]:
    """NK001/NK002: prove the plan's fold-chain set is collision-free."""
    findings: List[Finding] = []
    if noise_ids is not None:
        findings.extend(check_noise_ids(noise_ids, row_sub=row_sub))
    seen: Dict[Tuple[int, ...], int] = {}
    for chain in enumerate_fold_tuples(plan, m, noise_ids=noise_ids,
                                       row_sub=row_sub):
        if chain in seen:
            seen[chain] += 1
            if seen[chain] == 2:       # report each colliding chain once
                findings.append(Finding(
                    pass_id=PASS_ID, code="NK001", severity=Severity.ERROR,
                    message=f"fold_in chain {chain} emitted more than once; "
                            "independent noise draws would be identical",
                    layer=chain[0]))
        else:
            seen[chain] = 1
    return findings


def check_noise_ids(noise_ids: Sequence[int], *,
                    row_sub: Optional[Sequence[int]] = None
                    ) -> List[Finding]:
    """NK002: duplicate (noise_id, sub) pairs within one fused batch."""
    findings: List[Finding] = []
    subs = (list(row_sub) if row_sub is not None else [0] * len(noise_ids))
    seen: Dict[Tuple[int, int], int] = {}
    for rid, sub in zip((int(r) for r in noise_ids), subs):
        pair = (rid, int(sub))
        n = seen.get(pair, 0) + 1
        seen[pair] = n
        if n == 2:
            findings.append(Finding(
                pass_id=PASS_ID, code="NK002", severity=Severity.ERROR,
                message=f"noise id {pair[0]} (sub {pair[1]}) appears more "
                        "than once in a fused batch; the duplicated rows "
                        "would share identity-keyed thermal draws"))
    return findings


def check_request_ranges(requests: Iterable[Tuple[int, int]]) -> List[Finding]:
    """NK003/NK004: audit `request_noise_ids`-style (index, rows) ranges.

    Each request ``(request_index, rows)`` claims ids
    ``[request_index * NOISE_ID_STRIDE, request_index * NOISE_ID_STRIDE
    + rows)``; ranges must stay disjoint and inside int32.
    """
    stride = _stride()
    findings: List[Finding] = []
    spans: List[Tuple[int, int, int]] = []
    for idx, rows in requests:
        lo = idx * stride
        hi = lo + rows          # exclusive
        if rows > stride:
            findings.append(Finding(
                pass_id=PASS_ID, code="NK003", severity=Severity.ERROR,
                message=f"request {idx} needs {rows} ids but "
                        f"NOISE_ID_STRIDE is {stride}; its range bleeds "
                        "into the next request's"))
        if idx < 0 or hi - 1 > INT32_MAX:
            findings.append(Finding(
                pass_id=PASS_ID, code="NK004", severity=Severity.ERROR,
                message=f"request {idx} id range [{lo}, {hi}) leaves int32 "
                        f"(max {INT32_MAX}); request_noise_ids would wrap "
                        "into another request's range "
                        "(request_index >= 2048 overflows)"))
            continue
        spans.append((lo, hi, idx))
    spans.sort()
    for (lo_a, hi_a, idx_a), (lo_b, hi_b, idx_b) in zip(spans, spans[1:]):
        if lo_b < hi_a:
            findings.append(Finding(
                pass_id=PASS_ID, code="NK003", severity=Severity.ERROR,
                message=f"requests {idx_a} and {idx_b} claim overlapping "
                        f"noise-id ranges [{lo_a},{hi_a}) and "
                        f"[{lo_b},{hi_b})"))
    return findings


def check_scheduler_limits(*, max_requests: int,
                           max_calls_per_request: int) -> List[Finding]:
    """NK005: can `CIMDecodeLM.noise_id(uid, call)` wrap its modulus?

    ``noise_id = (uid * NOISE_ID_STRIDE + call) % 2**31``: the modulus
    silently aliases uid 2048 onto uid 0, and a call counter reaching the
    stride bleeds into uid+1's range.
    """
    stride = _stride()
    findings: List[Finding] = []
    if max_requests * stride > INT32_MAX + 1:
        findings.append(Finding(
            pass_id=PASS_ID, code="NK005", severity=Severity.WARNING,
            message=f"serving {max_requests} requests exceeds the "
                    f"{(INT32_MAX + 1) // stride} distinct uid ranges the "
                    "2**31 noise-id modulus provides; ranges recycle"))
    if max_calls_per_request > stride:
        findings.append(Finding(
            pass_id=PASS_ID, code="NK005", severity=Severity.WARNING,
            message=f"a request may issue {max_calls_per_request} decode "
                    f"calls but NOISE_ID_STRIDE is {stride}; its call "
                    "counter bleeds into the next uid's id range"))
    return findings


def run(plan, m: int, *, noise_ids: Optional[Sequence[int]] = None,
        row_sub: Optional[Sequence[int]] = None,
        requests: Optional[Iterable[Tuple[int, int]]] = None,
        max_requests: int = 0, max_calls_per_request: int = 0) -> Report:
    """Run the full noise-key pass over one plan; returns a Report."""
    report = Report()
    report.extend(check_injectivity(plan, m, noise_ids=noise_ids,
                                    row_sub=row_sub))
    if requests is not None:
        report.extend(check_request_ranges(requests))
    if max_requests or max_calls_per_request:
        report.extend(check_scheduler_limits(
            max_requests=max_requests,
            max_calls_per_request=max_calls_per_request))
    return report
