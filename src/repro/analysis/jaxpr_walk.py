"""Jaxpr traversal utilities shared by the cimcheck passes.

JAX programs arrive as nested `ClosedJaxpr` scopes: the outer trace wraps
`pjit`/`custom_jvp_call`/`scan`/`pallas_call`/`shard_map` equations whose
params embed further jaxprs.  The passes in `repro.analysis` need

  * `iter_scopes(jaxpr)` — depth-first enumeration of every nested scope,
  * `subjaxprs(eqn)` — the child jaxprs embedded in one equation's params,
  * `def_map(jaxpr)` — var -> defining-equation index within one scope,
  * `source_summary(eqn)` — best-effort "file:line (fn)" location string,
  * small literal/dtype helpers used by the barrier lint.

Everything here treats jaxprs as read-only data; nothing is retraced.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, List, Optional, Tuple

from jax.extend import core as jex_core
from jax.extend import source_info_util as _siu

Jaxpr = jex_core.Jaxpr
ClosedJaxpr = jex_core.ClosedJaxpr
Literal = jex_core.Literal
Var = jex_core.Var


def as_jaxpr(obj: Any) -> Optional[Jaxpr]:
    """Unwrap ClosedJaxpr -> Jaxpr; pass Jaxpr through; else None."""
    if isinstance(obj, ClosedJaxpr):
        return obj.jaxpr
    if isinstance(obj, Jaxpr):
        return obj
    return None


def subjaxprs(eqn) -> List[Tuple[str, Jaxpr]]:
    """Child jaxprs embedded in an equation's params.

    Returns ``(param_name, jaxpr)`` pairs; params holding tuples/lists of
    jaxprs (e.g. ``cond``'s branches) are flattened with an index suffix.
    """
    out: List[Tuple[str, Jaxpr]] = []
    for name, val in eqn.params.items():
        j = as_jaxpr(val)
        if j is not None:
            out.append((name, j))
            continue
        if isinstance(val, (tuple, list)):
            for i, item in enumerate(val):
                ji = as_jaxpr(item)
                if ji is not None:
                    out.append((f"{name}[{i}]", ji))
    return out


def iter_scopes(jaxpr: Jaxpr) -> Iterator[Jaxpr]:
    """Depth-first over this scope and every nested sub-jaxpr scope."""
    stack = [jaxpr]
    seen = set()
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        yield j
        for eqn in j.eqns:
            for _, sub in subjaxprs(eqn):
                stack.append(sub)


def def_map(jaxpr: Jaxpr) -> Dict[Any, Any]:
    """Map each Var in one scope to the equation that defines it."""
    defs: Dict[Any, Any] = {}
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if isinstance(v, Var):
                defs[v] = eqn
    return defs


def source_summary(eqn) -> str:
    """Best-effort 'file:line (fn)' string for an equation."""
    try:
        return _siu.summarize(eqn.source_info)
    except Exception:
        return ""


def literal_value(v) -> Optional[float]:
    """The scalar float value of a Literal invar, else None."""
    if not isinstance(v, Literal):
        return None
    val = v.val
    try:
        import numpy as np
        arr = np.asarray(val)
        if arr.size != 1:
            return None
        return float(arr.reshape(()))
    except Exception:
        return None


def is_float_var(v) -> bool:
    """True when the var/literal has an inexact (float) dtype."""
    aval = getattr(v, "aval", None)
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return False
    import numpy as np
    return np.issubdtype(dtype, np.inexact)


def is_pow2(x: float) -> bool:
    """True for finite nonzero powers of two (incl. negative exponents)."""
    import math
    if x == 0.0 or not math.isfinite(x):
        return False
    m, _ = math.frexp(abs(x))
    return m == 0.5
