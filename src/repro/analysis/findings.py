"""Finding/report datatypes for the cimcheck static-analysis framework.

Every cimcheck pass (see `repro.analysis`) reports problems as `Finding`
records collected into a `Report`.  A finding carries a pass id (e.g.
``"barriers"``), a stable machine-readable code (e.g. ``"NB001"``), a
severity, a human message, and an optional source location / layer index.

Reports support fnmatch-style suppressions so known-benign findings can be
waived without weakening a pass globally, and serialize to JSON for the CI
artifact (`scripts/cimcheck.py --json`).
"""
from __future__ import annotations

import enum
import fnmatch
import json
from dataclasses import dataclass, field
from typing import Iterable, List, Optional, Sequence, Tuple


class Severity(enum.IntEnum):
    """Finding severity; ERROR fails --strict / verify="strict"."""

    INFO = 0
    WARNING = 1
    ERROR = 2


@dataclass(frozen=True)
class Finding:
    """One problem reported by a cimcheck pass."""

    pass_id: str            # which pass produced it ("barriers", "noise", ...)
    code: str               # stable machine code ("NB001", "NK002", ...)
    severity: Severity
    message: str
    where: str = ""         # source location / op path, best effort
    layer: Optional[int] = None

    def format(self) -> str:
        """Render the finding as a one-line human-readable string."""
        loc = f" @ {self.where}" if self.where else ""
        lyr = f" [layer {self.layer}]" if self.layer is not None else ""
        return (f"{self.severity.name}: {self.pass_id}/{self.code}{lyr}: "
                f"{self.message}{loc}")

    def to_dict(self) -> dict:
        """Serialize to a plain JSON-compatible dict."""
        return {
            "pass": self.pass_id,
            "code": self.code,
            "severity": self.severity.name,
            "message": self.message,
            "where": self.where,
            "layer": self.layer,
        }


@dataclass(frozen=True)
class Suppression:
    """fnmatch pattern waiving findings: matches pass_id and code."""

    pass_id: str = "*"
    code: str = "*"
    reason: str = ""

    def matches(self, finding: Finding) -> bool:
        """True when this suppression waives the given finding."""
        return (fnmatch.fnmatch(finding.pass_id, self.pass_id)
                and fnmatch.fnmatch(finding.code, self.code))


class CimcheckError(RuntimeError):
    """Raised by strict verification when a report contains errors."""

    def __init__(self, report: "Report"):
        self.report = report
        lines = [f.format() for f in report.errors()]
        super().__init__(
            "cimcheck found %d error(s):\n%s" % (len(lines), "\n".join(lines)))


@dataclass
class Report:
    """Accumulated findings from one or more cimcheck passes."""

    findings: List[Finding] = field(default_factory=list)
    suppressions: Tuple[Suppression, ...] = ()
    suppressed: List[Finding] = field(default_factory=list)

    def add(self, finding: Finding) -> None:
        """Record a finding, routing it to `suppressed` when waived."""
        for sup in self.suppressions:
            if sup.matches(finding):
                self.suppressed.append(finding)
                return
        self.findings.append(finding)

    def extend(self, findings: Iterable[Finding]) -> None:
        """Record several findings through the suppression filter."""
        for f in findings:
            self.add(f)

    def merge(self, other: "Report") -> None:
        """Fold another report's findings into this one (re-filtering)."""
        self.extend(other.findings)
        self.suppressed.extend(other.suppressed)

    def errors(self) -> List[Finding]:
        """Findings at ERROR severity."""
        return [f for f in self.findings if f.severity >= Severity.ERROR]

    def warnings(self) -> List[Finding]:
        """Findings at WARNING severity."""
        return [f for f in self.findings if f.severity == Severity.WARNING]

    def codes(self) -> List[str]:
        """The (unsuppressed) finding codes, in report order."""
        return [f.code for f in self.findings]

    def ok(self) -> bool:
        """True when no unsuppressed ERROR findings exist."""
        return not self.errors()

    def raise_if(self, mode: str = "strict") -> "Report":
        """Enforce a verification mode over this report.

        ``"strict"`` raises `CimcheckError` on any ERROR finding; ``"warn"``
        prints findings to stderr; ``"off"`` does nothing.  Returns self so
        calls chain.
        """
        if mode == "off":
            return self
        if mode == "warn":
            import sys
            for f in self.findings:
                print("cimcheck: " + f.format(), file=sys.stderr)
            return self
        if mode == "strict":
            if not self.ok():
                raise CimcheckError(self)
            return self
        raise ValueError(f"unknown cimcheck mode {mode!r}; "
                         "expected 'strict', 'warn' or 'off'")

    def to_json(self, indent: Optional[int] = 2) -> str:
        """Serialize the report (findings + suppressed) to a JSON string."""
        payload = {
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "ok": self.ok(),
        }
        return json.dumps(payload, indent=indent)


def parse_suppressions(specs: Sequence[str]) -> Tuple[Suppression, ...]:
    """Parse CLI-style suppression specs ``pass_id/code[:reason]``."""
    out = []
    for spec in specs:
        body, _, reason = spec.partition(":")
        pass_id, _, code = body.partition("/")
        out.append(Suppression(pass_id=pass_id or "*", code=code or "*",
                               reason=reason))
    return tuple(out)
