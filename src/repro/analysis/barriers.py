"""Numerics-barrier lint (pass id ``barriers``): the PR 7 bug class.

XLA is free to rewrite float arithmetic in context-dependent ways — fusing a
``mul`` + ``add`` into an FMA, or turning ``x / c`` into ``x * (1/c)`` — and
either rewrite can flip the integer produced by a downstream ``floor``/
``round`` (the ADC epilogue and the quantizers).  The repo's contract is
that every product feeding a rounding op must be pinned behind
``rounding_barrier`` (``jax.lax.optimization_barrier``) and every division
by a trace-time constant must be pre-folded with ``_static_reciprocal``
(see `repro.core.quantization`).

This pass walks a traced jaxpr backwards from every float ``floor`` /
``round`` / ``ceil`` sink and reports:

  * **NB001** — an unbarriered ``mul`` reachable from a rounding sink
    through value-preserving ops (the ``gain*dp`` pattern);
  * **NB002** — a ``div`` by a non-power-of-two trace-time literal on such
    a path (should be a ``_static_reciprocal`` multiply, barriered).

The walk is transparent through ops that cannot introduce FMA contraction
or reciprocal rewrites (add/sub/select/reshape/slice/...), stops safely at
``optimization_barrier``, integer values, and scope inputs, and descends
through ``pjit``/``custom_jvp_call``/``closed_call`` boundaries so sinks
wrapped in ``ste_floor`` still see their caller's arithmetic.

A light HLO-text cross-check (`lint_hlo_text`) additionally flags
constant-divides living in the same compiled computation as a ``floor``
(**NB101**, WARNING) — a weaker signal than the jaxpr walk, but it runs on
the *scheduled* module after XLA had its say.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.analysis.findings import Finding, Report, Severity
from repro.analysis.jaxpr_walk import (ClosedJaxpr, Jaxpr, Literal, Var,
                                       def_map, is_float_var, is_pow2,
                                       literal_value, source_summary,
                                       subjaxprs)

PASS_ID = "barriers"

# Rounding primitives whose integer output depends on exact float bits.
SINK_PRIMS = ("floor", "round", "ceil")

# Value-preserving / contraction-immune ops the backward walk passes
# through (all float invars are pushed; non-float invars drop out).
TRANSPARENT_PRIMS = frozenset({
    "add", "sub", "neg", "max", "min", "clamp", "select_n",
    "broadcast_in_dim", "reshape", "transpose", "squeeze", "expand_dims",
    "slice", "dynamic_slice", "rev", "concatenate", "pad", "stop_gradient",
    "copy", "gather", "reduce_max", "reduce_min", "abs", "sign",
})

# Call-like primitives we descend through, mapping inner scope inputs back
# to the caller's operands when the signatures line up 1:1.
CALL_PRIMS = frozenset({
    "pjit", "closed_call", "core_call", "custom_jvp_call",
    "custom_vjp_call", "custom_vjp_call_jaxpr", "remat", "checkpoint",
    "remat2",
})


@dataclass(frozen=True)
class _Scope:
    """One jaxpr scope plus how its invars map back to a caller."""

    jaxpr: Jaxpr
    defs: Dict[Any, Any]
    parent: Optional["_Scope"]
    call_eqn: Optional[Any]    # caller eqn when invars map 1:1, else None


def _call_body(eqn) -> Optional[Jaxpr]:
    subs = dict(subjaxprs(eqn))
    for name in ("jaxpr", "call_jaxpr"):
        if name in subs:
            return subs[name]
    return next(iter(subs.values()), None)


def _child_scope(eqn, parent: _Scope) -> Optional[_Scope]:
    body = _call_body(eqn)
    if body is None:
        return None
    mapped = len(eqn.invars) == len(body.invars)
    return _Scope(body, def_map(body), parent, eqn if mapped else None)


class _Lint:
    """Backward-walk state for one traced jaxpr."""

    def __init__(self, where_prefix: str, layer: Optional[int]):
        self.where_prefix = where_prefix
        self.layer = layer
        self.findings: List[Finding] = []
        self._emitted: set = set()
        self._visited: set = set()

    def _emit(self, code: str, message: str, eqn, sink_where: str) -> None:
        where = source_summary(eqn)
        if sink_where and sink_where != where:
            where = f"{where} -> sink {sink_where}"
        if self.where_prefix:
            where = f"{self.where_prefix}: {where}"
        key = (code, message, where)
        if key in self._emitted:
            return
        self._emitted.add(key)
        self.findings.append(Finding(
            pass_id=PASS_ID, code=code, severity=Severity.ERROR,
            message=message, where=where, layer=self.layer))

    def scan(self, root: Jaxpr) -> None:
        """Find every rounding sink in every nested scope and trace back."""
        stack = [_Scope(root, def_map(root), None, None)]
        seen = set()
        while stack:
            scope = stack.pop()
            if id(scope.jaxpr) in seen:
                continue
            seen.add(id(scope.jaxpr))
            for eqn in scope.jaxpr.eqns:
                if (eqn.primitive.name in SINK_PRIMS and eqn.invars
                        and is_float_var(eqn.invars[0])):
                    self._trace(eqn.invars[0], scope, source_summary(eqn))
                child = (_child_scope(eqn, scope)
                         if subjaxprs(eqn) else None)
                if child is not None:
                    stack.append(child)

    # -- backward walk ----------------------------------------------------
    def _trace(self, var, scope: _Scope, sink_where: str) -> None:
        work: List[Tuple[Any, _Scope]] = [(var, scope)]
        while work:
            v, sc = work.pop()
            if isinstance(v, Literal):
                continue
            if not is_float_var(v):
                continue
            vkey = (id(sc.jaxpr), v)
            if vkey in self._visited:
                continue
            self._visited.add(vkey)
            eqn = sc.defs.get(v)
            if eqn is None:
                # Scope input (invar or closed-over const).  Ascend to the
                # caller's operand when the call mapped 1:1, else opaque.
                if sc.call_eqn is not None and v in sc.jaxpr.invars:
                    idx = sc.jaxpr.invars.index(v)
                    work.append((sc.call_eqn.invars[idx], sc.parent))
                continue
            name = eqn.primitive.name
            if name == "optimization_barrier":
                continue
            if name in TRANSPARENT_PRIMS:
                for iv in eqn.invars:
                    work.append((iv, sc))
                continue
            if name == "convert_element_type":
                src = eqn.invars[0]
                if is_float_var(src):
                    work.append((src, sc))
                continue
            if name == "mul":
                lits = [literal_value(iv) for iv in eqn.invars]
                pow2_idx = next((i for i, lv in enumerate(lits)
                                 if lv is not None and is_pow2(lv)), None)
                if pow2_idx is not None:
                    work.append((eqn.invars[1 - pow2_idx], sc))
                    continue
                self._emit(
                    "NB001",
                    "unbarriered float product reaches a rounding op; wrap "
                    "the product in rounding_barrier(...) to pin it against "
                    "FMA contraction", eqn, sink_where)
                continue
            if name == "div":
                dlit = literal_value(eqn.invars[1])
                if dlit is not None and not is_pow2(dlit):
                    self._emit(
                        "NB002",
                        f"division by trace-time constant {dlit!r} reaches "
                        "a rounding op; XLA may rewrite it as a reciprocal "
                        "multiply — use _static_reciprocal + "
                        "rounding_barrier", eqn, sink_where)
                    continue
                if dlit is not None and is_pow2(dlit):
                    work.append((eqn.invars[0], sc))
                continue   # traced divisor: div is itself an FMA boundary
            if name in CALL_PRIMS:
                child = _child_scope(eqn, sc)
                if child is None:
                    continue
                try:
                    idx = eqn.outvars.index(v)
                except ValueError:
                    continue
                work.append((child.jaxpr.outvars[idx], child))
                continue
            # anything else (dot_general, reductions, rng, transcendentals,
            # pallas_call, ...) produces a fresh value: safe stop.
        return


def lint_jaxpr(closed: ClosedJaxpr, *, where_prefix: str = "",
               layer: Optional[int] = None) -> List[Finding]:
    """Run the barrier lint over one traced (Closed)Jaxpr."""
    root = closed.jaxpr if isinstance(closed, ClosedJaxpr) else closed
    lint = _Lint(where_prefix, layer)
    lint.scan(root)
    return lint.findings


def lint_callable(fn, *args, where_prefix: str = "", **kwargs) -> Report:
    """Trace ``fn(*args, **kwargs)`` (ShapeDtypeStructs welcome) and lint."""
    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    report = Report()
    report.extend(lint_jaxpr(closed, where_prefix=where_prefix))
    return report


# -- scheduled-HLO cross-check -------------------------------------------

_HLO_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->",
                          re.MULTILINE)
_HLO_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*[\w\[\],{}\s/]*?\s"
    r"([a-z][\w\-]*)\((.*?)\)(.*)$", re.MULTILINE)
_HLO_REF_RE = re.compile(r"%([\w.\-]+)")
_HLO_OPNAME_RE = re.compile(r'op_name="([^"]*)"')

# HLO opcodes the backward walk passes through (the TRANSPARENT_PRIMS
# analogue at the scheduled level)
_HLO_TRANSPARENT = frozenset({
    "add", "subtract", "negate", "maximum", "minimum", "clamp", "select",
    "broadcast", "reshape", "transpose", "convert", "slice", "copy",
    "concatenate", "pad", "reverse", "abs", "sign", "multiply",
    "dynamic-slice", "get-tuple-element",
})


def lint_hlo_text(hlo_text: str, *, where_prefix: str = "",
                  max_hops: int = 12) -> List[Finding]:
    """WARNING-level cross-check on *scheduled* HLO text (code NB101).

    By schedule time XLA has already turned constant divides into
    reciprocal multiplies, but it preserves the originating jaxpr op in
    metadata: the rewritten op is a ``multiply`` whose ``op_name`` ends in
    ``/div``.  For every ``floor`` in the module this walks its producer
    chain backwards (through elementwise/shape ops, up to ``max_hops``)
    and flags such a rewrite on the path — the exact post-hoc signature
    of the PR 7 bug, caught after XLA had its say.  Post-floor divides
    (the dequantize path) never fire: the walk follows producers only,
    and ``optimization_barrier`` stops it.
    """
    findings: List[Finding] = []
    blocks = re.split(r"\n\s*\n", hlo_text)
    for block in blocks:
        comp = _HLO_COMP_RE.search(block)
        if comp is None:
            continue
        defs = {}        # op name -> (opcode, [operand names], from_div)
        for name, opcode, operands, rest in _HLO_OP_RE.findall(block):
            refs = _HLO_REF_RE.findall(operands)
            opname = _HLO_OPNAME_RE.search(rest)
            from_div = bool(opname) and opname.group(1).endswith("/div")
            defs[name] = (opcode, refs, from_div)
        for name, (opcode, operands, _) in defs.items():
            if opcode != "floor":
                continue
            work = [(op, 0) for op in operands]
            seen = set()
            while work:
                ref, depth = work.pop()
                if ref in seen or depth > max_hops or ref not in defs:
                    continue
                seen.add(ref)
                sub_opcode, sub_ops, sub_from_div = defs[ref]
                if sub_opcode in ("multiply", "divide") and sub_from_div:
                    where = f"{comp.group(1)}/{name}"
                    if where_prefix:
                        where = f"{where_prefix}: {where}"
                    findings.append(Finding(
                        pass_id=PASS_ID, code="NB101",
                        severity=Severity.WARNING,
                        message="XLA rewrote a constant divide into a "
                                "reciprocal multiply on a floor() path "
                                "in the scheduled module; pre-fold it "
                                "with _static_reciprocal + "
                                "rounding_barrier", where=where))
                    work = []
                    continue
                if sub_opcode in _HLO_TRANSPARENT:
                    for op in sub_ops:
                        work.append((op, depth + 1))
    return findings
