"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training evaluates the linear recurrence with jax.lax.associative_scan
(log-depth, scan-free HLO); decode is the O(1) update.  The block follows
Griffin: (GeLU branch) * (conv1d -> RG-LRU branch), then output projection.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.cim_layers import CIMConfig, cim_linear_apply, init_cim_linear
from repro.models.sharding import BATCH, TP, shard

_C = 8.0


def init_rglru_block(key: jax.Array, d_model: int, width: int,
                     conv_width: int = 4,
                     cim: Optional[CIMConfig] = None) -> Dict:
    ks = jax.random.split(key, 6)
    s = (1.0 / d_model) ** 0.5
    sw = (1.0 / width) ** 0.5
    return {
        "w_gelu": init_cim_linear(ks[0], d_model, width, cfg=cim),
        "w_rnn": init_cim_linear(ks[1], d_model, width, cfg=cim),
        "conv_w": 0.1 * jax.random.normal(ks[2], (conv_width, width)),
        "conv_b": jnp.zeros((width,)),
        "w_a": sw * jax.random.normal(ks[3], (width, width)),
        "b_a": jnp.zeros((width,)),
        "w_x": sw * jax.random.normal(ks[4], (width, width)),
        "b_x": jnp.zeros((width,)),
        # Lambda init so that a ~ U[0.9, 0.999] at r=1 (Griffin appendix)
        "lam": jnp.log(jnp.expm1(-jnp.log(
            jnp.linspace(0.9, 0.999, width)) / _C)),
        "w_out": init_cim_linear(ks[5], width, d_model, cfg=cim),
    }


def rglru_scan(a: jnp.ndarray, b: jnp.ndarray,
               h0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + b_t along axis 1 via associative scan."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h


def rglru_block(params: Dict, x: jnp.ndarray, cim: CIMConfig, *,
                state: Optional[Dict] = None
                ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x (B, L, D) -> (out (B, L, D), new_state).  state = {"h": (B,W),
    "conv": (B, W_conv-1, W)} for decode."""
    gelu_branch = jax.nn.gelu(cim_linear_apply(params["w_gelu"], x, cim))
    gelu_branch = shard(gelu_branch, BATCH, None, TP)
    u = cim_linear_apply(params["w_rnn"], x, cim)
    u = shard(u, BATCH, None, TP)

    width = params["conv_w"].shape[0]
    if state is None:
        up = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
        new_conv = None
    else:
        up = jnp.concatenate([state["conv"].astype(u.dtype), u], axis=1)
        new_conv = up[:, -(width - 1):, :]
    uc = sum(up[:, i:i + u.shape[1], :] * params["conv_w"][i]
             for i in range(width))
    uc = uc + params["conv_b"]

    ucf = uc.astype(jnp.float32)
    r = jax.nn.sigmoid(ucf @ params["w_a"] + params["b_a"])
    i = jax.nn.sigmoid(ucf @ params["w_x"] + params["b_x"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * ucf)

    if state is None:
        h = rglru_scan(a, b)
        new_state = None
    else:
        h = a * state["h"][:, None, :] + b          # L == 1 decode step
        new_state = {"h": h[:, -1, :], "conv": new_conv}

    y = gelu_branch.astype(jnp.float32) * h
    out = cim_linear_apply(params["w_out"], y.astype(x.dtype), cim)
    return shard(out, BATCH, None, None), new_state


def init_rglru_state(batch: int, width: int, conv_width: int = 4) -> Dict:
    return {"h": jnp.zeros((batch, width), jnp.float32),
            "conv": jnp.zeros((batch, conv_width - 1, width), jnp.bfloat16)}
