"""Mixture-of-experts FFN with shard_map expert execution.

Parallelism (DESIGN.md §5): tokens are data-parallel over ("pod","data"),
every expert's FFN is tensor-parallel over "model" (Megatron split on d_ff).
Inside the shard_map body everything is *local*: top-k routing results are
sorted per shard, tokens are gathered into fixed-capacity expert groups
(dropped-token discipline, capacity_factor), the grouped GEMMs run as
batched einsums over the expert axis, and the down-projection partials are
psum'd over "model".

Per-expert ABN: the CIM fakequant path quantizes each expert's weights with
per-(expert, channel) scales and applies per-expert gamma/beta — the paper's
distribution-aware reshaping argument is strongest exactly here, since every
expert sees a different token distribution.

CIM modes: "fakequant" runs the batched-einsum reference with *per-expert*
activation statistics (segment quantization over the expert axis) and the
zero-point folded inside the ADC floor; "engine" routes every expert's
capacity-grouped GEMM through one compiled CIM program per (fan_in, fan_out,
precision) shape — the experts are the plan-once/serve-many case (same
LayerSpec, E different binds), so E experts hit a single program-cache
entry.  The two paths are bit-exact in clean mode.  Unknown modes raise
ValueError — an engine-mode serving config can never silently fall back to
an unquantized float einsum.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import abn as abn_lib
from repro.core import mapping
from repro.core import noise_model as nm
from repro.core.cim_layers import CIMConfig, _code_gain, _engine_config
from repro.core.quantization import (adc_quantize, quantize_act,
                                     quantize_weight, rounding_barrier)
from repro.jax_compat import get_abstract_mesh, shard_map
from repro.models.common import activation_fn
from repro.models.sharding import BATCH, TP, mesh_spec, shard


def init_moe(key: jax.Array, d: int, f: int, n_experts: int,
             cim: Optional[CIMConfig] = None) -> Dict:
    """Router + expert bank params: w_gate/w_up (E, D, F), w_down (E, F, D),
    per-expert ABN gamma/beta on the down-projection's D outputs."""
    ks = jax.random.split(key, 4)
    s_in = (1.0 / d) ** 0.5
    s_out = (1.0 / f) ** 0.5
    return {
        "router": s_in * jax.random.normal(ks[0], (d, n_experts), jnp.float32),
        "w_gate": s_in * jax.random.normal(ks[1], (n_experts, d, f), jnp.float32),
        "w_up": s_in * jax.random.normal(ks[2], (n_experts, d, f), jnp.float32),
        "w_down": s_out * jax.random.normal(ks[3], (n_experts, f, d), jnp.float32),
        "abn_log_gamma": jnp.zeros((n_experts, d), jnp.float32),
        "abn_beta": jnp.zeros((n_experts, d), jnp.float32),
    }


def _get_expert_w(params: Dict, name: str, dtype) -> jnp.ndarray:
    """Raw or deploy-quantized expert bank; int8 dequant fuses on TPU."""
    if f"{name}_q" in params:
        return (params[f"{name}_q"].astype(dtype)
                * params[f"{name}_scale"][..., None, :].astype(dtype))
    return params[name]


def _expert_abn(abn: Optional[Tuple[jnp.ndarray, jnp.ndarray]],
                e: int, f: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-expert ABN params, defaulting to log2(gamma)=4 / beta=0 for the
    projections that carry no learned reshaping (gate/up)."""
    if abn is not None:
        return abn[0], abn[1]
    return (jnp.full((e, f), 4.0, jnp.float32),
            jnp.zeros((e, f), jnp.float32))


def _expert_gemm_engine(x_g: jnp.ndarray, w: jnp.ndarray, cim: CIMConfig,
                        abn: Optional[Tuple[jnp.ndarray, jnp.ndarray]],
                        key: Optional[jax.Array],
                        reference: bool) -> jnp.ndarray:
    """(E, C, D) x (E, D, F) through ONE compiled CIM program, E binds.

    Every expert shares the same LayerSpec (capacity bucket, fan-in,
    fan-out, precision) so compile_program returns a single cached
    program; the per-expert weights/ABN differ only in the bind — the
    plan-once/serve-many contract, visible as >= E serve calls per
    program in CIMProgram.stats()."""
    from repro.runtime.program import DEFAULT_BUCKETS, compile_program

    e, c, d = x_g.shape
    f = w.shape[2]
    # entry/exit barriers: match _expert_gemm's fakequant branch so the
    # digital glue around the expert GEMMs (activation, gating, scatter)
    # is the same isolated subgraph in both modes (rounding_barrier)
    x_g = rounding_barrier(x_g)
    bucket = DEFAULT_BUCKETS.bucket_for(c)
    spec = mapping.LayerSpec(m=bucket, k=d, n=f, r_in=cim.r_in,
                             r_w=cim.r_w, r_out=cim.r_out)
    prog = compile_program([spec], _engine_config(cim))
    lg, bt = _expert_abn(abn, e, f)
    outs = []
    for ei in range(e):
        p = {"w": w[ei].astype(jnp.float32),
             "abn_log_gamma": lg[ei], "abn_beta": bt[ei]}
        sub = None if key is None else jax.random.fold_in(key, ei)
        outs.append(prog.serve([p], x_g[ei].astype(jnp.float32), sub,
                               reference=reference))
    return rounding_barrier(jnp.stack(outs)).astype(x_g.dtype)


def _expert_gemm(x_g: jnp.ndarray, w: jnp.ndarray, cim: CIMConfig,
                 abn: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                 *, key: Optional[jax.Array] = None,
                 reference: bool = False) -> jnp.ndarray:
    """(E, C, D) x (E, D, F) -> (E, C, F) through the configured CIM path.

    fakequant: per-expert activation statistics (segment quantization over
    the expert axis), per-(expert, channel) weight scales, per-expert ABN,
    and the zero-point folded into the ABN offset *inside* the per-row-tile
    ADC floor — the same arithmetic as core.cim_layers._fakequant_forward,
    so it is bit-exact with mode="engine" in clean mode.  engine: compiled
    per-expert programs (_expert_gemm_engine).  bypass/deploy: plain
    einsum.  Anything else raises ValueError."""
    if cim.mode in ("bypass", "deploy"):
        return jnp.einsum("ecd,edf->ecf", x_g, w.astype(x_g.dtype))
    if cim.mode == "engine":
        return _expert_gemm_engine(x_g, w, cim, abn, key, reference)
    if cim.mode != "fakequant":
        raise ValueError(
            f"moe expert GEMM does not support CIM mode {cim.mode!r}; "
            "use fakequant, engine, bypass or deploy")
    e, _, _ = x_g.shape
    fan_in, fan_out = w.shape[1], w.shape[2]
    # entry barrier mirroring _expert_gemm_engine (rounding_barrier)
    x_g = rounding_barrier(x_g)
    aq = quantize_act(x_g.astype(jnp.float32), cim.r_in,
                      segment_ids=jnp.arange(e, dtype=jnp.int32),
                      num_segments=e)                 # per-expert stats
    wq = quantize_weight(w, cim.r_w, axis=1)          # scale (E, 1, F)
    lg, bt = _expert_abn(abn, e, fan_out)
    gamma = abn_lib.abn_gamma(
        abn_lib.ABNParams(lg, bt), gamma_bits=cim.gamma_bits,
        max_gamma=cim.max_gamma)[:, None, :]          # (E, 1, F)
    beta = bt[:, None, :]
    g0 = _code_gain(cim, fan_in)
    mid = 2.0 ** (cim.r_out - 1)

    if cim.noise.enabled and key is not None:
        key, k2 = jax.random.split(key)
        res_v = jax.vmap(
            lambda kk: nm.sample_column_residues(kk, fan_out, cim.r_w,
                                                 cim.noise, cim.macro)
        )(jax.random.split(k2, e))                    # (E, F) per expert
        lsb_v = cim.macro.alpha_adc() * cim.macro.vddh \
            / 2.0 ** (cim.r_out - 1)
        offset_codes = gamma * res_v[:, None, :] / lsb_v
    else:
        offset_codes = 0.0

    # K > n_rows splits into row tiles with per-tile ADC conversions,
    # mirroring _fakequant_forward / the engine schedule exactly.
    row_tiles = -(-fan_in // cim.macro.n_rows)
    # materialized ADC gain (quantization.rounding_barrier): the floor /
    # dequant chain must see the identical float in every fusion context
    gain = rounding_barrier(gamma * g0)
    zp = aq.zero / aq.scale                           # (E, 1, 1)
    dp_hat = jnp.zeros(x_g.shape[:-1] + (fan_out,), jnp.float32)
    for ks, ksz in mapping.split_k_slices(fan_in, row_tiles):
        ke = ks + ksz
        dp = jnp.einsum("ecd,edf->ecf", aq.q[..., ks:ke], wq.q[:, ks:ke, :])
        zp_dp = zp * jnp.sum(wq.q[:, ks:ke, :], axis=1, keepdims=True)
        if cim.noise.enabled and key is not None:
            key, k1 = jax.random.split(key)
            dp = dp + nm.thermal_sigma_dp(cim.noise, cim.r_out, g0) \
                * jax.random.normal(k1, dp.shape)
        beta_eff = (beta + offset_codes) + gain * zp_dp
        code = adc_quantize(dp, r_out=cim.r_out, gain=gain,
                            beta_codes=beta_eff)
        dp_hat = dp_hat + (code - mid - beta) / gain
    return rounding_barrier(dp_hat * aq.scale * wq.scale).astype(x_g.dtype)


def _moe_local(x: jnp.ndarray, probs: jnp.ndarray, top_idx: jnp.ndarray,
               w_gate: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray,
               abn_lg: jnp.ndarray, abn_b: jnp.ndarray,
               key: Optional[jax.Array] = None, *,
               n_experts: int, top_k: int, capacity_factor: float,
               cim: CIMConfig, act: str, psum_axis: Optional[str],
               reference: bool = False) -> jnp.ndarray:
    """Local (per data shard) dropped-token expert execution.

    x (t, D); probs/top_idx (t, k).  Returns (t, D)."""
    t, d = x.shape
    cap = int(capacity_factor * top_k * t / n_experts + 0.5)
    cap = max(8, min(cap, t * top_k))

    flat_e = top_idx.reshape(-1)                       # (t*k,)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)
    flat_p = probs.reshape(-1)
    order = jnp.argsort(flat_e)                        # stable
    e_sorted = flat_e[order]
    # rank within the expert group
    same = jax.nn.one_hot(e_sorted, n_experts, dtype=jnp.int32)
    rank = (jnp.cumsum(same, axis=0) - 1)[jnp.arange(t * top_k), e_sorted]
    keep = rank < cap
    slot = e_sorted * cap + rank                       # (t*k,) flat slot id
    slot = jnp.where(keep, slot, n_experts * cap)      # overflow bin

    # scatter token ids / gates into the capacity grid
    tok_grid = jnp.zeros((n_experts * cap + 1,), jnp.int32).at[slot].set(
        flat_tok[order], mode="drop")
    gate_grid = jnp.zeros((n_experts * cap + 1,), flat_p.dtype).at[slot].set(
        jnp.where(keep, flat_p[order], 0.0), mode="drop")
    tok_grid = tok_grid[:-1].reshape(n_experts, cap)
    gate_grid = gate_grid[:-1].reshape(n_experts, cap)

    k_up = k_gate = k_down = None
    if key is not None:
        k_up, k_gate, k_down = (jax.random.fold_in(key, i) for i in range(3))
    x_g = x[tok_grid]                                  # (E, C, D)
    h_up = _expert_gemm(x_g, w_up, cim, key=k_up, reference=reference)
    fn = activation_fn(act)
    if w_gate is not None:
        h = fn(_expert_gemm(x_g, w_gate, cim, key=k_gate,
                            reference=reference)) * h_up
    else:
        h = fn(h_up)
    y_g = _expert_gemm(h, w_down, cim, abn=(abn_lg, abn_b), key=k_down,
                       reference=reference)            # (E, C, D)
    y_g = y_g * gate_grid[..., None].astype(y_g.dtype)

    out = jnp.zeros((t, d), y_g.dtype).at[tok_grid.reshape(-1)].add(
        y_g.reshape(-1, d))
    if psum_axis is not None:
        out = jax.lax.psum(out, psum_axis)
    return out


def moe_block(params: Dict, x: jnp.ndarray, *, n_experts: int, top_k: int,
              capacity_factor: float, cim: CIMConfig, act: str = "silu",
              key: Optional[jax.Array] = None, reference: bool = False
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (out (B, S, D), aux load-balance loss scalar).

    `key` seeds the experts' CIM noise model (a distinct fold per
    projection bank and per expert).  `reference` asks the engine path to
    run its interpret-mode oracle instead of the Pallas kernel (noise-key
    parity tests).  mode="engine" always executes the *local* expert path:
    the compiled programs own their sharding (cim.sharding), so the outer
    data/tensor shard_map is skipped rather than nested."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)

    logits = (xf.astype(jnp.float32) @ params["router"])
    probs_full = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs_full, top_k)
    top_p = (top_p / jnp.sum(top_p, -1, keepdims=True)).astype(x.dtype)

    # Switch-style load-balance aux loss (computed globally, cheap)
    me = jnp.mean(probs_full, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_idx[:, 0], n_experts), axis=0)
    aux = n_experts * jnp.sum(me * ce)

    mesh = get_abstract_mesh()
    kwargs = dict(n_experts=n_experts, top_k=top_k,
                  capacity_factor=capacity_factor, cim=cim, act=act,
                  reference=reference)
    w_gate = _get_expert_w(params, "w_gate", x.dtype)
    w_up = _get_expert_w(params, "w_up", x.dtype)
    w_down = _get_expert_w(params, "w_down", x.dtype)
    if mesh.empty or cim.mode == "engine":
        out = _moe_local(xf, top_p, top_idx, w_gate, w_up,
                         w_down, params["abn_log_gamma"],
                         params["abn_beta"], key, psum_axis=None, **kwargs)
    else:
        names = set(mesh.axis_names)
        batch_axes = tuple(a for a in BATCH if a in names)
        n_batch = 1
        for a in batch_axes:
            n_batch *= mesh.shape[a]
        if (b * s) % max(n_batch, 1) != 0:     # e.g. single-token decode
            batch_axes = ()
        tp = TP if TP in names else None
        body = functools.partial(_moe_local, psum_axis=tp, **kwargs)
        tok_spec = P(batch_axes if batch_axes else None, None)
        if key is None:
            def body_nokey(xs, ps, ti, wg, wu, wd, lg, bt):
                return body(xs, ps, ti, wg, wu, wd, lg, bt, None)
            out = shard_map(
                body_nokey, mesh=mesh,
                in_specs=(tok_spec, tok_spec, tok_spec,
                          P(None, None, tp), P(None, None, tp),
                          P(None, tp, None), P(None, None), P(None, None)),
                out_specs=tok_spec,
            )(xf, top_p, top_idx, w_gate, w_up,
              w_down, params["abn_log_gamma"], params["abn_beta"])
        else:
            out = shard_map(
                body, mesh=mesh,
                in_specs=(tok_spec, tok_spec, tok_spec,
                          P(None, None, tp), P(None, None, tp),
                          P(None, tp, None), P(None, None), P(None, None),
                          P(None)),
                out_specs=tok_spec,
            )(xf, top_p, top_idx, w_gate, w_up,
              w_down, params["abn_log_gamma"], params["abn_beta"], key)
    return out.reshape(b, s, d), aux
