"""Mixture-of-experts FFN with shard_map expert execution.

Parallelism (DESIGN.md §5): tokens are data-parallel over ("pod","data"),
every expert's FFN is tensor-parallel over "model" (Megatron split on d_ff).
Inside the shard_map body everything is *local*: top-k routing results are
sorted per shard, tokens are gathered into fixed-capacity expert groups
(dropped-token discipline, capacity_factor), the grouped GEMMs run as
batched einsums over the expert axis, and the down-projection partials are
psum'd over "model".

Per-expert ABN: the CIM fakequant path quantizes each expert's weights with
per-(expert, channel) scales and applies per-expert gamma/beta — the paper's
distribution-aware reshaping argument is strongest exactly here, since every
expert sees a different token distribution.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.cim_layers import CIMConfig
from repro.core.quantization import adc_quantize, quantize_act, quantize_weight
from repro.jax_compat import get_abstract_mesh, shard_map
from repro.models.sharding import BATCH, TP, mesh_spec, shard


def init_moe(key: jax.Array, d: int, f: int, n_experts: int,
             cim: Optional[CIMConfig] = None) -> Dict:
    ks = jax.random.split(key, 4)
    s_in = (1.0 / d) ** 0.5
    s_out = (1.0 / f) ** 0.5
    return {
        "router": s_in * jax.random.normal(ks[0], (d, n_experts), jnp.float32),
        "w_gate": s_in * jax.random.normal(ks[1], (n_experts, d, f), jnp.float32),
        "w_up": s_in * jax.random.normal(ks[2], (n_experts, d, f), jnp.float32),
        "w_down": s_out * jax.random.normal(ks[3], (n_experts, f, d), jnp.float32),
        "abn_log_gamma": jnp.zeros((n_experts, d), jnp.float32),
        "abn_beta": jnp.zeros((n_experts, d), jnp.float32),
    }


def _get_expert_w(params: Dict, name: str, dtype) -> jnp.ndarray:
    """Raw or deploy-quantized expert bank; int8 dequant fuses on TPU."""
    if f"{name}_q" in params:
        return (params[f"{name}_q"].astype(dtype)
                * params[f"{name}_scale"][..., None, :].astype(dtype))
    return params[name]


def _expert_gemm(x_g: jnp.ndarray, w: jnp.ndarray, cim: CIMConfig,
                 abn: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None
                 ) -> jnp.ndarray:
    """(E, C, D) x (E, D, F) -> (E, C, F), optionally CIM-fakequantized with
    per-expert weight scales and (on the down-proj) per-expert ABN."""
    if cim.mode != "fakequant":
        return jnp.einsum("ecd,edf->ecf", x_g, w.astype(x_g.dtype))
    aq = quantize_act(x_g.astype(jnp.float32), cim.r_in)
    wq = quantize_weight(w, cim.r_w, axis=1)          # scale (E, 1, F)
    dp = jnp.einsum("ecd,edf->ecf", aq.q, wq.q)
    zp_dp = (aq.zero / aq.scale) * jnp.sum(wq.q, axis=1, keepdims=True)
    # code gain for one macro row-tile of the expert's fan-in
    from repro.core.cim_layers import _code_gain
    g0 = _code_gain(cim, w.shape[1])
    if abn is not None:
        gamma = jnp.clip(2.0 ** abn[0], 2.0 ** -4, cim.max_gamma)[:, None, :]
        beta = abn[1][:, None, :]
    else:
        gamma, beta = jnp.float32(16.0), jnp.float32(0.0)
    code = adc_quantize(dp + zp_dp, r_out=cim.r_out, gain=gamma * g0,
                        beta_codes=beta)
    mid = 2.0 ** (cim.r_out - 1)
    dp_hat = (code - mid - beta) / (gamma * g0)
    return (dp_hat * aq.scale * wq.scale).astype(x_g.dtype)


def _moe_local(x: jnp.ndarray, probs: jnp.ndarray, top_idx: jnp.ndarray,
               w_gate: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray,
               abn_lg: jnp.ndarray, abn_b: jnp.ndarray, *,
               n_experts: int, top_k: int, capacity_factor: float,
               cim: CIMConfig, act: str, psum_axis: Optional[str]
               ) -> jnp.ndarray:
    """Local (per data shard) dropped-token expert execution.

    x (t, D); probs/top_idx (t, k).  Returns (t, D)."""
    t, d = x.shape
    cap = int(capacity_factor * top_k * t / n_experts + 0.5)
    cap = max(8, min(cap, t * top_k))

    flat_e = top_idx.reshape(-1)                       # (t*k,)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)
    flat_p = probs.reshape(-1)
    order = jnp.argsort(flat_e)                        # stable
    e_sorted = flat_e[order]
    # rank within the expert group
    same = jax.nn.one_hot(e_sorted, n_experts, dtype=jnp.int32)
    rank = (jnp.cumsum(same, axis=0) - 1)[jnp.arange(t * top_k), e_sorted]
    keep = rank < cap
    slot = e_sorted * cap + rank                       # (t*k,) flat slot id
    slot = jnp.where(keep, slot, n_experts * cap)      # overflow bin

    # scatter token ids / gates into the capacity grid
    tok_grid = jnp.zeros((n_experts * cap + 1,), jnp.int32).at[slot].set(
        flat_tok[order], mode="drop")
    gate_grid = jnp.zeros((n_experts * cap + 1,), flat_p.dtype).at[slot].set(
        jnp.where(keep, flat_p[order], 0.0), mode="drop")
    tok_grid = tok_grid[:-1].reshape(n_experts, cap)
    gate_grid = gate_grid[:-1].reshape(n_experts, cap)

    x_g = x[tok_grid]                                  # (E, C, D)
    h_up = _expert_gemm(x_g, w_up, cim)
    fn = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
          "relu2": lambda v: jnp.square(jax.nn.relu(v))}[act]
    if w_gate is not None:
        h = fn(_expert_gemm(x_g, w_gate, cim)) * h_up
    else:
        h = fn(h_up)
    y_g = _expert_gemm(h, w_down, cim, abn=(abn_lg, abn_b))  # (E, C, D)
    y_g = y_g * gate_grid[..., None].astype(y_g.dtype)

    out = jnp.zeros((t, d), y_g.dtype).at[tok_grid.reshape(-1)].add(
        y_g.reshape(-1, d))
    if psum_axis is not None:
        out = jax.lax.psum(out, psum_axis)
    return out


def moe_block(params: Dict, x: jnp.ndarray, *, n_experts: int, top_k: int,
              capacity_factor: float, cim: CIMConfig, act: str = "silu"
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (out (B, S, D), aux load-balance loss scalar)."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)

    logits = (xf.astype(jnp.float32) @ params["router"])
    probs_full = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs_full, top_k)
    top_p = (top_p / jnp.sum(top_p, -1, keepdims=True)).astype(x.dtype)

    # Switch-style load-balance aux loss (computed globally, cheap)
    me = jnp.mean(probs_full, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_idx[:, 0], n_experts), axis=0)
    aux = n_experts * jnp.sum(me * ce)

    mesh = get_abstract_mesh()
    kwargs = dict(n_experts=n_experts, top_k=top_k,
                  capacity_factor=capacity_factor, cim=cim, act=act)
    w_gate = _get_expert_w(params, "w_gate", x.dtype)
    w_up = _get_expert_w(params, "w_up", x.dtype)
    w_down = _get_expert_w(params, "w_down", x.dtype)
    if mesh.empty:
        out = _moe_local(xf, top_p, top_idx, w_gate, w_up,
                         w_down, params["abn_log_gamma"],
                         params["abn_beta"], psum_axis=None, **kwargs)
    else:
        names = set(mesh.axis_names)
        batch_axes = tuple(a for a in BATCH if a in names)
        n_batch = 1
        for a in batch_axes:
            n_batch *= mesh.shape[a]
        if (b * s) % max(n_batch, 1) != 0:     # e.g. single-token decode
            batch_axes = ()
        tp = TP if TP in names else None
        body = functools.partial(_moe_local, psum_axis=tp, **kwargs)
        tok_spec = P(batch_axes if batch_axes else None, None)
        out = shard_map(
            body, mesh=mesh,
            in_specs=(tok_spec, tok_spec, tok_spec,
                      P(None, None, tp), P(None, None, tp), P(None, tp, None),
                      P(None, None), P(None, None)),
            out_specs=tok_spec,
        )(xf, top_p, top_idx, w_gate, w_up,
          w_down, params["abn_log_gamma"], params["abn_beta"])
    return out.reshape(b, s, d), aux
