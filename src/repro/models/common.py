"""Shared building blocks for the model zoo: norms, rotary, attention
(full / causal / sliding-window / cross, flash-style streaming for long
sequences), KV caches, and CIM-quantized projections.

Every weight-bearing projection goes through core.cim_layers.cim_linear_apply,
so the paper's technique (fakequant with ABN reshaping) is a config flag away
for every architecture.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.cim_layers import CIMConfig, cim_linear_apply, init_cim_linear
from repro.models.sharding import BATCH, TP, shard


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(d: int, kind: str) -> Dict:
    """Parameters for a `kind` norm over a width-`d` feature axis
    (rmsnorm / layernorm / OLMo-style non-parametric layernorm)."""
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    if kind == "nonparam_ln":          # OLMo: non-parametric LayerNorm
        return {}
    raise ValueError(kind)


def apply_norm(params: Dict, x: jnp.ndarray, kind: str,
               eps: float = 1e-6) -> jnp.ndarray:
    """Normalize the trailing feature axis in float32, cast back to
    x.dtype.  `kind` matches init_norm."""
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        y = y * params["scale"]
    elif kind in ("layernorm", "nonparam_ln"):
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            y = y * params["scale"] + params["bias"]
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse rotary frequencies, shape (head_dim // 2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               inv_freq: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _mask_value(dtype):
    return jnp.finfo(dtype).min


def attention_scores_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, *,
                          causal: bool, window: int) -> jnp.ndarray:
    """(..., Sq, Sk) boolean keep-mask."""
    rel = q_pos[:, None] - k_pos[None, :]
    keep = (k_pos >= 0)[None, :] & (rel >= 0) if causal else \
        jnp.broadcast_to((k_pos >= 0)[None, :], rel.shape)
    if window > 0:
        keep = keep & (rel < window)
    return keep


def plain_attention(q, k, v, *, q_pos, k_pos, causal, window=0):
    """Reference attention; q (B,Sq,H,D), k/v (B,Sk,G,D).

    q_pos/k_pos are (Sq,)/(Sk,) shared across the batch, or (B,Sq)/(B,Sk)
    for per-row positions (slot-mapped in-flight decode, where every batch
    row sits at its own sequence offset) — the keep-mask is then built per
    batch row."""
    b, sq, h, d = q.shape
    g = k.shape[2]
    rep = h // g
    qf = q.astype(jnp.float32) / (d ** 0.5)
    qf = qf.reshape(b, sq, g, rep, d)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qf, k.astype(jnp.float32))
    if q_pos.ndim == 2 or k_pos.ndim == 2:
        qp = q_pos if q_pos.ndim == 2 else jnp.broadcast_to(
            q_pos[None], (b, sq))
        kp = k_pos if k_pos.ndim == 2 else jnp.broadcast_to(
            k_pos[None], (b, k.shape[1]))
        keep = jax.vmap(functools.partial(
            attention_scores_mask, causal=causal, window=window))(qp, kp)
        scores = jnp.where(keep[:, None, None], scores, -1e30)
    else:
        keep = attention_scores_mask(q_pos, k_pos, causal=causal,
                                     window=window)
        scores = jnp.where(keep[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def flash_attention(q, k, v, *, q_pos, k_pos, causal, window=0,
                    kv_block: int = 1024):
    """Streaming (online-softmax) attention: O(Sq * kv_block) live memory.

    Used whenever Sk is large (long-context prefill / whisper encoder).
    Shapes as plain_attention.  Pure lax.scan: HLO size O(1) in Sk.
    """
    b, sq, h, d = q.shape
    sk, g = k.shape[1], k.shape[2]
    rep = h // g
    if sk % kv_block:
        pad = kv_block - sk % kv_block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-10**9)
        sk += pad
    n_blk = sk // kv_block
    kb = k.reshape(b, n_blk, kv_block, g, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_blk, kv_block, g, d).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(n_blk, kv_block)
    qf = (q.astype(jnp.float32) / (d ** 0.5)).reshape(b, sq, g, rep, d)

    def step(carry, blk):
        acc, m, l = carry
        kc, vc, pc = blk
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qf, kc.astype(jnp.float32))
        keep = attention_scores_mask(q_pos, pc, causal=causal, window=window)
        s = jnp.where(keep[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, -1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p, vc.astype(jnp.float32))
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, g, rep, sq, d), jnp.float32)
    m0 = jnp.full((b, g, rep, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, g, rep, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (kb, vb, pb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (GQA, optional bias / SWA / cross), CIM projections
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    """Static attention-block hyperparameters (GQA shape, RoPE, window,
    flash threshold, kernel implementation)."""
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    window: int = 0                    # >0: sliding-window attention
    causal: bool = True
    rope_theta: float = 1e6
    use_rope: bool = True
    flash_threshold: int = 8192        # Sk above which the streaming path is used
    impl: str = "jnp"                  # jnp | pallas (fused VMEM kernel)


def init_attention(key: jax.Array, cfg: AttnConfig,
                   cim: Optional[CIMConfig] = None) -> Dict:
    """Q/K/V/O projection params (CIM-linear layout) + optional biases."""
    ks = jax.random.split(key, 4)
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": init_cim_linear(ks[0], d, h * hd, cfg=cim),
        "wk": init_cim_linear(ks[1], d, g * hd, cfg=cim),
        "wv": init_cim_linear(ks[2], d, g * hd, cfg=cim),
        "wo": init_cim_linear(ks[3], h * hd, d, cfg=cim),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((g * hd,), jnp.float32)
        p["bv"] = jnp.zeros((g * hd,), jnp.float32)
    return p


def _repeat_kv_to(x: jnp.ndarray, target_heads: int) -> jnp.ndarray:
    """Repeat KV heads so the head axis is TP-shardable (DESIGN.md §5)."""
    g = x.shape[2]
    if g >= target_heads:
        return x
    return jnp.repeat(x, target_heads // g, axis=2)


def attention_block(params: Dict, x: jnp.ndarray, cfg: AttnConfig,
                    cim: CIMConfig, *, positions: jnp.ndarray,
                    cache: Optional[Dict] = None,
                    kv_repeat_to: int = 0,
                    x_kv: Optional[jnp.ndarray] = None,
                    cross_kv: Optional[Dict] = None,
                    kv_positions: Optional[jnp.ndarray] = None,
                    key: Optional[jax.Array] = None
                    ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Self- (x_kv None) or cross- (x_kv given) attention with optional
    KV cache for decode.  `cross_kv` supplies precomputed cross-attention
    K/V ({"k","v"}) during cached decode.  Returns (out, updated_cache).

    `key` seeds the CIM noise model of the four projections (a distinct
    fold per projection); None keeps them clean/deterministic.

    The self-attention decode cache is a *ring buffer* of length L: writes
    land at idx % L, so sliding-window layers keep only their window."""
    b, s, d = x.shape
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if x_kv is None else x_kv
    kq = kk_key = kv_key = ko = None
    if key is not None:
        kq, kk_key, kv_key, ko = (jax.random.fold_in(key, i)
                                  for i in range(4))

    use_pallas = (cfg.impl == "pallas" and s > 1 and cache is None
                  and cross_kv is None)
    q = cim_linear_apply(params["wq"], x, cim, key=kq)
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(b, s, h, hd)
    if not use_pallas:
        # pallas path: the kernel's shard_map in_specs define the layout;
        # an extra constraint here only inserts reshard copies
        q = shard(q, BATCH, None, TP, None)

    if cross_kv is not None:
        # cross-attention decode: encoder KV precomputed at prefill
        k, v = cross_kv["k"], cross_kv["v"]
        k_pos = jnp.arange(k.shape[1])
        new_cache = cross_kv
    else:
        kk = cim_linear_apply(params["wk"], src, cim, key=kk_key)
        vv = cim_linear_apply(params["wv"], src, cim, key=kv_key)
        if "bk" in params:
            kk, vv = kk + params["bk"], vv + params["bv"]
        k = kk.reshape(b, src.shape[1], g, hd)
        v = vv.reshape(b, src.shape[1], g, hd)
        src_pos = positions if x_kv is None else (
            kv_positions if kv_positions is not None
            else jnp.arange(src.shape[1]))
        if cfg.use_rope and x_kv is None:
            inv = rope_frequencies(hd, cfg.rope_theta)
            q = apply_rope(q, positions, inv)
            k = apply_rope(k, src_pos, inv)
        if kv_repeat_to:
            k = _repeat_kv_to(k, kv_repeat_to)
            v = _repeat_kv_to(v, kv_repeat_to)
        if use_pallas:
            pass  # shard_map in_specs drive k/v layout (replicated on TP)
        if cache is not None and x_kv is None and cache["idx"].ndim == 1:
            # slot-mapped decode (in-flight batching): `idx` is a (B,)
            # per-slot write cursor, every batch row rides its own ring
            # position.  Scatter-write one token per row; the mask
            # positions become per-row (B, L) and plain_attention builds
            # the keep-mask per batch row.
            if s != 1:
                raise ValueError(
                    f"slot-mapped KV decode is single-token (s=1), got "
                    f"s={s}; prefill per request and scatter into the "
                    "slot with write_slot_kv")
            length = cache["k"].shape[1]
            idx = cache["idx"]
            write = jax.lax.rem(idx, length)
            rows = jnp.arange(b)
            k = cache["k"].at[rows, write].set(
                k[:, 0].astype(cache["k"].dtype))
            v = cache["v"].at[rows, write].set(
                v[:, 0].astype(cache["v"].dtype))
            k = shard(k, BATCH, TP, None, None)
            v = shard(v, BATCH, TP, None, None)
            new_cache = {"k": k, "v": v, "idx": idx + s}
            # position held by ring slot j after the write, per batch row
            j = jnp.arange(length)[None, :]
            last = (idx + s - 1)[:, None]
            src_pos = last - jnp.mod(last - j, length)
            src_pos = jnp.where(src_pos >= 0, src_pos, -10**9)
        elif cache is not None and x_kv is None:
            # decode: ring-buffer append at idx % L (s == 1 for decode;
            # multi-token prefill-into-cache requires idx + s <= L)
            length = cache["k"].shape[1]
            idx = cache["idx"]
            write = jax.lax.rem(idx, length)
            k = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, write, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, write, 0, 0))
            k = shard(k, BATCH, TP, None, None)
            v = shard(v, BATCH, TP, None, None)
            new_cache = {"k": k, "v": v, "idx": idx + s}
            # position held by ring slot j after the write
            j = jnp.arange(length)
            last = idx + s - 1
            src_pos = last - jnp.mod(last - j, length)
            src_pos = jnp.where(src_pos >= 0, src_pos, -10**9)
        elif cache is not None:
            new_cache = {"k": k, "v": v}
        else:
            new_cache = None
        if (cache is None or x_kv is not None) and not use_pallas:
            k = shard(k, BATCH, None, TP, None)
            v = shard(v, BATCH, None, TP, None)
        k_pos = src_pos

    # per-slot decode keeps 2D (B, S) q positions so the per-row masks of
    # plain_attention line up; otherwise 2D positions collapse to row 0
    # (shared across the batch, the pre-slot contract)
    per_row = getattr(k_pos, "ndim", 1) == 2
    q_pos = positions if (positions.ndim == 1 or per_row) else positions[0]
    if use_pallas:
        # fused VMEM flash kernel (fwd + bwd); positions are contiguous
        # 0..S-1 in the no-cache path, masks generated in-kernel
        from repro.kernels.flash_attn.ops import flash_attention_sharded
        out = flash_attention_sharded(
            q, k, v, cfg.causal and x_kv is None and s > 1,
            cfg.window if x_kv is None else 0)
    elif k.shape[1] > cfg.flash_threshold and s > 1:
        out = flash_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                              causal=cfg.causal and x_kv is None,
                              window=cfg.window)
    else:
        out = plain_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                              causal=cfg.causal and x_kv is None and s > 1,
                              window=cfg.window if x_kv is None else 0)
    out = out.reshape(b, s, h * hd)
    y = cim_linear_apply(params["wo"], out, cim, key=ko)
    return shard(y, BATCH, None, None), new_cache


def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Dict:
    """Ring-buffer decode cache with one shared write cursor (all batch
    rows advance in lockstep — the classic static-batch serving shape)."""
    return {"k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
            "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
            "idx": jnp.array(0, jnp.int32)}


def init_slot_kv_cache(slots: int, max_len: int, n_kv: int, head_dim: int,
                       dtype=jnp.bfloat16) -> Dict:
    """Slot-mapped decode cache for in-flight (continuous) batching.

    Same K/V layout as init_kv_cache but `idx` is a (slots,) *per-slot*
    write cursor: every slot rides its own ring position, so requests at
    different sequence offsets decode fused in one batch.  attention_block
    detects the vector cursor and switches to per-row scatter writes and
    per-row masks.  Admit a request with write_slot_kv (scatter its
    prefilled batch-1 cache into a slot), retire with free_slot_kv
    (cursor reset only — the stale K/V rows are never moved or gathered)."""
    return {"k": jnp.zeros((slots, max_len, n_kv, head_dim), dtype),
            "v": jnp.zeros((slots, max_len, n_kv, head_dim), dtype),
            "idx": jnp.zeros((slots,), jnp.int32)}


def write_slot_kv(cache: Dict, slot, prefill: Dict) -> Dict:
    """Admit one request: scatter its prefilled batch-1 KV cache (an
    init_kv_cache the request was prefilled into) into `slot` of a
    slot-mapped cache and set the slot's cursor to the prefill length.
    Leaves every other slot untouched — admission never perturbs the
    requests already in flight."""
    return {"k": cache["k"].at[slot].set(
                prefill["k"][0].astype(cache["k"].dtype)),
            "v": cache["v"].at[slot].set(
                prefill["v"][0].astype(cache["v"].dtype)),
            "idx": cache["idx"].at[slot].set(
                jnp.asarray(prefill["idx"], jnp.int32))}


def free_slot_kv(cache: Dict, slot) -> Dict:
    """Retire one request: reset the slot's write cursor to 0.

    Gather-free — the slot's stale K/V rows stay in place (a zero cursor
    masks every ring position out of the attention scores, and the next
    admit overwrites them), so retirement moves no cache data and cannot
    perturb the surviving requests."""
    return {"k": cache["k"], "v": cache["v"],
            "idx": cache["idx"].at[slot].set(0)}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

_ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu2": lambda v: jnp.square(jax.nn.relu(v)),
}


def activation_fn(name: str):
    """The single source of the MLP/MoE activation table (silu / gelu /
    relu2).  Every function preserves the input dtype — callers apply it
    in whatever compute dtype the projections produced.  Raises ValueError
    on an unknown name rather than serving an un-activated hidden state."""
    try:
        return _ACTIVATIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown activation {name!r}; expected one of "
            f"{sorted(_ACTIVATIONS)}") from None


def init_mlp(key: jax.Array, d: int, f: int, gated: bool,
             cim: Optional[CIMConfig] = None) -> Dict:
    """Up/down (+ optional gate) projection params for a d->f->d MLP."""
    ks = jax.random.split(key, 3)
    p = {"w_up": init_cim_linear(ks[0], d, f, cfg=cim),
         "w_down": init_cim_linear(ks[1], f, d, cfg=cim)}
    if gated:
        p["w_gate"] = init_cim_linear(ks[2], d, f, cfg=cim)
    return p


def mlp_block(params: Dict, x: jnp.ndarray, cim: CIMConfig,
              act: str = "silu",
              key: Optional[jax.Array] = None) -> jnp.ndarray:
    """(Gated) MLP with every projection through the CIM path.  `key`
    seeds the projections' noise model (distinct fold per projection)."""
    k_up = k_gate = k_down = None
    if key is not None:
        k_up, k_gate, k_down = (jax.random.fold_in(key, i)
                                for i in range(3))
    up = cim_linear_apply(params["w_up"], x, cim, key=k_up)
    up = shard(up, BATCH, None, TP)
    fn = activation_fn(act)
    if "w_gate" in params:
        gate = cim_linear_apply(params["w_gate"], x, cim, key=k_gate)
        gate = shard(gate, BATCH, None, TP)
        hidden = fn(gate) * up
    else:
        hidden = fn(up)
    y = cim_linear_apply(params["w_down"], hidden, cim, key=k_down)
    return shard(y, BATCH, None, None)
