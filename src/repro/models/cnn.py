"""The paper's own workloads: the 784-512-128-10 MLP of Fig. 3(b) and a
LeNet-5-style CNN (the paper measures a modified 4b LeNet-5 on-chip).

Every layer runs through the CIM stack, so these models exercise the full
technique: adaptive-swing activation quantization, bit-plane weights,
DSCI-ADC output quantization with learned per-channel ABN, and post-silicon
noise injection during training.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.cim_layers import (CIMConfig, cim_conv2d_apply,
                                   cim_linear_apply, init_cim_linear)


def init_mlp(key: jax.Array, dims=(784, 512, 128, 10),
             cim: Optional[CIMConfig] = None) -> Dict:
    ks = jax.random.split(key, len(dims) - 1)
    return {f"fc{i}": init_cim_linear(ks[i], dims[i], dims[i + 1], cfg=cim)
            for i in range(len(dims) - 1)}


def mlp_forward(params: Dict, x: jnp.ndarray, cim: CIMConfig,
                key: Optional[jax.Array] = None) -> jnp.ndarray:
    """x (B, 784) -> logits (B, 10)."""
    n = len(params)
    for i in range(n):
        if key is not None:
            key, sub = jax.random.split(key)
        else:
            sub = None
        x = cim_linear_apply(params[f"fc{i}"], x, cim, key=sub)
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def init_lenet(key: jax.Array, n_classes: int = 10, in_ch: int = 1,
               cim: Optional[CIMConfig] = None) -> Dict:
    ks = jax.random.split(key, 5)
    return {
        "conv1": init_cim_linear(ks[0], 3 * 3 * in_ch, 16, cfg=cim),
        "conv2": init_cim_linear(ks[1], 3 * 3 * 16, 32, cfg=cim),
        "fc1": init_cim_linear(ks[2], 32 * 7 * 7, 128, cfg=cim),
        "fc2": init_cim_linear(ks[3], 128, n_classes, cfg=cim),
    }


def lenet_forward(params: Dict, x: jnp.ndarray, cim: CIMConfig,
                  key: Optional[jax.Array] = None) -> jnp.ndarray:
    """x (B, 28, 28, C) -> logits."""
    def nk():
        nonlocal key
        if key is None:
            return None
        key, sub = jax.random.split(key)
        return sub

    h = jax.nn.relu(cim_conv2d_apply(params["conv1"], x, cim, key=nk()))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = jax.nn.relu(cim_conv2d_apply(params["conv2"], h, cim, key=nk()))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(cim_linear_apply(params["fc1"], h, cim, key=nk()))
    return cim_linear_apply(params["fc2"], h, cim, key=nk())
