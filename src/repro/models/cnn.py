"""The paper's own workloads: the 784-512-128-10 MLP of Fig. 3(b) and a
LeNet-5-style CNN (the paper measures a modified 4b LeNet-5 on-chip).

Every layer runs through the CIM stack, so these models exercise the full
technique: adaptive-swing activation quantization, bit-plane weights,
DSCI-ADC output quantization with learned per-channel ABN, and post-silicon
noise injection during training.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.cim_layers import (CIMConfig, cim_conv2d_apply,
                                   cim_linear_apply, init_cim_linear)
from repro.core.mapping import LayerSpec, conv_layer_spec


def init_mlp(key: jax.Array, dims=(784, 512, 128, 10),
             cim: Optional[CIMConfig] = None) -> Dict:
    ks = jax.random.split(key, len(dims) - 1)
    return {f"fc{i}": init_cim_linear(ks[i], dims[i], dims[i + 1], cfg=cim)
            for i in range(len(dims) - 1)}


def mlp_forward(params: Dict, x: jnp.ndarray, cim: CIMConfig,
                key: Optional[jax.Array] = None) -> jnp.ndarray:
    """x (B, 784) -> logits (B, 10)."""
    n = len(params)
    for i in range(n):
        if key is not None:
            key, sub = jax.random.split(key)
        else:
            sub = None
        x = cim_linear_apply(params[f"fc{i}"], x, cim, key=sub)
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def init_lenet(key: jax.Array, n_classes: int = 10, in_ch: int = 1,
               cim: Optional[CIMConfig] = None) -> Dict:
    ks = jax.random.split(key, 5)
    return {
        "conv1": init_cim_linear(ks[0], 3 * 3 * in_ch, 16, cfg=cim),
        "conv2": init_cim_linear(ks[1], 3 * 3 * 16, 32, cfg=cim),
        "fc1": init_cim_linear(ks[2], 32 * 7 * 7, 128, cfg=cim),
        "fc2": init_cim_linear(ks[3], 128, n_classes, cfg=cim),
    }


LENET_LAYER_ORDER = ("conv1", "conv2", "fc1", "fc2")


def lenet_engine_specs(batch: int, h: int = 28, w: int = 28, in_ch: int = 1,
                       n_classes: int = 10,
                       cim: Optional[CIMConfig] = None
                       ) -> Tuple[List[LayerSpec], List[str], List[int]]:
    """The LeNet network as one engine schedule: conv-tagged + dense
    LayerSpecs with matching activations and max-pool epilogues — the
    arguments of `CIMInferenceEngine(specs, activations=..., pools=...)`."""
    cim = cim if cim is not None else CIMConfig()
    r = dict(r_in=cim.r_in, r_w=cim.r_w, r_out=cim.r_out)
    ph, pw = h // 2, w // 2                 # after each 2x2 max-pool
    qh, qw = ph // 2, pw // 2
    specs = [
        conv_layer_spec(batch, h, w, in_ch, 16, kh=3, kw=3, padding=1, **r),
        conv_layer_spec(batch, ph, pw, 16, 32, kh=3, kw=3, padding=1, **r),
        LayerSpec(m=batch, k=32 * qh * qw, n=128, **r),
        LayerSpec(m=batch, k=128, n=n_classes, **r),
    ]
    return specs, ["relu", "relu", "relu", "none"], [2, 2, 1, 1]


def lenet_program(batch: int, h: int = 28, w: int = 28, in_ch: int = 1,
                  n_classes: int = 10, cim: Optional[CIMConfig] = None):
    """The whole LeNet (conv1 -> pool -> conv2 -> pool -> fc1 -> fc2) as
    one compiled CIMProgram from the module-level program cache — planned
    once per distinct (geometry, CIMConfig), then served many times
    (`prog.bind(lenet_params_list(params)).serve(images)`)."""
    from repro.core.cim_layers import _engine_config
    from repro.runtime.program import compile_program

    cim = cim if cim is not None else CIMConfig()
    specs, acts, pools = lenet_engine_specs(batch, h, w, in_ch, n_classes,
                                            cim)
    return compile_program(specs, _engine_config(cim), activations=acts,
                           pools=pools)


def lenet_engine(batch: int, h: int = 28, w: int = 28, in_ch: int = 1,
                 n_classes: int = 10, cim: Optional[CIMConfig] = None):
    """One CIMInferenceEngine executing the whole LeNet (conv1 -> pool ->
    conv2 -> pool -> fc1 -> fc2) through the Pallas kernel variants (the
    engine wraps the same cached program `lenet_program` returns)."""
    from repro.core.cim_layers import _engine_config
    from repro.runtime import CIMInferenceEngine

    cim = cim if cim is not None else CIMConfig()
    specs, acts, pools = lenet_engine_specs(batch, h, w, in_ch, n_classes,
                                            cim)
    return CIMInferenceEngine(specs, _engine_config(cim), activations=acts,
                              pools=pools)


def lenet_params_list(params: Dict) -> List[Dict]:
    """init_lenet's name-keyed params in the engine's positional order."""
    return [params[name] for name in LENET_LAYER_ORDER]


def lenet_forward(params: Dict, x: jnp.ndarray, cim: CIMConfig,
                  key: Optional[jax.Array] = None) -> jnp.ndarray:
    """x (B, 28, 28, C) -> logits.

    mode="engine" runs the whole network — conv1/conv2/fc1/fc2 plus the
    pooling and flatten epilogues — through one compiled program from the
    module-level cache (`lenet_program`): planning happens once per
    distinct (geometry, CIMConfig) and the batch dispatches through the
    program's bucket ladder, so repeated calls — at any batch size inside
    a bucket — reuse the compiled schedule.  With cim.noise enabled the
    engine runs in its noise-injected mode and `key` seeds the noise
    model."""
    if cim.mode == "engine":
        from repro.runtime.program import DEFAULT_BUCKETS
        b, h, w, c = x.shape
        prog = lenet_program(DEFAULT_BUCKETS.bucket_for(b), h, w, c,
                             params["fc2"]["w"].shape[1], cim)
        return prog.serve(lenet_params_list(params), x, key)

    def nk():
        nonlocal key
        if key is None:
            return None
        key, sub = jax.random.split(key)
        return sub

    h = jax.nn.relu(cim_conv2d_apply(params["conv1"], x, cim, key=nk()))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = jax.nn.relu(cim_conv2d_apply(params["conv2"], h, cim, key=nk()))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(cim_linear_apply(params["fc1"], h, cim, key=nk()))
    return cim_linear_apply(params["fc2"], h, cim, key=nk())
