"""Mesh-aware sharding helpers.

All model code annotates activations/params with *logical* specs through
`shard(...)`; the helper silently drops axes that the current mesh does not
have, so the same model runs on the 1-device CPU smoke tests, the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh without change.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P

from repro.jax_compat import get_abstract_mesh

# logical axis groups
BATCH = ("pod", "data")     # pure data-parallel axes
TP = "model"                # tensor-parallel axis

AxisEl = Union[None, str, Sequence[str]]


def _filter(el: AxisEl, names) -> AxisEl:
    if el is None:
        return None
    if isinstance(el, str):
        return el if el in names else None
    kept = tuple(a for a in el if a in names)
    return kept if kept else None


def mesh_spec(*elems: AxisEl, shape: Optional[Sequence[int]] = None
              ) -> Optional[P]:
    """PartitionSpec with axes absent from the ambient mesh dropped; if
    `shape` is given, axes whose product does not divide the corresponding
    dim are also dropped (e.g. batch=1 long-context decode, odd vocabs)."""
    mesh = get_abstract_mesh()
    if mesh.empty:
        return None
    names = set(mesh.axis_names)
    filtered = [_filter(e, names) for e in elems]
    if shape is not None:
        for i, e in enumerate(filtered):
            if e is None or i >= len(shape):
                continue
            axes = (e,) if isinstance(e, str) else tuple(e)
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if shape[i] % prod != 0:
                # keep the largest prefix of axes that still divides
                kept = []
                prod = 1
                for a in axes:
                    if shape[i] % (prod * mesh.shape[a]) == 0:
                        kept.append(a)
                        prod *= mesh.shape[a]
                filtered[i] = tuple(kept) if kept else None
    return P(*filtered)


def shard(x: jax.Array, *elems: AxisEl) -> jax.Array:
    spec = mesh_spec(*elems, shape=x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def axis_size(name: str) -> int:
    mesh = get_abstract_mesh()
    if mesh.empty or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
