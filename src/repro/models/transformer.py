"""Model assembly for all assigned architectures.

One config-driven implementation covering:
  dense   : pre-norm decoder (GQA + gated MLP)      [minitron/qwen2/olmo/granite]
  moe     : dense attention + top-k expert FFN      [phi3.5-moe/mixtral]
  hybrid  : Griffin blocks (2x RG-LRU : 1x local attn)  [recurrentgemma]
  ssm     : Mamba-2 SSD stack                        [mamba2]
  vlm     : dense decoder + precomputed patch-embed prefix  [internvl2]
  audio   : Whisper enc-dec, conv frontend stubbed   [whisper]

Layer stacks are scanned (jax.lax.scan over stacked params) with optional
remat, so HLO size is depth-independent — required for the 80-layer dry-runs.
All projections run through the CIM layer (core/cim_layers.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.cim_layers import CIMConfig, cim_linear_apply, init_cim_linear
from repro.models import common as cm
from repro.models import mamba2 as m2
from repro.models import rglru as rg
from repro.models.moe import init_moe, moe_block
from repro.models.sharding import BATCH, TP, axis_size, shard


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _attn_cfg(cfg: ModelConfig, *, window: int = 0, causal: bool = True,
              use_rope: bool = True, n_heads: int = 0, n_kv: int = 0
              ) -> cm.AttnConfig:
    return cm.AttnConfig(
        d_model=cfg.d_model, n_heads=n_heads or cfg.n_heads,
        n_kv_heads=n_kv or cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias, window=window, causal=causal,
        rope_theta=cfg.rope_theta, use_rope=use_rope, impl=cfg.attn_impl)


# ---------------------------------------------------------------------------
# layer init (one layer; stacked via vmap over keys)
# ---------------------------------------------------------------------------

def _init_decoder_layer(cfg: ModelConfig, key: jax.Array) -> Dict:
    ks = jax.random.split(key, 4)
    cim = cfg.cim
    p: Dict[str, Any] = {
        "ln1": cm.init_norm(cfg.d_model, cfg.norm_type),
        "ln2": cm.init_norm(cfg.d_model, cfg.norm_type),
        "attn": cm.init_attention(
            ks[0], _attn_cfg(cfg, window=cfg.sliding_window), cim),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.moe_experts, cim)
    else:
        p["mlp"] = cm.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, cim)
    return p


def _init_ssm_layer(cfg: ModelConfig, key: jax.Array) -> Dict:
    return {
        "ln1": cm.init_norm(cfg.d_model, cfg.norm_type),
        "mixer": m2.init_mamba2_layer(
            key, cfg.d_model, expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
            d_state=cfg.ssm_state, conv_width=cfg.conv_width, cim=cfg.cim),
    }


def _init_rec_layer(cfg: ModelConfig, key: jax.Array) -> Dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": cm.init_norm(cfg.d_model, cfg.norm_type),
        "ln2": cm.init_norm(cfg.d_model, cfg.norm_type),
        "rec": rg.init_rglru_block(ks[0], cfg.d_model,
                                   cfg.lru_width or cfg.d_model,
                                   cfg.conv_width, cfg.cim),
        "mlp": cm.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, cfg.cim),
    }


def _init_local_attn_layer(cfg: ModelConfig, key: jax.Array) -> Dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": cm.init_norm(cfg.d_model, cfg.norm_type),
        "ln2": cm.init_norm(cfg.d_model, cfg.norm_type),
        "attn": cm.init_attention(
            ks[0], _attn_cfg(cfg, window=cfg.local_window), cfg.cim),
        "mlp": cm.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, cfg.cim),
    }


def _init_enc_layer(cfg: ModelConfig, key: jax.Array) -> Dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": cm.init_norm(cfg.d_model, cfg.norm_type),
        "ln2": cm.init_norm(cfg.d_model, cfg.norm_type),
        "attn": cm.init_attention(
            ks[0], _attn_cfg(cfg, causal=False, use_rope=False), cfg.cim),
        "mlp": cm.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, cfg.cim),
    }


def _init_xdec_layer(cfg: ModelConfig, key: jax.Array) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "ln1": cm.init_norm(cfg.d_model, cfg.norm_type),
        "ln_x": cm.init_norm(cfg.d_model, cfg.norm_type),
        "ln2": cm.init_norm(cfg.d_model, cfg.norm_type),
        "attn": cm.init_attention(
            ks[0], _attn_cfg(cfg, use_rope=False), cfg.cim),
        "xattn": cm.init_attention(
            ks[1], _attn_cfg(cfg, causal=False, use_rope=False), cfg.cim),
        "mlp": cm.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.gated_mlp, cfg.cim),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    """Init the full parameter pytree for `cfg` (embeddings, every block
    of the family's layer stack, final norm, untied lm_head if any)."""
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    emb_scale = d ** -0.5
    params: Dict[str, Any] = {
        "embed": emb_scale * jax.random.normal(
            keys[0], (cfg.vocab_size, d), jnp.float32),
        "final_norm": cm.init_norm(d, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_cim_linear(keys[1], d, cfg.vocab_size)

    if cfg.family in ("dense", "moe", "vlm"):
        lk = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = jax.vmap(
            functools.partial(_init_decoder_layer, cfg))(lk)
    elif cfg.family == "ssm":
        lk = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = jax.vmap(
            functools.partial(_init_ssm_layer, cfg))(lk)
    elif cfg.family == "hybrid":
        nb, tail = divmod(cfg.n_layers, 3)
        bk = jax.random.split(keys[2], nb)

        def init_block(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"rec1": _init_rec_layer(cfg, k1),
                    "rec2": _init_rec_layer(cfg, k2),
                    "attn": _init_local_attn_layer(cfg, k3)}

        params["blocks"] = jax.vmap(init_block)(bk)
        if tail:
            tk = jax.random.split(keys[3], tail)
            params["tail"] = jax.vmap(
                functools.partial(_init_rec_layer, cfg))(tk)
    elif cfg.family == "audio":
        ek = jax.random.split(keys[2], cfg.encoder_layers)
        dk = jax.random.split(keys[3], cfg.n_layers)
        params["enc_layers"] = jax.vmap(
            functools.partial(_init_enc_layer, cfg))(ek)
        params["layers"] = jax.vmap(
            functools.partial(_init_xdec_layer, cfg))(dk)
        params["enc_norm"] = cm.init_norm(d, cfg.norm_type)
        params["pos_dec"] = 0.01 * jax.random.normal(
            keys[4], (cfg.max_target_len, d), jnp.float32)
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _decoder_layer(cfg: ModelConfig, p: Dict, x: jnp.ndarray, *,
                   positions: jnp.ndarray, cache: Optional[Dict],
                   key: Optional[jax.Array] = None
                   ) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """One pre-norm decoder layer (attention + MLP-or-MoE FFN); `key`
    seeds the CIM noise model of the layer's projections (distinct folds
    for the attention and FFN banks)."""
    cim = cfg.cim
    k_attn = k_ffn = None
    if key is not None:
        k_attn, k_ffn = jax.random.fold_in(key, 0), jax.random.fold_in(key, 1)
    h = cm.apply_norm(p["ln1"], x, cfg.norm_type)
    attn_out, new_kv = cm.attention_block(
        p["attn"], h, _attn_cfg(cfg, window=cfg.sliding_window), cim,
        positions=positions, cache=None if cache is None else cache["kv"],
        key=k_attn)
    x = x + attn_out
    h = cm.apply_norm(p["ln2"], x, cfg.norm_type)
    if cfg.family == "moe":
        ffn_out, aux = moe_block(
            p["moe"], h, n_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
            capacity_factor=cfg.moe_capacity_factor, cim=cim, act=cfg.mlp_act,
            key=k_ffn)
    else:
        ffn_out = cm.mlp_block(p["mlp"], h, cim, cfg.mlp_act, key=k_ffn)
        aux = 0.0
    x = x + ffn_out
    new_cache = None if cache is None else {"kv": new_kv}
    return x, new_cache, jnp.asarray(aux, jnp.float32)


def _ssm_layer(cfg: ModelConfig, p: Dict, x, *, positions, cache,
               key: Optional[jax.Array] = None):
    h = cm.apply_norm(p["ln1"], x, cfg.norm_type)
    out, new_state = m2.mamba2_layer(
        p["mixer"], h, cfg, cfg.cim,
        state=None if cache is None else cache["ssm"])
    new_cache = None if cache is None else {"ssm": new_state}
    return x + out, new_cache, jnp.float32(0.0)


def _rec_layer(cfg: ModelConfig, p: Dict, x, *, cache):
    h = cm.apply_norm(p["ln1"], x, cfg.norm_type)
    out, new_state = rg.rglru_block(
        p["rec"], h, cfg.cim, state=None if cache is None else cache["rec"])
    x = x + out
    h = cm.apply_norm(p["ln2"], x, cfg.norm_type)
    x = x + cm.mlp_block(p["mlp"], h, cfg.cim, cfg.mlp_act)
    return x, (None if cache is None else {"rec": new_state})


def _local_attn_layer(cfg: ModelConfig, p: Dict, x, *, positions, cache):
    h = cm.apply_norm(p["ln1"], x, cfg.norm_type)
    out, new_kv = cm.attention_block(
        p["attn"], h, _attn_cfg(cfg, window=cfg.local_window), cfg.cim,
        positions=positions, cache=None if cache is None else cache["kv"])
    x = x + out
    h = cm.apply_norm(p["ln2"], x, cfg.norm_type)
    x = x + cm.mlp_block(p["mlp"], h, cfg.cim, cfg.mlp_act)
    return x, (None if cache is None else {"kv": new_kv})


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def _scan_stack(layer_fn, stacked_params, x, cache, remat: bool,
                policy: str = "full"):
    """lax.scan over stacked layer params (+ optionally stacked cache)."""
    if remat and policy == "dots":
        fn = jax.checkpoint(
            layer_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat:
        fn = jax.checkpoint(layer_fn)
    else:
        fn = layer_fn

    def body(carry, xs):
        x, aux = carry
        p, c = xs
        new_x, new_c, a = fn(p, x, c)
        return (new_x.astype(x.dtype), aux + a), new_c

    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (stacked_params, cache))
    return x, new_cache, aux


def _decoder_stack(cfg: ModelConfig, params, x, positions, cache, key=None):
    layer = {"dense": _decoder_layer, "moe": _decoder_layer,
             "vlm": _decoder_layer, "ssm": _ssm_layer}[cfg.family]

    if key is None:
        def f(p, x, c):
            return layer(cfg, p, x, positions=positions, cache=c)

        return _scan_stack(f, params["layers"], x, cache, cfg.remat,
                           cfg.remat_policy)

    # noise-keyed run: fold a distinct key per layer index (the scan body
    # sees a traced index, so one trace covers every layer)
    n_layers = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]

    def f_keyed(px, x, c):
        p, idx = px
        return layer(cfg, p, x, positions=positions, cache=c,
                     key=jax.random.fold_in(key, idx))

    return _scan_stack(f_keyed, (params["layers"],
                                 jnp.arange(n_layers, dtype=jnp.int32)),
                       x, cache, cfg.remat, cfg.remat_policy)


def _hybrid_stack(cfg: ModelConfig, params, x, positions, cache):
    def block_fn(p, x, c):
        c1 = None if c is None else c["rec1"]
        c2 = None if c is None else c["rec2"]
        c3 = None if c is None else c["attn"]
        x, nc1 = _rec_layer(cfg, p["rec1"], x, cache=c1)
        x, nc2 = _rec_layer(cfg, p["rec2"], x, cache=c2)
        x, nc3 = _local_attn_layer(cfg, p["attn"], x,
                                   positions=positions, cache=c3)
        nc = None if c is None else {"rec1": nc1, "rec2": nc2, "attn": nc3}
        return x, nc, jnp.float32(0.0)

    bc = None if cache is None else cache["blocks"]
    x, new_bc, aux = _scan_stack(block_fn, params["blocks"], x, bc, cfg.remat)

    new_tail = None
    if "tail" in params:
        def tail_fn(p, x, c):
            x, nc = _rec_layer(cfg, p, x, cache=c)
            return x, nc, jnp.float32(0.0)
        tc = None if cache is None else cache["tail"]
        x, new_tail, _ = _scan_stack(tail_fn, params["tail"], x, tc, cfg.remat)

    new_cache = None if cache is None else {"blocks": new_bc, "tail": new_tail}
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# public forward passes
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params, tokens: jnp.ndarray) -> jnp.ndarray:
    """Token-id lookup into the (sharded) embedding table, cast to the
    model compute dtype."""
    emb = shard(params["embed"], TP, None)
    x = emb[tokens].astype(_dtype(cfg))
    return shard(x, BATCH, None, None)


def lm_logits(cfg: ModelConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    """Final norm + LM head (tied embedding, bypass-mode lm_head, or
    deploy-quantized serving weights — always digital, see DESIGN.md)."""
    x = cm.apply_norm(params["final_norm"], x, cfg.norm_type)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(x.dtype)
    elif "w" in params["lm_head"]:
        # lm_head stays in bypass mode (DESIGN.md: quality-critical layer)
        logits = x @ params["lm_head"]["w"].astype(x.dtype)
    else:   # deploy-quantized serving weights
        head = params["lm_head"]
        logits = x @ (head["w_q"].astype(x.dtype)
                      * head["w_scale"].astype(x.dtype))
    return shard(logits, BATCH, None, TP)


def forward(cfg: ModelConfig, params, tokens: jnp.ndarray, *,
            positions: Optional[jnp.ndarray] = None,
            cache: Optional[Dict] = None,
            prefix_embeds: Optional[jnp.ndarray] = None,
            encoder_frames: Optional[jnp.ndarray] = None,
            key: Optional[jax.Array] = None
            ) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (logits, new_cache, aux_loss).

    tokens (B, S); positions default arange (no cache) / cache index offset.
    vlm: prefix_embeds (B, P, D) prepended.  audio: encoder_frames (B,T,D)
    run through the encoder (train/prefill) — for cached decode the cross
    KV lives in the cache instead.  `key` seeds the CIM noise model of the
    projections (decoder-stack families only; one fold per layer).
    """
    if key is not None and cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"noise-keyed forward is not wired for family {cfg.family!r}")
    b, s = tokens.shape
    x = embed_tokens(cfg, params, tokens)

    if cfg.family == "vlm" and prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        s = x.shape[1]

    inner_cache = None if cache is None else cache["layers"]
    if positions is None:
        if cache is not None:
            positions = cache["pos"] + jnp.arange(s)
        else:
            positions = jnp.arange(s)

    if cfg.family == "audio":
        logits, new_inner, aux = _audio_forward(
            cfg, params, x, positions, inner_cache, encoder_frames)
    elif cfg.family == "hybrid":
        x, new_inner, aux = _hybrid_stack(cfg, params, x, positions,
                                          inner_cache)
        logits = lm_logits(cfg, params, x)
    else:
        x, new_inner, aux = _decoder_stack(cfg, params, x, positions,
                                           inner_cache, key=key)
        logits = lm_logits(cfg, params, x)
    new_cache = (None if cache is None
                 else {"pos": cache["pos"] + s, "layers": new_inner})
    return logits, new_cache, aux


def _audio_forward(cfg, params, x, positions, cache, encoder_frames):
    """Whisper backbone.  Modes:
       * train / prefill : encoder_frames given — run the encoder, compute
         fresh cross K/V (stored into the cache if one is passed);
       * cached decode   : encoder_frames None — use cache[...]["xkv"]."""
    pos_emb = params["pos_dec"]
    pos = jnp.clip(positions, 0, cfg.max_target_len - 1)
    x = x + pos_emb[pos].astype(x.dtype)

    enc = None
    if encoder_frames is not None:
        enc = encoder_frames.astype(x.dtype)
        enc = enc + _sinusoid(enc.shape[1], cfg.d_model).astype(x.dtype)
        enc_pos = jnp.arange(enc.shape[1])

        def enc_fn(p, h, c):
            hh = cm.apply_norm(p["ln1"], h, cfg.norm_type)
            out, _ = cm.attention_block(
                p["attn"], hh, _attn_cfg(cfg, causal=False, use_rope=False),
                cfg.cim, positions=enc_pos)
            h = h + out
            hh = cm.apply_norm(p["ln2"], h, cfg.norm_type)
            h = h + cm.mlp_block(p["mlp"], hh, cfg.cim, cfg.mlp_act)
            return h, None, jnp.float32(0.0)

        enc, _, _ = _scan_stack(enc_fn, params["enc_layers"], enc, None,
                                cfg.remat)
        enc = cm.apply_norm(params["enc_norm"], enc, cfg.norm_type)

    def dec_fn(p, h, c):
        hh = cm.apply_norm(p["ln1"], h, cfg.norm_type)
        out, nkv = cm.attention_block(
            p["attn"], hh, _attn_cfg(cfg, use_rope=False), cfg.cim,
            positions=positions, cache=None if c is None else c["kv"])
        h = h + out
        hh = cm.apply_norm(p["ln_x"], h, cfg.norm_type)
        xkv_in = None if (c is None or enc is not None) else c["xkv"]
        out, nxkv = cm.attention_block(
            p["xattn"], hh, _attn_cfg(cfg, causal=False, use_rope=False),
            cfg.cim, positions=positions, x_kv=enc,
            cross_kv=xkv_in, cache={} if c is not None else None)
        h = h + out
        hh = cm.apply_norm(p["ln2"], h, cfg.norm_type)
        h = h + cm.mlp_block(p["mlp"], hh, cfg.cim, cfg.mlp_act)
        nc = None if c is None else {"kv": nkv, "xkv": nxkv}
        return h, nc, jnp.float32(0.0)

    x, new_dec, _ = _scan_stack(dec_fn, params["layers"], x, cache,
                                cfg.remat)
    return lm_logits(cfg, params, x), new_dec, jnp.float32(0.0)


def _sinusoid(length: int, channels: int) -> jnp.ndarray:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(channels // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (9.21 / (channels // 2 - 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _kv_cache_len(cfg: ModelConfig, max_len: int, window: int) -> int:
    if window > 0:
        return min(max_len, window)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    """Decode cache pytree: {"pos": scalar, "layers": stacked per-layer}."""
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    g = cfg.n_kv_heads

    def stack(tree, n):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)

    def kv(n, length):
        return stack(cm.init_kv_cache(batch, length, g, hd, dtype), n)

    pos = jnp.array(0, jnp.int32)
    if cfg.family in ("dense", "moe", "vlm"):
        length = _kv_cache_len(cfg, max_len, cfg.sliding_window)
        return {"pos": pos, "layers": {"kv": kv(cfg.n_layers, length)}}
    if cfg.family == "ssm":
        st = m2.init_mamba2_state(batch, cfg.d_model, cfg)
        return {"pos": pos,
                "layers": {"ssm": stack(st, cfg.n_layers)}}
    if cfg.family == "hybrid":
        nb, tail = divmod(cfg.n_layers, 3)
        width = cfg.lru_width or cfg.d_model
        rec = rg.init_rglru_state(batch, width, cfg.conv_width)
        blocks = {"rec1": {"rec": stack(rec, nb)},
                  "rec2": {"rec": stack(rec, nb)},
                  "attn": {"kv": kv(nb, _kv_cache_len(cfg, max_len,
                                                      cfg.local_window))}}
        layers = {"blocks": blocks, "tail": None}
        if tail:
            layers["tail"] = {"rec": stack(rec, tail)}
        return {"pos": pos, "layers": layers}
    if cfg.family == "audio":
        xkv = stack({"k": jnp.zeros((batch, max_len, g, hd), dtype),
                     "v": jnp.zeros((batch, max_len, g, hd), dtype)},
                    cfg.n_layers)
        dec = {"kv": kv(cfg.n_layers, cfg.max_target_len), "xkv": xkv}
        return {"pos": pos, "layers": dec}
    raise ValueError(cfg.family)


def init_slot_cache(cfg: ModelConfig, slots: int, max_len: int,
                    dtype=jnp.bfloat16) -> Dict:
    """Slot-mapped decode cache for in-flight (continuous) batching:
    {"pos": (slots,) per-slot position, "layers": stacked per-layer
    cm.init_slot_kv_cache} — every slot rides its own ring cursor, so
    requests at different sequence offsets decode fused in one batch.
    Attention-cache families only (dense/moe/vlm)."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"slot-mapped decode supports attention-cache families "
            f"(dense/moe/vlm), not {cfg.family!r}")
    hd = cfg.resolved_head_dim
    length = _kv_cache_len(cfg, max_len, cfg.sliding_window)
    kvs = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
        cm.init_slot_kv_cache(slots, length, cfg.n_kv_heads, hd, dtype))
    return {"pos": jnp.zeros((slots,), jnp.int32), "layers": {"kv": kvs}}


def write_slot_cache(cache: Dict, slot: int, prefill: Dict) -> Dict:
    """Admit a prefilled request into slot `slot` of a slot-mapped cache:
    scatter the batch-1 `prefill` cache's K/V rings, per-layer cursors and
    position into the slot (gather-free; every other slot untouched)."""
    pkv, kv = prefill["layers"]["kv"], cache["layers"]["kv"]
    new = {"k": kv["k"].at[:, slot].set(pkv["k"][:, 0].astype(kv["k"].dtype)),
           "v": kv["v"].at[:, slot].set(pkv["v"][:, 0].astype(kv["v"].dtype)),
           "idx": kv["idx"].at[:, slot].set(pkv["idx"])}
    return {"pos": cache["pos"].at[slot].set(prefill["pos"]),
            "layers": {"kv": new}}


def free_slot_cache(cache: Dict, slot: int) -> Dict:
    """Retire the request in slot `slot`: reset its cursors/position only
    (its K/V rows stay in place until the next admission overwrites them —
    per-row masks keep dead rows invisible to everyone else)."""
    kv = cache["layers"]["kv"]
    return {"pos": cache["pos"].at[slot].set(0),
            "layers": {"kv": {**kv,
                              "idx": kv["idx"].at[:, slot].set(0)}}}
