"""Mamba-2 (SSD, state-space duality, arXiv:2405.21060) in pure JAX.

Training uses the chunked SSD algorithm (quadratic within a chunk, linear
across chunks via a lax.scan state recurrence); decode is the O(1)-per-token
state update.  Heads are tensor-parallel over "model"; the in/out projections
run through the CIM layer like every other GEMM (the SSD inner recurrence
itself is inapplicable to the weight-stationary macro — DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.cim_layers import CIMConfig, cim_linear_apply, init_cim_linear
from repro.models.sharding import BATCH, TP, shard


def ssm_dims(d_model: int, expand: int, headdim: int, d_state: int,
             n_groups: int = 1):
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    conv_ch = d_inner + 2 * n_groups * d_state
    proj_out = 2 * d_inner + 2 * n_groups * d_state + n_heads
    return d_inner, n_heads, conv_ch, proj_out


def init_mamba2_layer(key: jax.Array, d_model: int, *, expand: int,
                      headdim: int, d_state: int, conv_width: int,
                      cim: Optional[CIMConfig] = None,
                      n_groups: int = 1) -> Dict:
    d_inner, n_heads, conv_ch, proj_out = ssm_dims(
        d_model, expand, headdim, d_state, n_groups)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": init_cim_linear(ks[0], d_model, proj_out, cfg=cim),
        "conv_w": 0.1 * jax.random.normal(ks[1], (conv_width, conv_ch)),
        "conv_b": jnp.zeros((conv_ch,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)),
        "D_skip": jnp.ones((n_heads,)),
        "dt_bias": jnp.zeros((n_heads,)),
        "gate_norm": jnp.ones((d_inner,)),
        "out_proj": init_cim_linear(ks[2], d_inner, d_model, cfg=cim),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv.  x (B, L, C), w (W, C).  Returns (y, new_state)
    where state carries the trailing W-1 inputs for decode."""
    width = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    return jax.nn.silu(y + b), xp[:, -(width - 1):, :]


def _segsum(da: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular pairwise sums: out[..., i, j] = sum_{j<t<=i} da[t]."""
    q = da.shape[-1]
    cs = jnp.cumsum(da, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # (..., i, j)
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, *, chunk: int,
                init_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.

    xh (B,L,H,P), dt (B,L,H), a (H,) negative, B/C (B,L,G,N) with G
    broadcastable to H.  Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    bsz, l, h, p = xh.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    pad = (-l) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lp = l + pad
    nc = lp // chunk
    xc = xh.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    Bc = jnp.repeat(B.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    Cc = jnp.repeat(C.reshape(bsz, nc, chunk, g, n), rep, axis=3)

    da = dtc * a[None, None, None, :]                   # (B,nc,Q,H) log decay
    da = jnp.moveaxis(da, -1, 2)                        # (B,nc,H,Q)
    seg = _segsum(da)                                   # (B,nc,H,Q,Q)
    decay = jnp.exp(seg)

    # intra-chunk (diagonal blocks)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc) * decay
    scores = scores * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores, xc)

    # chunk-final states
    cum = jnp.cumsum(da, axis=-1)                       # (B,nc,H,Q)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)         # (B,nc,H,Q)
    su = Bc * (dtc * jnp.moveaxis(decay_to_end, 2, -1))[..., None]
    states = jnp.einsum("bcqhn,bcqhp->bchpn", su, xc)   # (B,nc,H,P,N)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[..., -1])                 # (B,nc,H)

    def step(carry, inp):
        s_c, d_c = inp
        new = carry * d_c[..., None, None] + s_c
        return new, carry                               # emit state *before*

    init = (jnp.zeros((bsz, h, p, n), xh.dtype) if init_state is None
            else init_state)
    final, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (B,nc,H,P,N)

    # off-diagonal contribution: decay from chunk start
    in_decay = jnp.exp(cum)                             # (B,nc,H,Q)
    y_off = jnp.einsum("bcqhn,bchpn->bcqhp",
                       Cc * jnp.moveaxis(in_decay, 2, -1)[..., None],
                       prev_states)
    y = (y_diag + y_off).reshape(bsz, lp, h, p)[:, :l]
    return y, final


def ssd_naive(xh, dt, a, B, C, init_state=None):
    """O(L) recurrence oracle for tests."""
    bsz, l, h, p = xh.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Br = jnp.repeat(B, rep, axis=2)
    Cr = jnp.repeat(C, rep, axis=2)
    s = (jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None
         else init_state.astype(jnp.float32))
    ys = []
    for t in range(l):
        dec = jnp.exp(dt[:, t] * a[None, :])            # (B,H)
        s = s * dec[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt[:, t], Br[:, t], xh[:, t])
        ys.append(jnp.einsum("bhn,bhpn->bhp", Cr[:, t], s))
    return jnp.stack(ys, axis=1), s


def mamba2_layer(params: Dict, x: jnp.ndarray, cfg, cim: CIMConfig, *,
                 state: Optional[Dict] = None
                 ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """One Mamba-2 block.  x (B, L, D).  state: {"ssm": (B,H,P,N),
    "conv": (B,W-1,C)} for decode."""
    bsz, l, d_model = x.shape
    d_inner, n_heads, conv_ch, _ = ssm_dims(
        d_model, cfg.ssm_expand, cfg.ssm_headdim, cfg.ssm_state)
    g, n, p = 1, cfg.ssm_state, cfg.ssm_headdim

    zxbcdt = cim_linear_apply(params["in_proj"], x, cim)
    zxbcdt = shard(zxbcdt, BATCH, None, TP)
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, d_inner + conv_ch], axis=-1)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    xc, B, C = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    xh = xc.reshape(bsz, l, n_heads, p)
    xh = shard(xh, BATCH, None, TP, None)
    B = B.reshape(bsz, l, g, n)
    C = C.reshape(bsz, l, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])

    if state is None:
        y, final = ssd_chunked(xh.astype(jnp.float32), dt, a,
                               B.astype(jnp.float32), C.astype(jnp.float32),
                               chunk=cfg.ssm_chunk)
        new_state = None
    else:
        # decode: single-step state update (l == 1)
        s = state["ssm"]
        dec = jnp.exp(dt[:, 0] * a[None, :])
        Br = jnp.repeat(B[:, 0], n_heads // g, axis=1)
        Cr = jnp.repeat(C[:, 0], n_heads // g, axis=1)
        s = s * dec[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt[:, 0], Br.astype(jnp.float32),
            xh[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhn,bhpn->bhp", Cr.astype(jnp.float32), s)[:, None]
        final = s
        new_state = {"ssm": final, "conv": new_conv}
    y = y + xh.astype(jnp.float32) * params["D_skip"][None, None, :, None]
    y = y.reshape(bsz, l, d_inner)

    # gated RMSNorm then out-projection
    gated = y * jax.nn.silu(z.astype(jnp.float32))
    gn = gated * jax.lax.rsqrt(jnp.mean(gated * gated, -1, keepdims=True)
                               + 1e-6) * params["gate_norm"]
    out = cim_linear_apply(params["out_proj"], gn.astype(x.dtype), cim)
    return shard(out, BATCH, None, None), new_state


def init_mamba2_state(batch: int, d_model: int, cfg, dtype=jnp.float32) -> Dict:
    d_inner, n_heads, conv_ch, _ = ssm_dims(
        d_model, cfg.ssm_expand, cfg.ssm_headdim, cfg.ssm_state)
    return {
        "ssm": jnp.zeros((batch, n_heads, cfg.ssm_headdim, cfg.ssm_state),
                         jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }
