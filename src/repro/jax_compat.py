"""Version-tolerant aliases for JAX APIs that moved between releases.

The repo targets the newest stable JAX but must run on the pinned container
toolchain (0.4.x).  Every symbol here resolves the modern spelling when it
exists and otherwise falls back to the legacy one with identical semantics:

  * ``make_mesh``          — ``axis_types=`` kwarg appeared after 0.4.x; the
    fallback builds the same Auto-axes mesh without it.
  * ``get_abstract_mesh``  — newer JAX tracks an ambient abstract mesh; on
    0.4.x the ambient mesh is the thread-resource physical mesh set by the
    ``with mesh:`` context (same ``.empty``/``.axis_names``/``.shape`` duck
    type, which is all our sharding helpers read).
  * ``set_mesh``           — ``jax.set_mesh(mesh)`` vs the legacy ``with
    mesh:`` context manager (``Mesh`` is itself a context manager).
  * ``shard_map``          — ``jax.shard_map(..., check_vma=)`` vs
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``.
  * ``TPUCompilerParams``  — ``pltpu.CompilerParams`` was renamed from
    ``pltpu.TPUCompilerParams``; kernels take whichever exists.
"""
from __future__ import annotations

import contextlib
from typing import Sequence

import jax
from jax.experimental.pallas import tpu as _pltpu

# --- pallas compiler params -------------------------------------------------

TPUCompilerParams = getattr(_pltpu, "CompilerParams", None) or \
    getattr(_pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Build TPU pallas compiler params under either class name."""
    return TPUCompilerParams(**kwargs)


# --- mesh construction ------------------------------------------------------

def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where supported."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(axis_type.Auto,) * len(axis_names))
        except TypeError:  # pragma: no cover - very old make_mesh
            pass
    return jax.make_mesh(axis_shapes, axis_names)


# --- ambient mesh -----------------------------------------------------------

def get_abstract_mesh():
    """The ambient mesh (possibly empty), whatever this JAX calls it."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src.mesh import thread_resources
    return thread_resources.env.physical_mesh


def set_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh."""
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        ctx = fn(mesh)
        # jax.set_mesh is itself a context manager in recent releases
        if hasattr(ctx, "__enter__"):
            return ctx
        return contextlib.nullcontext(mesh)
    return mesh  # legacy: Mesh is a context manager


# --- shard_map --------------------------------------------------------------

def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
