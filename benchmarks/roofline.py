"""Roofline analysis from the dry-run JSONs (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs / peak_FLOPs            (per device)
    memory term     = HLO_bytes / HBM_bw
    collective term = collective_bytes / (links * link_bw)
plus MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) and the useful-
compute ratio.  Hardware: TPU v5e-class, 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (hw.TPU_V5E).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import all_archs, get_config
from repro.configs.base import SHAPES
from repro.core.hw import EFFECTIVE_LINKS, TPU_V5E
from repro.models import transformer as tf


def model_flops(arch_mod: str, shape_name: str) -> float:
    """Analytic useful FLOPs for the whole step (all devices), fwd+bwd for
    train (x3 of fwd), fwd for prefill, per-token for decode."""
    import jax
    import numpy as np
    cfg = get_config(arch_mod)
    shape = SHAPES[shape_name]
    params = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))

    def leaf_count(tree):
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))

    n_total = leaf_count(params)
    # active params: for MoE, experts beyond top_k are inactive per token
    if cfg.family == "moe":
        moe_leaves = jax.tree_util.tree_map_with_path(
            lambda p, l: l if any("moe" in str(getattr(k, "key", ""))
                                  for k in p) else None, params)
        n_moe = sum(int(np.prod(l.shape))
                    for l in jax.tree.leaves(moe_leaves) if l is not None)
        # router + shared stay active; experts scale by top_k / E
        n_active = (n_total - n_moe) + n_moe * cfg.moe_top_k / cfg.moe_experts
    else:
        n_active = n_total

    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def load_cells(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(cell: Dict) -> Optional[Dict]:
    if cell.get("status") != "ok":
        return None
    chips = 512 if cell["mesh"] == "multi" else 256
    flops_dev = cell.get("hlo_flops", 0.0)
    bytes_dev = cell.get("hlo_bytes", 0.0)
    coll_dev = cell.get("collective_bytes", 0.0)
    t_compute = flops_dev / TPU_V5E.peak_bf16_flops
    t_memory = bytes_dev / TPU_V5E.hbm_bw
    t_coll = coll_dev / (EFFECTIVE_LINKS * TPU_V5E.ici_bw_per_link)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())

    from repro.launch.dryrun import ALIAS
    arch_mod = ALIAS.get(cell["arch"], cell["arch"])
    mf = model_flops(arch_mod, cell["shape"])
    mf_dev = mf / chips
    useful_ratio = mf_dev / flops_dev if flops_dev else 0.0
    # roofline fraction: time the useful FLOPs would take at peak vs the
    # bound imposed by the dominant term
    frac = (mf_dev / TPU_V5E.peak_bf16_flops) / bound if bound else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "cim": cell.get("cim_mode", "bypass"),
        "t_compute": t_compute, "t_memory": t_memory, "t_collective": t_coll,
        "dominant": dominant, "model_flops": mf,
        "useful_ratio": useful_ratio, "roofline_frac": frac,
        "step_time_bound_s": bound,
    }


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | collective (s)"
           " | dominant | useful/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']:.3f} | {r['t_memory']:.3f} "
            f"| {r['t_collective']:.3f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} |")
    return hdr + "\n".join(lines)


def main(dryrun_dir: str = "experiments/dryrun",
         out: str = "experiments/roofline.json"):
    rows = []
    for cell in load_cells(dryrun_dir):
        r = roofline_row(cell)
        if r is not None:
            rows.append(r)
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(markdown_table(rows))
    return rows


if __name__ == "__main__":
    main()
