"""Fig. 8(b): DP linearity error vs DP duration (settling model)."""
import time

from repro.core.noise_model import NoiseConfig, settle_fraction
from repro.core.hw import DEFAULT_MACRO
from repro.core import digital_ref as dr


def run():
    noise = NoiseConfig()
    cfg = DEFAULT_MACRO
    rows = []
    for t_dp in (2.0, 3.0, 5.0, 7.0, 10.0):
        # worst-case: full array, max dp -> deviation alpha*N*VDDL
        frac = settle_fraction(cfg.n_units, t_dp, noise)
        v_full = cfg.swing_efficiency(cfg.n_units) * cfg.vddl
        err_v = (1 - frac) * v_full
        lsb = cfg.alpha_adc() * cfg.vddh / 2 ** 7
        rows.append((t_dp, err_v / lsb))
    return rows


def main():
    t0 = time.time()
    rows = run()
    us = (time.time() - t0) * 1e6
    for t_dp, err_lsb in rows:
        print(f"fig8_settling_tdp{t_dp:.0f}ns,{us/len(rows):.1f},"
              f"inl_{err_lsb:.2f}lsb")
    # paper: T_dp = 5ns keeps INL below ~1 LSB
    err5 = [e for t, e in rows if t == 5.0][0]
    assert err5 < 1.2, err5
    print(f"fig8_summary,0,inl_at_5ns_{err5:.2f}lsb(paper<1)")


if __name__ == "__main__":
    main()
