"""Fig. 10 (MBIW charge-injection / leakage) and Fig. 20-21 (distortion vs
C_in, RMS vs supply) behavioural checks."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import digital_ref as dr
from repro.core import noise_model as nm
from repro.core.cim_macro import cim_macro_forward
from repro.core.hw import CIMMacroConfig, DEFAULT_MACRO
from repro.core.noise_model import NO_NOISE, NoiseConfig


def run_fig10():
    """Charge-injection error map: bounded by ~1 LSB8, bilinear in
    (V_in, V_acc) with a zero-error locus."""
    noise = NoiseConfig()
    cfg = DEFAULT_MACRO
    vs = jnp.linspace(0.1, 0.7, 13)
    grid = np.asarray([[float(nm.charge_injection_error(
        jnp.float32(vi), jnp.float32(va), noise, cfg))
        for va in vs] for vi in vs])
    lsb8 = nm.lsb8_volts(cfg)
    return float(np.abs(grid).max() / lsb8), float(np.abs(grid).min())


def run_fig20(c_in: int):
    """Zero-valued-DP distortion under clustered weights (paper's stress
    pattern): inputs zero-complement, half +1 / half -1 weights."""
    k = c_in * 9
    x = jnp.full((1, k), 255, jnp.int32)
    w = jnp.concatenate([jnp.ones((k // 2, 8)), -jnp.ones((k - k // 2, 8))])
    planes = dr.encode_weight_planes(w.astype(jnp.int32), 1)
    code = cim_macro_forward(x, planes, r_in=8, r_out=8, gamma=1.0,
                             noise=NoiseConfig(), key=jax.random.PRNGKey(0))
    return float(jnp.mean(jnp.abs(code.astype(jnp.float32) - 128.0)))


def main():
    t0 = time.time()
    max_lsb, _ = run_fig10()
    print(f"fig10_charge_injection,{(time.time()-t0)*1e6:.0f},"
          f"max_{max_lsb:.2f}lsb8(paper<=1)")
    assert max_lsb < 2.0
    for c_in in (4, 16, 64, 128):
        t0 = time.time()
        inl = run_fig20(c_in)
        print(f"fig20_zero_dp_cin{c_in},{(time.time()-t0)*1e6:.0f},"
              f"inl_{inl:.1f}codes")


if __name__ == "__main__":
    main()
