"""Fig. 3(b): pseudo-MNIST MLP test error vs (ADC precision, gamma
precision, adaptive swing) — the paper's distribution-aware reshaping claim.

NOTE: offline container -> procedural pseudo-MNIST (DESIGN.md §8); compare
relative trends, not absolute MNIST numbers.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cim_layers import CIMConfig
from repro.data.pseudo_mnist import make_dataset
from repro.models.cnn import init_mlp, mlp_forward
from repro.optim import AdamWConfig, adamw_init, adamw_update


def train_eval(cim: CIMConfig, seed=0, epochs=5, dims=(784, 128, 64, 10)):
    xtr, ytr, xte, yte = make_dataset(n_train=2048, n_test=512, seed=seed)
    params = init_mlp(jax.random.PRNGKey(seed), dims=dims, cim=cim)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=2e-3, weight_decay=0.0)

    @jax.jit
    def step(params, opt, xb, yb):
        def loss(p):
            lp = jax.nn.log_softmax(mlp_forward(p, xb, cim))
            return -jnp.mean(jnp.take_along_axis(lp, yb[:, None], 1))
        l, g = jax.value_and_grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, ocfg)
        return params, opt, l

    xs, ys = jnp.asarray(xtr.reshape(-1, 784)), jnp.asarray(ytr)
    for _ in range(epochs):
        for i in range(0, len(xs), 256):
            params, opt, _ = step(params, opt, xs[i:i + 256], ys[i:i + 256])
    logits = mlp_forward(params, jnp.asarray(xte.reshape(-1, 784)), cim)
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yte)))


def main():
    cases = [
        ("fp_baseline", CIMConfig(mode="bypass")),
        ("adc8_gamma_free_adaptive", CIMConfig(mode="fakequant")),
        ("adc8_gamma0b_adaptive", CIMConfig(mode="fakequant", gamma_bits=0)),
        ("adc8_gamma2b_adaptive", CIMConfig(mode="fakequant", gamma_bits=2)),
        ("adc8_gamma3b_adaptive", CIMConfig(mode="fakequant", gamma_bits=3)),
        ("adc8_gamma3b_fixed", CIMConfig(mode="fakequant", gamma_bits=3,
                                         adaptive_swing=False)),
        ("adc6_gamma3b_adaptive", CIMConfig(mode="fakequant", gamma_bits=3,
                                            r_out=6)),
        ("adc4_gamma3b_adaptive", CIMConfig(mode="fakequant", gamma_bits=3,
                                            r_out=4)),
    ]
    results = {}
    for name, cim in cases:
        t0 = time.time()
        acc = train_eval(cim)
        us = (time.time() - t0) * 1e6
        results[name] = acc
        print(f"fig3b_{name},{us:.0f},err{100*(1-acc):.1f}%", flush=True)
    # paper's qualitative claims on this figure:
    #  (i) unity gain (0b gamma) is much worse than learned gamma
    #  (ii) adaptive swing recovers what fixed swing loses at equal gamma bits
    assert results["adc8_gamma3b_adaptive"] >= results["adc8_gamma0b_adaptive"]
    assert results["adc8_gamma3b_adaptive"] >= results["adc8_gamma3b_fixed"] - 0.02
    print("fig3b_claims,0,checked")


if __name__ == "__main__":
    main()
