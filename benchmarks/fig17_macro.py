"""Fig. 17/19: measured-style macro transfer function + calibration gain."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import digital_ref as dr
from repro.core import noise_model as nm
from repro.core.calibration import residual_offsets
from repro.core.cim_macro import cim_macro_forward
from repro.core.hw import DEFAULT_MACRO
from repro.core.noise_model import NO_NOISE, NoiseConfig


def transfer_function(gamma: float):
    """Sweep weights from all-0 to all-1 with zero inputs' complement trick:
    the paper sweeps stored weights bottom-to-top with inputs at max."""
    k = 128  # 16 channels in FC mode
    n = 32
    steps = 33
    cfg = DEFAULT_MACRO
    codes = []
    x = jnp.full((1, k), 255, jnp.int32)
    for i in range(steps):
        n_on = int(k * i / (steps - 1))
        w = jnp.concatenate([jnp.ones((n_on, n)), -jnp.ones((k - n_on, n))])
        planes = dr.encode_weight_planes(w.astype(jnp.int32), 1)
        c = cim_macro_forward(x, planes, r_in=8, r_out=8, gamma=gamma,
                              noise=NO_NOISE)
        codes.append(float(jnp.mean(c.astype(jnp.float32))))
    return np.asarray(codes)


def run_calibration_claim():
    """Fig. 19: spatial deviation before/after calibration (in 8b LSB)."""
    key = jax.random.PRNGKey(0)
    noise = NoiseConfig()
    raw = nm.sample_sa_offsets(key, 256, noise)
    res = residual_offsets(raw)
    lsb = DEFAULT_MACRO.alpha_adc() * DEFAULT_MACRO.vddh / 2 ** 7
    before = float(jnp.max(jnp.abs(raw)) / lsb)
    after = float(jnp.percentile(jnp.abs(res), 95) / lsb)
    return before, after


def main():
    t0 = time.time()
    tf1 = transfer_function(1.0)
    us = (time.time() - t0) * 1e6
    rng = tf1.max() - tf1.min()
    mono = bool(np.all(np.diff(tf1) >= -1.0))
    print(f"fig17_transfer_gamma1,{us:.0f},range{rng:.0f}codes_monotone{mono}")
    tf4 = transfer_function(4.0)
    print(f"fig17_transfer_gamma4,0,range{tf4.max()-tf4.min():.0f}codes")
    before, after = run_calibration_claim()
    print(f"fig19_calibration,0,before{before:.1f}lsb_after{after:.1f}lsb"
          f"(paper_17to2)")


if __name__ == "__main__":
    main()
