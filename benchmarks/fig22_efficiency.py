"""Fig. 22/23 + Table I: energy-efficiency / throughput trade-offs.

`run_engine_precision_sweep` goes beyond the closed-form model: it plans and
*executes* a 2-layer network through the precision-scalable runtime at every
r_in operating point (Pallas interpret mode), verifies bit-exactness against
the digital reference, and reports the modeled throughput/efficiency of the
executed schedule — the paper's Fig. 22 scaling argument, end to end."""
import time

from repro.core.mapping import LayerSpec
from repro.perfmodel import AcceleratorPerfModel, EnergyModel, schedule_report
from repro.perfmodel.macro_perf import cim_eval_time_ns


def run_fig22a():
    """EE vs throughput for (r_in, r_out) combos, 1b weights, C_in=128."""
    em = EnergyModel()
    rows = []
    for r_in, r_out in ((1, 1), (2, 2), (4, 4), (8, 8), (1, 8), (8, 1)):
        spec = LayerSpec(m=1, k=1152, n=256, r_in=r_in, r_w=1, r_out=r_out,
                         kernel=(3, 3))
        ee = em.macro_tops_per_watt(spec)            # raw POPS/W
        tp = em.macro_throughput_tops(spec)
        rows.append((r_in, r_out, ee / 1e3, tp))
    return rows


def run_fig22b():
    """8b energy/op vs C_in: ADC amortization."""
    em = EnergyModel()
    rows = []
    for c_in in (4, 16, 64, 128):
        spec = LayerSpec(m=1, k=c_in * 9, n=256, r_in=8, r_w=1, r_out=8,
                         kernel=(3, 3))
        from repro.core.mapping import map_layer
        mp = map_layer(spec)
        e = em.macro_energy_pj(spec, mp)
        ops = em.macro_ops_per_eval(spec, mp)
        rows.append((c_in, e / ops * 1e3))            # fJ/op
    return rows


def run_fig23_system():
    """System-level EE with I/O transfer overheads (Eqs. 8-10)."""
    ap = AcceleratorPerfModel()
    rows = []
    for c_in in (4, 16, 64, 128):
        spec = LayerSpec(m=32 * 32, k=c_in * 9, n=64, r_in=8, r_w=4,
                         r_out=8, kernel=(3, 3))
        rep = ap.layer_report(spec)
        rows.append((c_in, rep["system_tops_per_w_8b"],
                     rep["macro_fraction"], rep["tops_8b_norm"]))
    return rows


def run_engine_precision_sweep(m=32, iters=2):
    """Execute a 2-layer network per r_in through the runtime engine."""
    import jax
    import jax.numpy as jnp
    from repro.runtime import CIMInferenceEngine

    rows = []
    for r_in in (1, 2, 4, 8):
        r_w = min(r_in, 4)
        specs = [LayerSpec(m=m, k=576, n=64, r_in=r_in, r_w=r_w, r_out=8,
                           kernel=(3, 3)),
                 LayerSpec(m=m, k=64, n=32, r_in=r_in, r_w=r_w, r_out=8)]
        eng = CIMInferenceEngine(specs)
        params = eng.init_params(jax.random.PRNGKey(r_in))
        x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(r_in + 8),
                                          (m, 576)))
        y = eng(params, x).block_until_ready()
        t0 = time.time()
        for _ in range(iters):
            eng(params, x).block_until_ready()
        wall_us = (time.time() - t0) / iters * 1e6
        exact = bool(jnp.all(y == eng.reference(params, x)))
        rep = eng.perf_report()
        rows.append((r_in, r_w, wall_us, rep["total"]["tops"],
                     rep["total"]["tops_per_w"], exact))
    return rows


def run_precision_ladder(n_trials=2, batch=4):
    """Fig. 22 through the accuracy-budget planner: calibrate a per-layer
    sensitivity profile on a small chain, plan the quality/balanced/
    throughput operating points, and report each point's projected
    efficiency next to its predicted quality delta — the workload-
    adaptive serving trade-off curve (repro.precision)."""
    from repro.precision import calibrate, plan_ladder
    from repro.runtime.engine import EngineConfig

    specs = (LayerSpec(m=8, k=128, n=64, r_in=8, r_w=4),
             LayerSpec(m=8, k=64, n=32, r_in=8, r_w=4),
             LayerSpec(m=8, k=32, n=16, r_in=8, r_w=4))
    cfg = EngineConfig()
    prof = calibrate(specs, cfg, n_trials=n_trials, batch=batch,
                     label="fig22-ladder")
    ladder = plan_ladder(prof, specs, cfg)
    rows = []
    for name, rep in ladder.report().items():
        rows.append((name, rep["assignment"], rep["predicted_delta"],
                     rep["tops_per_w"]))
    return rows


def main():
    t0 = time.time()
    for r_in, r_out, pops, tops in run_fig22a():
        print(f"fig22a_ee_tp_rin{r_in}_rout{r_out},0,"
              f"{pops:.2f}POPSpW_{tops:.2f}TOPS")
    for c_in, fj in run_fig22b():
        print(f"fig22b_energy_cin{c_in},0,{fj:.0f}fJ/op")
    for c_in, ee, frac, tops in run_fig23_system():
        print(f"fig23_system_cin{c_in},0,{ee:.1f}TOPSpW8b"
              f"_macrofrac{frac:.2f}_{tops:.3f}TOPS")
    for r_in, r_w, us, tops, tpw, exact in run_engine_precision_sweep():
        print(f"fig22_engine_rin{r_in}_rw{r_w},{us:.0f},"
              f"{tops:.2f}TOPS_{tpw:.1f}TOPSpW_exact{exact}")
    for name, asg, delta, tpw in run_precision_ladder():
        tag = "-".join(f"{ri}x{rw}" for ri, rw in asg)
        print(f"fig22_ladder_{name},0,"
              f"{tag}_{tpw:.2f}TOPSpW_delta{delta:.4f}")
    us = (time.time() - t0) * 1e6
    print(f"fig22_23_total,{us:.0f},done")


if __name__ == "__main__":
    main()
