"""Fig. 13: DSCI-ADC transfer function, INL/DNL vs gamma (voltage sim)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cim_macro import dsci_adc
from repro.core.hw import DEFAULT_MACRO
from repro.core.noise_model import NO_NOISE, NoiseConfig


def run(gamma: float, noisy: bool = False):
    cfg = DEFAULT_MACRO
    v = jnp.linspace(-cfg.vddl, cfg.vddl, 4096)[:, None]
    code = dsci_adc(v, r_out=8, gamma=jnp.float32(gamma),
                    beta_v=jnp.float32(0.0), sa_offset_v=jnp.zeros((1,)),
                    cfg=cfg, noise=NoiseConfig() if noisy else NO_NOISE,
                    key=jax.random.PRNGKey(0) if noisy else None)
    code = np.asarray(code[:, 0], np.float64)
    # ideal line over the non-clipped region
    lsb_v = cfg.alpha_adc() * cfg.vddh / (gamma * 2.0 ** 7)
    ideal = np.clip(np.floor(128 + np.asarray(v[:, 0]) / lsb_v), 0, 255)
    mask = (ideal > 2) & (ideal < 253)
    inl = np.abs(code - ideal)[mask]
    # DNL from code transition widths
    return float(inl.mean()), float(inl.max())


def main():
    for gamma in (1.0, 2.0, 8.0, 32.0):
        t0 = time.time()
        inl_mean, inl_max = run(gamma, noisy=True)
        us = (time.time() - t0) * 1e6
        print(f"fig13_adc_gamma{gamma:.0f},{us:.0f},"
              f"inl_mean{inl_mean:.2f}_max{inl_max:.2f}lsb")
    # paper: mean INL ~1.1 LSB, peak up to 4.5 LSB at gamma=32
    m1, _ = run(1.0, noisy=True)
    m32, x32 = run(32.0, noisy=True)
    print(f"fig13_summary,0,gamma1_mean{m1:.2f}(paper~1.1)"
          f"_gamma32_max{x32:.1f}(paper~4.5)")


if __name__ == "__main__":
    main()
