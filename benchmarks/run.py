"""Benchmark driver: one harness per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  The roofline analysis
(§Roofline) additionally reads experiments/dryrun/*.json — run
``python -m repro.launch.dryrun --all --mesh both`` first to refresh it.
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (fig3_abn_accuracy, fig6_split_dpl, fig8_settling,
                        fig10_20_nonidealities, fig13_adc, fig17_macro,
                        fig22_efficiency, kernel_bench, table1)


def main() -> None:
    suites = [
        ("fig6_split_dpl", fig6_split_dpl.main),
        ("fig8_settling", fig8_settling.main),
        ("fig10_20_nonidealities", fig10_20_nonidealities.main),
        ("fig13_adc", fig13_adc.main),
        ("fig17_macro", fig17_macro.main),
        ("fig22_efficiency", fig22_efficiency.main),
        ("table1", table1.main),
        ("kernel_bench", kernel_bench.main),
        ("fig3_abn_accuracy", fig3_abn_accuracy.main),   # slowest last
    ]
    failures = 0
    for name, fn in suites:
        try:
            fn()
        except Exception:
            failures += 1
            print(f"{name},0,FAILED", flush=True)
            traceback.print_exc()
    # roofline table if dry-run artifacts exist
    try:
        import glob
        if glob.glob("experiments/dryrun/*.json"):
            from benchmarks import roofline
            rows = []
            for cell in roofline.load_cells():
                r = roofline.roofline_row(cell)
                if r is not None:
                    rows.append(r)
            fr = [r["roofline_frac"] for r in rows]
            print(f"roofline_cells,0,n{len(rows)}_fracmin{min(fr):.3f}"
                  f"_fracmax{max(fr):.3f}")
    except Exception:
        print("roofline,0,FAILED")
        traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
