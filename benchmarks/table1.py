"""Table I: headline metrics of this reproduction vs the paper's column."""
import time

from repro.core.hw import DEFAULT_MACRO
from repro.core.mapping import LayerSpec
from repro.perfmodel import EnergyModel
from repro.perfmodel.macro_perf import cim_eval_time_ns


PAPER = {
    "density_kb_mm2": 187.0,
    "macro_ee_8b_tops_w": 150.0,
    "peak_ee_1b_pops_w": 8.0,
    "peak_ee_8b_raw_pops_w": 1.2,
    "system_ee_8b_tops_w": 40.0,
    "throughput_tops": 0.5,
    "max_rms_8b_lsb": 0.52,
}


def run():
    em = EnergyModel()
    cfg = DEFAULT_MACRO
    s84 = LayerSpec(m=1, k=1152, n=64, r_in=8, r_w=4, r_out=8, kernel=(3, 3))
    s8 = LayerSpec(m=1, k=1152, n=256, r_in=8, r_w=1, r_out=8, kernel=(3, 3))
    s1 = LayerSpec(m=1, k=1152, n=256, r_in=1, r_w=1, r_out=1, kernel=(3, 3))
    # density: 36 kB in the DP array area model (0.44 um^2 * 1152*256 cells
    # accounts for ~74% of the macro per Fig. 16c)
    cell_mm2 = 0.44e-6 * cfg.n_rows * cfg.n_cols / 0.74
    density = (cfg.n_rows * cfg.n_cols / 8 / 1024) / cell_mm2
    ours = {
        "density_kb_mm2": density,
        "macro_ee_8b_tops_w": em.macro_tops_per_watt(s84, normalize_8b=True),
        "peak_ee_1b_pops_w": em.macro_tops_per_watt(s1) / 1e3,
        "peak_ee_8b_raw_pops_w": em.macro_tops_per_watt(s8) / 1e3,
        "system_ee_8b_tops_w": None,   # see fig23 (config dependent 25-45)
        "throughput_tops": em.macro_throughput_tops(s8, normalize_8b=True),
        "max_rms_8b_lsb": 0.52,        # by construction (noise model input)
    }
    return ours


def main():
    t0 = time.time()
    ours = run()
    us = (time.time() - t0) * 1e6
    for k, v in ours.items():
        p = PAPER[k]
        vs = "model" if v is None else f"{v:.2f}"
        print(f"table1_{k},{us/len(ours):.0f},ours{vs}_paper{p}")


if __name__ == "__main__":
    main()
