"""Fig. 6(b,c): swing improvement + DP energy savings of the split DPL."""
import time

from repro.core.hw import DEFAULT_MACRO
from repro.perfmodel import EnergyModel


def run():
    cfg = DEFAULT_MACRO
    em = EnergyModel()
    rows = []
    base_swing = None
    for c_in in (4, 8, 16, 32, 64, 128):
        units = cfg.units_for_rows(c_in * 9)
        swing_split = (c_in * 9) * cfg.alpha_eff(units)
        swing_base = (c_in * 9) * cfg.alpha_eff_baseline()
        improvement = swing_split / swing_base
        e_split = em.e_dp_pj(units, 8)
        e_base = em.e_dp_pj(cfg.n_units, 8)
        savings = 1.0 - e_split / e_base
        rows.append((c_in, improvement, savings))
    return rows


def main():
    t0 = time.time()
    rows = run()
    us = (time.time() - t0) * 1e6
    for c_in, imp, sav in rows:
        print(f"fig6_split_dpl_cin{c_in},{us/len(rows):.1f},"
              f"swing_x{imp:.1f}_esave{100*sav:.0f}%")
    # paper: up to ~20x swing utilization, up to 72% energy savings @64ch
    imp_max = max(r[1] for r in rows)
    sav64 = [r[2] for r in rows if r[0] == 64][0]
    print(f"fig6_summary,0,max_swing_x{imp_max:.1f}(paper~20)"
          f"_esave64ch{100*sav64:.0f}%(paper72%)")


if __name__ == "__main__":
    main()
