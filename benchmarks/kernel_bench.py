"""Pallas cim_mbiw kernel micro-benchmark (interpret mode on CPU: checks
dispatch overhead + correctness at benchmark shapes; wall-clock here is NOT
TPU performance — the TPU projection is the roofline analysis).

Sweeps the macro's precision operating points (r_in x r_w) through the
precision-specialized kernel variants, reporting per-precision wall-clock,
achieved integer-op rate, and bit-exactness against the oracle — the
software analogue of the paper's Fig. 22 sweep."""
import time

import jax
import jax.numpy as jnp

from repro.core import digital_ref as dr
from repro.core.hw import DEFAULT_MACRO
from repro.kernels.cim_mbiw import ops
from repro.kernels.cim_mbiw.ref import cim_matmul_ref

PRECISIONS = [(r_in, r_w) for r_in in (1, 2, 4, 8) for r_w in (1, 2, 4)]


def _case(m, k, n, r_in, r_w, r_out=8, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.randint(kx, (m, k), 0, 2 ** r_in).astype(jnp.int32)
    w = dr.quantize_weight_odd(
        jax.random.randint(kw, (k, n), -(2 ** r_w - 1), 2 ** r_w), r_w)
    gamma = jnp.full((n,), 16.0)
    beta = jnp.zeros((n,))
    cfg = DEFAULT_MACRO
    units = cfg.units_for_rows(min(k, cfg.n_rows))
    g0 = dr.adc_gain_factor(r_in, r_w, r_out, units * cfg.rows_per_unit,
                            cfg.swing_efficiency(units), cfg.alpha_adc())
    return x, w, gamma, beta, g0


def bench(m, k, n, r_in=8, r_w=4, r_out=8, iters=3):
    x, w, gamma, beta, g0 = _case(m, k, n, r_in, r_w, r_out, seed=m + k + n)
    out = ops.cim_matmul(x, w, gamma, beta, r_in=r_in, r_out=r_out, g0=g0)
    out.block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = ops.cim_matmul(x, w, gamma, beta, r_in=r_in, r_out=r_out,
                             g0=g0)
        out.block_until_ready()
    t_kernel = (time.time() - t0) / iters

    ref = cim_matmul_ref(x, w, gamma, beta, g0=g0, r_out=r_out)
    match = bool(jnp.all(out == ref))
    return t_kernel * 1e6, match


def bench_precision_sweep(m=128, k=1152, n=64, iters=3):
    """Per-precision throughput through the dispatch table (Fig. 22 sweep)."""
    rows = []
    for r_in, r_w in PRECISIONS:
        prec = ops.KernelPrecision(r_in, r_w, 8)
        fn = ops.kernel_variant(prec, bm=128, bn=128, bk=256)
        x, w, gamma, beta, g0 = _case(m, k, n, r_in, r_w, seed=r_in + r_w)
        out = fn(x, w, gamma, beta, g0)
        out.block_until_ready()
        t0 = time.time()
        for _ in range(iters):
            fn(x, w, gamma, beta, g0).block_until_ready()
        us = (time.time() - t0) / iters * 1e6
        ref = cim_matmul_ref(x, w, gamma, beta, g0=g0, r_out=8)
        match = bool(jnp.all(out == ref))
        gops = 2.0 * m * k * n / (us * 1e-6) / 1e9
        rows.append((r_in, r_w, prec.n_planes, us, gops, match))
    return rows


def bench_conv_sweep(batch=4, h=14, w=14, c_in=16, c_out=32, iters=2):
    """Conv front-end sweep: a 3x3 conv layer through the engine's im2col
    streaming + kernel dispatch at each precision point, checked bit-exact
    against the digital conv reference (engine.reference)."""
    from repro.core.mapping import conv_layer_spec
    from repro.runtime import CIMInferenceEngine

    rows = []
    for r_in, r_w in PRECISIONS:
        spec = conv_layer_spec(batch, h, w, c_in, c_out, kh=3, kw=3,
                               stride=1, padding=1, r_in=r_in, r_w=r_w)
        eng = CIMInferenceEngine([spec], activations=["none"])
        params = eng.init_params(jax.random.PRNGKey(r_in + r_w))
        x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(0),
                                          (batch, h, w, c_in)))
        out = eng(params, x)
        out.block_until_ready()
        t0 = time.time()
        for _ in range(iters):
            eng(params, x).block_until_ready()
        us = (time.time() - t0) / iters * 1e6
        match = bool(jnp.all(out == eng.reference(params, x)))
        macs = 2.0 * spec.m * spec.k * spec.n
        gops = macs / (us * 1e-6) / 1e9
        rows.append((r_in, r_w, us, gops, match))
    return rows


def bench_noise_sweep(batch=8, n_trials=2, scales=(0.0, 1.0, 2.0)):
    """Noise-injected engine mode: LeNet on pseudo_mnist through the fast
    Pallas path at scaled noise operating points, Monte-Carlo trials each.

    Reports per-scale wall-clock per trial, mean accuracy over trials, and
    determinism (trial 0 re-run under the same seed must be bit-identical)
    — the software analogue of the paper's Sec. V.A noise studies."""
    from repro.core.cim_layers import CIMConfig
    from repro.core.noise_model import NoiseConfig
    from repro.data.pseudo_mnist import make_dataset
    from repro.models.cnn import init_lenet, lenet_engine, lenet_params_list

    _, _, xte, yte = make_dataset(n_train=1, n_test=batch)
    imgs = jnp.asarray(xte)[..., None]
    labels = jnp.asarray(yte)
    base = NoiseConfig()
    rows = []
    for scale in scales:
        noise = base.replace(enabled=scale > 0,
                             thermal_rms_lsb8=base.thermal_rms_lsb8 * scale,
                             sa_sigma_v=base.sa_sigma_v * scale)
        cim = CIMConfig(mode="engine", r_in=4, r_w=2, noise=noise)
        params = lenet_params_list(init_lenet(jax.random.PRNGKey(0),
                                              cim=cim))
        eng = lenet_engine(batch, cim=cim)
        key = jax.random.PRNGKey(7)
        if noise.enabled:
            eng.monte_carlo(params, imgs, key, 1).block_until_ready()  # warm
            t0 = time.time()
            logits = eng.monte_carlo(params, imgs, key, n_trials)
            logits.block_until_ready()
            us = (time.time() - t0) / n_trials * 1e6
            redo = eng(params, imgs, jax.random.split(key, n_trials)[0])
            det = bool(jnp.all(logits[0] == redo))
        else:
            eng(params, imgs).block_until_ready()
            t0 = time.time()
            logits = eng(params, imgs)[None]
            logits.block_until_ready()
            us = (time.time() - t0) * 1e6
            det = bool(jnp.all(logits[0] == eng(params, imgs)))
        acc = float(jnp.mean(jnp.argmax(logits, -1) == labels[None, :]))
        rows.append((scale, us, acc, det))
    return rows


def main():
    ok = True
    for (m, k, n) in ((128, 1152, 64), (256, 1152, 256), (512, 512, 128)):
        us, match = bench(m, k, n)
        ok &= match
        print(f"kernel_cim_mbiw_{m}x{k}x{n},{us:.0f},match{match}")
    for r_in, r_w, planes, us, gops, match in bench_precision_sweep():
        ok &= match
        print(f"kernel_prec_rin{r_in}_rw{r_w},{us:.0f},"
              f"{gops:.1f}GOPS_planes{planes}_match{match}")
    for r_in, r_w, us, gops, match in bench_conv_sweep():
        ok &= match
        print(f"conv_engine_rin{r_in}_rw{r_w},{us:.0f},"
              f"{gops:.1f}GOPS_match{match}")
    for scale, us, acc, det in bench_noise_sweep():
        ok &= det
        print(f"noise_engine_x{scale:g},{us:.0f},"
              f"acc{acc:.2f}_deterministic{det}")
    if not ok:
        raise SystemExit("oracle/determinism mismatch in sweep (see log)")


if __name__ == "__main__":
    main()
