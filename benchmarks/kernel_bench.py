"""Pallas cim_mbiw kernel micro-benchmark (interpret mode on CPU: checks
dispatch overhead + correctness at benchmark shapes; wall-clock here is NOT
TPU performance — the TPU projection is the roofline analysis).

Sweeps the macro's precision operating points (r_in x r_w) through the
precision-specialized kernel variants, reporting per-precision wall-clock,
achieved integer-op rate, and bit-exactness against the oracle — the
software analogue of the paper's Fig. 22 sweep.  The scaling sweep
additionally shards the engine across 1/2/4/8 (emulated) devices — when
run as a script the process requests 8 fake CPU devices via XLA_FLAGS
*before* jax initializes, so CPU-only CI exercises the multi-macro
dispatch."""
import os
import time

if __name__ == "__main__":      # must precede the first jax import
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import digital_ref as dr
from repro.core.hw import DEFAULT_MACRO
from repro.kernels.cim_mbiw import ops
from repro.kernels.cim_mbiw.ref import cim_matmul_ref

PRECISIONS = [(r_in, r_w) for r_in in (1, 2, 4, 8) for r_w in (1, 2, 4)]


def _case(m, k, n, r_in, r_w, r_out=8, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.randint(kx, (m, k), 0, 2 ** r_in).astype(jnp.int32)
    w = dr.quantize_weight_odd(
        jax.random.randint(kw, (k, n), -(2 ** r_w - 1), 2 ** r_w), r_w)
    gamma = jnp.full((n,), 16.0)
    beta = jnp.zeros((n,))
    cfg = DEFAULT_MACRO
    units = cfg.units_for_rows(min(k, cfg.n_rows))
    g0 = dr.adc_gain_factor(r_in, r_w, r_out, units * cfg.rows_per_unit,
                            cfg.swing_efficiency(units), cfg.alpha_adc())
    return x, w, gamma, beta, g0


def bench(m, k, n, r_in=8, r_w=4, r_out=8, iters=3):
    x, w, gamma, beta, g0 = _case(m, k, n, r_in, r_w, r_out, seed=m + k + n)
    out = ops.cim_matmul(x, w, gamma, beta, r_in=r_in, r_out=r_out, g0=g0)
    out.block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = ops.cim_matmul(x, w, gamma, beta, r_in=r_in, r_out=r_out,
                             g0=g0)
        out.block_until_ready()
    t_kernel = (time.time() - t0) / iters

    ref = cim_matmul_ref(x, w, gamma, beta, g0=g0, r_out=r_out)
    match = bool(jnp.all(out == ref))
    return t_kernel * 1e6, match


def bench_precision_sweep(m=128, k=1152, n=64, iters=3):
    """Per-precision throughput through the dispatch table (Fig. 22 sweep)."""
    rows = []
    for r_in, r_w in PRECISIONS:
        prec = ops.KernelPrecision(r_in, r_w, 8)
        fn = ops.kernel_variant(prec, bm=128, bn=128, bk=256)
        x, w, gamma, beta, g0 = _case(m, k, n, r_in, r_w, seed=r_in + r_w)
        out = fn(x, w, gamma, beta, g0)
        out.block_until_ready()
        t0 = time.time()
        for _ in range(iters):
            fn(x, w, gamma, beta, g0).block_until_ready()
        us = (time.time() - t0) / iters * 1e6
        ref = cim_matmul_ref(x, w, gamma, beta, g0=g0, r_out=8)
        match = bool(jnp.all(out == ref))
        gops = 2.0 * m * k * n / (us * 1e-6) / 1e9
        rows.append((r_in, r_w, prec.n_planes, us, gops, match))
    return rows


def bench_conv_sweep(batch=4, h=14, w=14, c_in=16, c_out=32, iters=2):
    """Conv front-end sweep: a 3x3 conv layer through the engine's im2col
    streaming + kernel dispatch at each precision point, checked bit-exact
    against the digital conv reference (engine.reference)."""
    from repro.core.mapping import conv_layer_spec
    from repro.runtime import CIMInferenceEngine

    rows = []
    for r_in, r_w in PRECISIONS:
        spec = conv_layer_spec(batch, h, w, c_in, c_out, kh=3, kw=3,
                               stride=1, padding=1, r_in=r_in, r_w=r_w)
        eng = CIMInferenceEngine([spec], activations=["none"])
        params = eng.init_params(jax.random.PRNGKey(r_in + r_w))
        x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(0),
                                          (batch, h, w, c_in)))
        out = eng(params, x)
        out.block_until_ready()
        t0 = time.time()
        for _ in range(iters):
            eng(params, x).block_until_ready()
        us = (time.time() - t0) / iters * 1e6
        match = bool(jnp.all(out == eng.reference(params, x)))
        macs = 2.0 * spec.m * spec.k * spec.n
        gops = macs / (us * 1e-6) / 1e9
        rows.append((r_in, r_w, us, gops, match))
    return rows


def bench_noise_sweep(batch=8, n_trials=2, scales=(0.0, 1.0, 2.0)):
    """Noise-injected engine mode: LeNet on pseudo_mnist through the fast
    Pallas path at scaled noise operating points, Monte-Carlo trials each.

    Reports per-scale wall-clock per trial, mean accuracy over trials, and
    determinism (trial 0 re-run under the same seed must be bit-identical)
    — the software analogue of the paper's Sec. V.A noise studies."""
    from repro.core.cim_layers import CIMConfig
    from repro.core.noise_model import NoiseConfig
    from repro.data.pseudo_mnist import make_dataset
    from repro.models.cnn import init_lenet, lenet_engine, lenet_params_list

    _, _, xte, yte = make_dataset(n_train=1, n_test=batch)
    imgs = jnp.asarray(xte)[..., None]
    labels = jnp.asarray(yte)
    base = NoiseConfig()
    rows = []
    for scale in scales:
        noise = base.replace(enabled=scale > 0,
                             thermal_rms_lsb8=base.thermal_rms_lsb8 * scale,
                             sa_sigma_v=base.sa_sigma_v * scale)
        cim = CIMConfig(mode="engine", r_in=4, r_w=2, noise=noise)
        params = lenet_params_list(init_lenet(jax.random.PRNGKey(0),
                                              cim=cim))
        eng = lenet_engine(batch, cim=cim)
        key = jax.random.PRNGKey(7)
        if noise.enabled:
            eng.monte_carlo(params, imgs, key, 1).block_until_ready()  # warm
            t0 = time.time()
            logits = eng.monte_carlo(params, imgs, key, n_trials)
            logits.block_until_ready()
            us = (time.time() - t0) / n_trials * 1e6
            redo = eng(params, imgs, jax.random.split(key, n_trials)[0])
            det = bool(jnp.all(logits[0] == redo))
        else:
            eng(params, imgs).block_until_ready()
            t0 = time.time()
            logits = eng(params, imgs)[None]
            logits.block_until_ready()
            us = (time.time() - t0) * 1e6
            det = bool(jnp.all(logits[0] == eng(params, imgs)))
        acc = float(jnp.mean(jnp.argmax(logits, -1) == labels[None, :]))
        rows.append((scale, us, acc, det))
    return rows


def bench_scaling_sweep(devices=(1, 2, 4, 8), iters=3):
    """Weak/strong-scaling of the sharded engine (ISSUE 4 tentpole).

    Strong scaling: a fixed 2-layer schedule (col-tile-rich first layer,
    rows-sharded second) at constant global work, sharded over D devices.
    Weak scaling: the GEMM-row extent grows with D (64 rows per device).
    Every point is checked bit-exact against the single-device engine.
    Wall-clock on emulated CPU devices measures dispatch plumbing, not
    macro performance — the numbers are for trend/regression tracking."""
    from repro.core.mapping import LayerSpec
    from repro.runtime import CIMInferenceEngine, EngineConfig, ShardingConfig

    def build(m, d):
        specs = [LayerSpec(m=m, k=576, n=256, r_in=4, r_w=4),   # 4 col tiles
                 LayerSpec(m=m, k=256, n=32, r_in=4, r_w=4)]    # rows kind
        cfg = EngineConfig()
        if d:
            cfg = cfg.replace(sharding=ShardingConfig(devices=d))
        return CIMInferenceEngine(specs, cfg)

    def run(eng, params, x, n=iters):
        eng(params, x).block_until_ready()          # compile
        t0 = time.time()
        for _ in range(n):
            eng(params, x).block_until_ready()
        return (time.time() - t0) / n * 1e6

    avail = len(jax.devices())
    m_strong = 256
    base = build(m_strong, 0)
    params = base.init_params(jax.random.PRNGKey(0))
    x_strong = jax.nn.relu(
        jax.random.normal(jax.random.PRNGKey(1), (m_strong, 576)))
    t_serial = run(base, params, x_strong)
    y_serial = jax.device_get(base(params, x_strong))

    rows = []
    for d in devices:
        if d > avail:
            rows.append((d, None, None, None, None))
            continue
        eng = build(m_strong, d)
        t_strong = run(eng, params, x_strong)
        match = bool((jax.device_get(eng(params, x_strong))
                      == y_serial).all())
        # weak scaling: 64 GEMM rows per device
        m_weak = 64 * d
        engw = build(m_weak, d)
        pw = engw.init_params(jax.random.PRNGKey(0))
        xw = jax.nn.relu(
            jax.random.normal(jax.random.PRNGKey(1), (m_weak, 576)))
        t_weak = run(engw, pw, xw)
        # the weak-scaling shapes exercise per-d rows-kind padding the
        # strong point does not — bit-check them too
        match &= bool((jax.device_get(engw(pw, xw))
                       == jax.device_get(build(m_weak, 0)(pw, xw))).all())
        eff = engw.perf_report()["total"]["parallel_efficiency"]
        rows.append((d, t_strong, t_weak, eff, match))
    return t_serial, rows


def bench_serving(batch=4, d=256, layers=3, steps=24, out_json=None):
    """Plan-once/serve-many vs the legacy per-call path (ISSUE 5).

    A decode-shaped workload (a `layers`-deep stack of d x d CIM linears at
    batch `batch` — one LM decode step per call) served two ways:

      * legacy: re-plan the network and re-enter run_network every call —
        what serve.py paid per token before the compiled-program runtime
        (the jit cache still hits on the equal plan, so this isolates the
        per-call planning + weight-quantization-in-graph overhead);
      * program: one compiled CIMProgram, weights pre-bound
        (`prog.bind(params)`), every call a bucket-cache hit.

    Both paths must agree bit-exactly.  Returns a row dict (per-call
    latency, tokens/s, speedup) and, when `out_json` is set, writes it as
    BENCH_serving.json for the serving-smoke CI job."""
    import json
    import warnings

    from repro.core.mapping import LayerSpec
    from repro.runtime import compile_program
    from repro.runtime import engine as rt

    specs = [LayerSpec(m=batch, k=d, n=d, r_in=4, r_w=2)
             for _ in range(layers)]
    acts = ["relu"] * (layers - 1) + ["none"]
    prog = compile_program(specs, activations=acts)
    params = prog.init_params(jax.random.PRNGKey(0))
    bound = prog.bind(params)
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (batch, d)))

    def legacy_call():
        plan = rt.plan_network(specs, rt.EngineConfig(), acts)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return rt.run_network(plan, params, x)

    y_prog = bound.serve(x)
    y_prog.block_until_ready()                  # warm the program path
    y_leg = legacy_call()
    y_leg.block_until_ready()                   # warm the legacy jit cache
    match = bool(jnp.all(y_prog == y_leg))

    t0 = time.time()
    for _ in range(steps):
        legacy_call().block_until_ready()
    t_leg = (time.time() - t0) / steps

    t0 = time.time()
    for _ in range(steps):
        bound.serve(x).block_until_ready()
    t_prog = (time.time() - t0) / steps

    row = {
        "batch": batch, "d_model": d, "layers": layers, "steps": steps,
        "legacy_us_per_call": t_leg * 1e6,
        "program_us_per_call": t_prog * 1e6,
        "legacy_tokens_per_s": batch / t_leg,
        "program_tokens_per_s": batch / t_prog,
        "speedup": t_leg / t_prog,
        "match": match,
        "program_stats": prog.stats(),
    }
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(row, fh, indent=2)
    return row


def bench_verify_overhead(d=192, layers=2, batch=8):
    """One-time cost of `compile_program(..., verify="strict")` (ISSUE 8).

    Plans and warms a genuinely cold program at the most expensive grid
    point (r_in=8, r_w=4 — 32 kernel planes), then times the full cimcheck
    pass stack (`verify_program`) against it.  The acceptance gate is
    overhead < 5% of the one-time plan+warmup cost: static verification
    must stay invisible next to the XLA compile it rides along with."""
    from repro.analysis import verify_program
    from repro.core.mapping import LayerSpec
    from repro.runtime import compile_program
    from repro.runtime.program import clear_program_cache

    specs = [LayerSpec(m=batch, k=d, n=d, r_in=8, r_w=4)
             for _ in range(layers)]
    clear_program_cache()
    t0 = time.time()
    prog = compile_program(specs)
    params = prog.init_params(jax.random.PRNGKey(0))
    bound = prog.bind(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, d))
    bound.serve(x).block_until_ready()
    t_plan = time.time() - t0

    t0 = time.time()
    verify_program(prog, "strict", graphs="serving")   # = verify="strict"
    t_verify = time.time() - t0
    return {
        "plan_warmup_s": t_plan,
        "verify_s": t_verify,
        "verify_strict_overhead": t_verify / t_plan,
    }


def bench_inflight_sweep(rates=(0.25, 1.0, 4.0), capacity=8, n_req=16,
                         seed=0):
    """Arrival-rate sweep of the in-flight batching scheduler (ISSUE 6).

    Poisson arrivals (requests per scheduler step, one stream per rate) x
    a short/medium/long generation-length mix, driven through
    InflightScheduler over a toy CIMDecodeLM.  Per rate: p50/p99 end-to-
    end latency and time-to-first-token (steps), decode tokens/s, mean
    fused occupancy, and an isolation spot-check — a sample of requests
    re-decoded solo (decode_sequential) must match the fused streams bit
    for bit."""
    from repro.runtime.scheduler import (CIMDecodeLM, InflightScheduler,
                                         Request, decode_sequential)

    model = CIMDecodeLM.toy(jax.random.PRNGKey(5), d=96, depth=2,
                            vocab=61, r_in=4, r_w=2)
    gen_mix = ((2, 0.5), (6, 0.3), (12, 0.2))     # short/medium/long
    rows = []
    for rate in rates:
        rng = np.random.default_rng(seed)
        t, arrivals = 0.0, []
        for uid in range(n_req):
            t += rng.exponential(1.0 / rate)
            gen = int(rng.choice([g for g, _ in gen_mix],
                                 p=[p for _, p in gen_mix]))
            prompt = tuple(int(v) for v in
                           rng.integers(0, 61, size=int(rng.integers(1, 5))))
            arrivals.append((int(t), Request(uid=uid, prompt=prompt,
                                             max_new_tokens=gen)))
        sched = InflightScheduler(model, capacity=capacity)
        fused = sched.run(arrivals)
        m = sched.metrics()
        sample = [r for _, r in arrivals[:: max(1, n_req // 3)]]
        match = all(fused[r.uid] == decode_sequential(model, r)
                    for r in sample)
        rows.append({
            "arrival_rate": rate, "requests": n_req, "capacity": capacity,
            "latency_steps_p50": m["latency_steps_p50"],
            "latency_steps_p99": m["latency_steps_p99"],
            "ttft_steps_p50": m["ttft_steps_p50"],
            "ttft_steps_p99": m["ttft_steps_p99"],
            "tokens_per_s": m["tokens_per_s"],
            "tokens_per_decode_step": m["tokens_per_decode_step"],
            "extents_seen": m["extents_seen"],
            "isolation_match": match,
        })
    return rows


def bench_llm_engine(steps=8):
    """Engine-mode LLM projections (ISSUE 7): per-expert program-cache
    reuse and engine-vs-fakequant throughput on a small MoE block.

    One moe_block forward routes 3E expert GEMMs (gate/up/down x E
    experts) through TWO cached programs — the (d->f) program shared by
    the gate and up banks and the (f->d) down program — so the program
    cache absorbs (3E-2)/3E of the compiles.  The row reports that hit
    rate, the per-program serve reuse factor, tokens/s for the engine vs
    the fakequant reference, and their bit-exactness."""
    import functools

    from repro.core import mapping
    from repro.core.cim_layers import CIMConfig, _engine_config
    from repro.models.moe import init_moe, moe_block
    from repro.runtime.program import DEFAULT_BUCKETS, compile_program

    e, d, f, top_k, cf = 4, 32, 96, 2, 1.25
    params = init_moe(jax.random.PRNGKey(0), d, f, e)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d), jnp.float32)
    cim_fq = CIMConfig(mode="fakequant", r_in=4, r_w=2)
    cim_en = cim_fq.replace(mode="engine")
    run_fq = jax.jit(functools.partial(moe_block, n_experts=e, top_k=top_k,
                                       capacity_factor=cf, cim=cim_fq))
    run_en = jax.jit(functools.partial(moe_block, n_experts=e, top_k=top_k,
                                       capacity_factor=cf, cim=cim_en))

    # replicate the capacity -> bucket -> LayerSpec key moe_block uses so
    # the stats below read the very programs its expert loop serves
    t = x.shape[0] * x.shape[1]
    cap = max(8, min(int(cf * top_k * t / e + 0.5), t * top_k))
    m = DEFAULT_BUCKETS.bucket_for(cap)
    progs = [compile_program(
        [mapping.LayerSpec(m=m, k=ki, n=ni, r_in=cim_en.r_in,
                           r_w=cim_en.r_w, r_out=cim_en.r_out)],
        _engine_config(cim_en)) for ki, ni in ((d, f), (f, d))]
    serves0 = sum(p.stats()["serve_calls"] for p in progs)

    y_en, _ = run_en(params, x)
    y_en.block_until_ready()
    y_fq, _ = run_fq(params, x)
    y_fq.block_until_ready()
    match = bool(jnp.all(y_en == y_fq))
    serves = sum(p.stats()["serve_calls"] for p in progs) - serves0

    times = {}
    for name, fn in (("engine", run_en), ("fakequant", run_fq)):
        t0 = time.time()
        for _ in range(steps):
            fn(params, x)[0].block_until_ready()
        times[name] = (time.time() - t0) / steps
    return {
        "n_experts": e, "d_model": d, "d_ff": f, "top_k": top_k,
        "tokens_per_call": t,
        "expert_gemm_serves": serves,
        "programs_compiled": len(progs),
        "program_cache_hit_rate": 1.0 - len(progs) / max(serves, 1),
        "serve_reuse_factor": serves / len(progs),
        "engine_tokens_per_s": t / times["engine"],
        "fakequant_tokens_per_s": t / times["fakequant"],
        "engine_us_per_call": times["engine"] * 1e6,
        "fakequant_us_per_call": times["fakequant"] * 1e6,
        "match": match,
    }


def bench_autotune(devices=(1, 4)):
    """Schedule-autotuner gate (ISSUE 9): tuned cost <= heuristic cost on
    every zoo model x precision point, and tuned programs bit-exact.

    The cost sweep is pure plan-time geometry (repro.tuner.tune_layer on
    the LeNet conv chain and the olmo-1b projection GEMMs across the full
    r_in x r_w grid, at 1 and 4 modeled devices — no fake-device mesh
    needed, the roofline model only reads the partition arithmetic).  One
    compiled point then checks the integrated path: a
    compile_program(tune="analytic") program must serve bit-identically
    to the untuned one."""
    from repro.configs import get_smoke_config
    from repro.core.cim_layers import CIMConfig, _engine_config
    from repro.core.mapping import LayerSpec
    from repro.models.cnn import lenet_engine_specs
    from repro.runtime.engine import EngineConfig
    from repro.runtime.program import compile_program
    from repro.tuner import SEARCH_COUNT, tune_layer

    def llm_specs(arch, r_in, r_w, m=8):
        # the decoder projection GEMMs, same shapes scripts/cimcheck.py
        # sweeps (fused QKV, O, fused gate_up, down)
        c = get_smoke_config(arch)
        hd = c.resolved_head_dim
        shapes = [(c.d_model, (c.n_heads + 2 * c.n_kv_heads) * hd),
                  (c.n_heads * hd, c.d_model),
                  (c.d_model, 2 * c.d_ff), (c.d_ff, c.d_model)]
        return [LayerSpec(m=m, k=k, n=n, r_in=r_in, r_w=r_w)
                for k, n in shapes]

    points = 0
    wins = 0
    ratio_sum = 0.0
    all_le = True
    n0 = SEARCH_COUNT["n"]
    for r_in, r_w in PRECISIONS:
        zoo = []
        specs, _, _ = lenet_engine_specs(
            8, cim=CIMConfig(r_in=r_in, r_w=r_w))
        zoo.append(("lenet", specs, _engine_config(
            CIMConfig(r_in=r_in, r_w=r_w))))
        zoo.append(("olmo-1b", llm_specs("olmo-1b", r_in, r_w),
                    EngineConfig()))
        for _, specs, cfg in zoo:
            for d in devices:
                heur_s = tuned_s = 0.0
                for spec in specs:
                    _, rep = tune_layer(spec, cfg, d, cache=None)
                    heur_s += rep["heuristic_s"]
                    tuned_s += rep["predicted_s"]
                points += 1
                all_le &= tuned_s <= heur_s * (1 + 1e-12)
                wins += tuned_s < heur_s
                ratio_sum += tuned_s / max(heur_s, 1e-30)

    spec = [LayerSpec(m=16, k=300, n=48, r_in=4, r_w=2)]
    p0 = compile_program(spec, EngineConfig())
    pt = compile_program(spec, EngineConfig(), tune="analytic",
                         tune_cache="")
    params = p0.init_params(jax.random.PRNGKey(0))
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (6, 300)))
    y0 = p0.bind(params).serve(x)
    yt = pt.bind(params).serve(x)
    match = bool(jnp.all(y0 == yt))
    return {
        "zoo_points": points,
        "layers_searched": SEARCH_COUNT["n"] - n0,
        "tuned_le_heuristic": bool(all_le),
        "points_improved": int(wins),
        "mean_cost_ratio": ratio_sum / max(points, 1),
        "match": match,
    }


def bench_precision_serving(capacity=6, n_req=12, steps=12, seed=3):
    """Workload-adaptive precision serving gate (ISSUE 10 tentpole).

    Calibrates the toy decode-LM's four projection GEMMs, plans quality
    and throughput operating points under DEFAULT_BUDGETS, builds ONE
    CIMDecodeLM serving both points over the same weights, and gates:

      * throughput win — the throughput point's projected decode
        tokens/s (macro perf model over its block stack) beats the
        quality point's.  The projection is the gate because interpret-
        mode CPU wall-clock cannot resolve the bit-plane difference (the
        plane loop fuses into one XLA op; dispatch overhead dominates) —
        measured wall tokens/s for both points is still reported for
        trend tracking;
      * mixed bit-exactness — a half/half schedule where every fused
        request must equal its solo decode at its own point;
      * budget adherence — a fresh sensitivity profile (different seed,
        different input draws) re-measures each point's total quality
        delta, which must stay within the planner's allowance/prediction
        up to a bounded slack.
    """
    from repro.core.mapping import LayerSpec
    from repro.precision import DEFAULT_BUDGETS, assign, calibrate
    from repro.runtime.engine import EngineConfig
    from repro.runtime.scheduler import (CIMDecodeLM, InflightScheduler,
                                         Request, decode_sequential)

    d, d_ff, depth, vocab = 48, 96, 2, 23
    specs = (LayerSpec(m=8, k=d, n=3 * d, r_in=8, r_w=4),
             LayerSpec(m=8, k=d, n=d, r_in=8, r_w=4),
             LayerSpec(m=8, k=d, n=2 * d_ff, r_in=8, r_w=4),
             LayerSpec(m=8, k=d_ff, n=d, r_in=8, r_w=4))
    cfg = EngineConfig()
    prof = calibrate(specs, cfg, n_trials=2, batch=4, seed=seed,
                     label="bench-precision")
    points = {}
    predicted = {}
    allowance = {}
    for name in ("quality", "throughput"):
        asg, delta = assign(prof, specs, DEFAULT_BUDGETS[name])
        points[name] = asg
        predicted[name] = delta
        allowance[name] = DEFAULT_BUDGETS[name] * prof.max_total_delta()

    model = CIMDecodeLM.toy(jax.random.PRNGKey(11), d=d, depth=depth,
                            vocab=vocab, r_in=8, r_w=4, points=points)

    rng = np.random.default_rng(seed)
    prompts = [tuple(int(v) for v in rng.integers(0, vocab, size=3))
               for _ in range(n_req)]
    gens = [int(rng.integers(2, 5)) for _ in range(n_req)]

    def run_uniform(point):
        sched = InflightScheduler(model, capacity=capacity)
        sched.run([(i % 3, Request(uid=i, prompt=prompts[i],
                                   max_new_tokens=gens[i], point=point))
                   for i in range(n_req)])
        return sched.metrics()

    # warm both points' executables, then measure (same schedule per point)
    for name in points:
        run_uniform(name)
    m_q = run_uniform("quality")
    m_t = run_uniform("throughput")

    def point_step_time(point):
        # modeled macro time of ONE fused decode step at this point: the
        # four projection programs of every block (Fig. 22 scaling)
        t = 0.0
        for blk in model.blocks_for(point):
            for bp in (blk.qkv, blk.o, blk.gate_up, blk.down):
                t += bp.program.perf_report(
                    point=point)["total"]["time_s"]
        return t

    t_q, t_t = point_step_time("quality"), point_step_time("throughput")
    projected = {"quality": capacity / max(t_q, 1e-30),
                 "throughput": capacity / max(t_t, 1e-30)}
    speedup = projected["throughput"] / max(projected["quality"], 1e-30)

    mixed = [Request(uid=i, prompt=prompts[i], max_new_tokens=gens[i],
                     point=("quality", "throughput")[i % 2])
             for i in range(n_req)]
    sched = InflightScheduler(model, capacity=capacity)
    fused = sched.run([(i % 3, r) for i, r in enumerate(mixed)])
    mixed_match = all(fused[r.uid] == decode_sequential(model, r)
                      for r in mixed)

    # MC budget check: fresh input draws re-measure the deltas the
    # planner summed — 2.5x slack bounds the draw-to-draw variation
    prof2 = calibrate(specs, cfg, n_trials=2, batch=4, seed=seed + 1,
                      label="bench-precision-check")
    within_budget = True
    measured = {}
    for name, asg in points.items():
        meas = sum(prof2.delta(i, pt) for i, pt in enumerate(asg))
        measured[name] = meas
        within_budget &= meas <= max(allowance[name],
                                     predicted[name]) * 2.5 + 1e-12
    return {
        "capacity": capacity, "requests": n_req,
        "points": {k: [list(p) for p in v] for k, v in points.items()},
        "predicted_delta": predicted,
        "allowance": allowance,
        "measured_delta": measured,
        "quality_tokens_per_s": projected["quality"],
        "throughput_tokens_per_s": projected["throughput"],
        "quality_wall_tokens_per_s": m_q["tokens_per_s"],
        "throughput_wall_tokens_per_s": m_t["tokens_per_s"],
        "speedup": speedup,
        "mixed_tokens_by_point": sched.metrics()["tokens_by_point"],
        "mixed_match": mixed_match,
        "within_budget": within_budget,
    }


def _serving_row(out_json="BENCH_serving.json"):
    """Run bench_serving plus the in-flight arrival-rate sweep, merge both
    into one BENCH_serving.json, print the CSV rows, and return whether
    every bit-exactness check (program-vs-legacy and fused-vs-solo
    isolation) held."""
    import json

    row = bench_serving(out_json=None)
    print(f"serving_program,{row['program_us_per_call']:.0f},"
          f"legacy{row['legacy_us_per_call']:.0f}us_"
          f"speedup{row['speedup']:.2f}_match{row['match']}")
    sweep = bench_inflight_sweep()
    for r in sweep:
        print(f"serving_inflight_rate{r['arrival_rate']:g},"
              f"{r['tokens_per_s']:.0f},"
              f"p50_{r['latency_steps_p50']:.0f}_"
              f"p99_{r['latency_steps_p99']:.0f}steps_"
              f"occ{r['tokens_per_decode_step']:.2f}_"
              f"match{r['isolation_match']}")
    row["inflight_sweep"] = sweep
    llm = bench_llm_engine()
    print(f"serving_llm_engine,{llm['engine_tokens_per_s']:.0f},"
          f"fakequant{llm['fakequant_tokens_per_s']:.0f}tok_s_"
          f"hit{llm['program_cache_hit_rate']:.2f}_"
          f"reuse{llm['serve_reuse_factor']:.1f}x_match{llm['match']}")
    row["llm_engine"] = llm
    at = bench_autotune()
    print(f"serving_autotune,{at['zoo_points']},"
          f"ratio{at['mean_cost_ratio']:.3f}_"
          f"improved{at['points_improved']}_"
          f"le{at['tuned_le_heuristic']}_match{at['match']}")
    row["autotune"] = at
    vo = bench_verify_overhead()
    print(f"serving_verify_strict,{vo['verify_s'] * 1e3:.0f}ms,"
          f"plan{vo['plan_warmup_s'] * 1e3:.0f}ms_"
          f"overhead{vo['verify_strict_overhead']:.3f}")
    row.update(vo)
    ps = bench_precision_serving()
    print(f"serving_precision_sweep,"
          f"{ps['throughput_tokens_per_s']:.0f},"
          f"quality{ps['quality_tokens_per_s']:.0f}tok_s_"
          f"speedup{ps['speedup']:.2f}_"
          f"mixed{ps['mixed_match']}_budget{ps['within_budget']}")
    row["precision_sweep"] = ps
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(row, fh, indent=2)
    return (row["match"] and llm["match"]
            and at["match"] and at["tuned_le_heuristic"]
            and all(r["isolation_match"] for r in sweep)
            and ps["mixed_match"] and ps["within_budget"]
            and ps["speedup"] > 1.0)


def main(serving_only=False):
    ok = True
    if serving_only:
        if not _serving_row():
            raise SystemExit("program vs legacy serving mismatch")
        return
    for (m, k, n) in ((128, 1152, 64), (256, 1152, 256), (512, 512, 128)):
        us, match = bench(m, k, n)
        ok &= match
        print(f"kernel_cim_mbiw_{m}x{k}x{n},{us:.0f},match{match}")
    for r_in, r_w, planes, us, gops, match in bench_precision_sweep():
        ok &= match
        print(f"kernel_prec_rin{r_in}_rw{r_w},{us:.0f},"
              f"{gops:.1f}GOPS_planes{planes}_match{match}")
    for r_in, r_w, us, gops, match in bench_conv_sweep():
        ok &= match
        print(f"conv_engine_rin{r_in}_rw{r_w},{us:.0f},"
              f"{gops:.1f}GOPS_match{match}")
    for scale, us, acc, det in bench_noise_sweep():
        ok &= det
        print(f"noise_engine_x{scale:g},{us:.0f},"
              f"acc{acc:.2f}_deterministic{det}")
    t_serial, srows = bench_scaling_sweep()
    print(f"shard_engine_serial,{t_serial:.0f}")
    for d, t_strong, t_weak, eff, match in srows:
        if t_strong is None:
            print(f"shard_engine_d{d},skipped_needs_{d}_devices")
            continue
        ok &= match
        print(f"shard_engine_d{d},{t_strong:.0f},"
              f"strong_x{t_serial / t_strong:.2f}_weak{t_weak:.0f}us_"
              f"eff{eff:.2f}_match{match}")
    ok &= _serving_row()
    if not ok:
        raise SystemExit("oracle/determinism mismatch in sweep (see log)")


if __name__ == "__main__":
    import sys
    main(serving_only="serving" in sys.argv[1:])
