"""Pallas cim_mbiw kernel micro-benchmark (interpret mode on CPU: checks
dispatch overhead + correctness at benchmark shapes; wall-clock here is NOT
TPU performance — the TPU projection is the roofline analysis)."""
import time

import jax
import jax.numpy as jnp

from repro.core import digital_ref as dr
from repro.core.hw import DEFAULT_MACRO
from repro.kernels.cim_mbiw import ops
from repro.kernels.cim_mbiw.ref import cim_matmul_ref


def bench(m, k, n, r_in=8, r_w=4, r_out=8, iters=3):
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.randint(kx, (m, k), 0, 2 ** r_in).astype(jnp.int32)
    w = dr.quantize_weight_odd(
        jax.random.randint(kw, (k, n), -(2 ** r_w - 1), 2 ** r_w), r_w)
    gamma = jnp.full((n,), 16.0)
    beta = jnp.zeros((n,))
    cfg = DEFAULT_MACRO
    units = cfg.units_for_rows(min(k, cfg.n_rows))
    g0 = dr.adc_gain_factor(r_in, r_w, r_out, units * cfg.rows_per_unit,
                            cfg.swing_efficiency(units), cfg.alpha_adc())

    out = ops.cim_matmul(x, w, gamma, beta, r_in=r_in, r_out=r_out, g0=g0)
    out.block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = ops.cim_matmul(x, w, gamma, beta, r_in=r_in, r_out=r_out,
                             g0=g0)
        out.block_until_ready()
    t_kernel = (time.time() - t0) / iters

    ref = cim_matmul_ref(x, w, gamma, beta, g0=g0, r_out=r_out)
    match = bool(jnp.all(out == ref))
    return t_kernel * 1e6, match


def main():
    for (m, k, n) in ((128, 1152, 64), (256, 1152, 256), (512, 512, 128)):
        us, match = bench(m, k, n)
        print(f"kernel_cim_mbiw_{m}x{k}x{n},{us:.0f},match{match}")


if __name__ == "__main__":
    main()
