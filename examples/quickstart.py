"""Quickstart: the paper's technique in 30 lines.

A CIM-quantized linear layer with distribution-aware reshaping (ABN),
compared against (a) full precision and (b) unity-gain quantization —
reproducing the paper's Fig. 3 argument on one matmul.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.cim_layers import CIMConfig, cim_linear_apply, init_cim_linear

key = jax.random.PRNGKey(0)
cfg = CIMConfig(mode="fakequant")          # 8b in, 4b weights, 8b ADC out

# a layer that uses 4 of the macro's 32 serial-split units (K=144 rows)
params = init_cim_linear(key, 144, 64, cfg=cfg)   # distribution-aware gamma
x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (256, 144)))

y_fp = x @ params["w"]                                   # full precision
y_cim = cim_linear_apply(params, x, cfg)                 # IMAGINE path
unity = {**params, "abn_log_gamma": jnp.zeros_like(params["abn_log_gamma"])}
y_unity = cim_linear_apply(unity, x, cfg)                # no reshaping

def rel(y):
    return float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))

print(f"relative error, distribution-aware ABN : {rel(y_cim):8.4f}")
print(f"relative error, unity gain (no ABN)    : {rel(y_unity):8.4f}")
print("-> the ABN 'zoom' recovers the ADC bits the narrow DP distribution "
      "would otherwise waste (paper Fig. 3).")

# the same layer through the voltage-domain behavioural macro (Sec. III)
y_sim = cim_linear_apply(params, x[:16], cfg.replace(mode="sim"))
print(f"voltage-domain sim vs fakequant        : "
      f"{float(jnp.linalg.norm(y_sim - y_cim[:16]) / jnp.linalg.norm(y_sim)):8.4f}")

# --- the precision-scalable inference runtime (paper Fig. 22) --------------
# A 2-layer network planned into macro tiles and executed through the
# precision-specialized Pallas kernel variants, at each r_in operating
# point.  Accuracy degrades gracefully as precision (and energy) drops.
from repro.core.mapping import LayerSpec
from repro.runtime import compile_program

print("\nprecision-scalable engine (2-layer network, r_w = min(r_in, 4)):")
for r_in in (8, 4, 2, 1):
    specs = [LayerSpec(m=256, k=144, n=64, r_in=r_in, r_w=min(r_in, 4)),
             LayerSpec(m=256, k=64, n=32, r_in=r_in, r_w=min(r_in, 4))]
    prog = compile_program(specs)          # plan once (global program cache)
    eparams = prog.init_params(jax.random.PRNGKey(2))
    bound = prog.bind(eparams)             # weights pre-quantized & packed
    y_eng = bound.serve(x)                             # Pallas kernel path
    y_ref = bound.reference(x)                         # digital oracle
    y_full = jax.nn.relu(x @ eparams[0]["w"]) @ eparams[1]["w"]
    rel_fp = float(jnp.linalg.norm(y_eng - y_full) / jnp.linalg.norm(y_full))
    ee = prog.perf_report()["total"]["tops_per_w"]
    print(f"  r_in={r_in}: bit-exact with reference: "
          f"{bool(jnp.all(y_eng == y_ref))}, rel err vs fp: {rel_fp:6.4f}, "
          f"modeled {ee:6.1f} TOPS/W")

# --- conv front-end: a whole LeNet through one engine plan -----------------
# The engine consumes NHWC images directly: im2col streaming feeds the
# K = kh*kw*C_in row groups through the Pallas kernels, with max-pool and
# the conv -> dense flatten planned as layer epilogues.  Engine logits track
# the fakequant training path within quantization tolerance.
from repro.data.pseudo_mnist import make_dataset
from repro.models.cnn import (init_lenet, lenet_forward, lenet_params_list,
                              lenet_program)

_, _, xte, _ = make_dataset(n_train=1, n_test=32)
imgs = jnp.asarray(xte)[..., None]                       # (32, 28, 28, 1)
lcfg = CIMConfig(mode="fakequant", r_in=4, r_w=2)        # the paper's 4b LeNet
lparams = init_lenet(jax.random.PRNGKey(3), cim=lcfg)
logits_fq = lenet_forward(lparams, imgs, lcfg)
logits_eng = lenet_forward(lparams, imgs, lcfg.replace(mode="engine"))
lprog = lenet_program(imgs.shape[0], cim=lcfg)           # the cached program
lbound = lprog.bind(lenet_params_list(lparams))
bitexact = bool(jnp.all(logits_eng == lbound.reference(imgs)))
rel_fq = float(jnp.max(jnp.abs(logits_eng - logits_fq))
               / (jnp.max(jnp.abs(logits_fq)) + 1e-9))
rep = lprog.perf_report()["total"]
print(f"\nLeNet conv front-end (pseudo-MNIST, 4b): bit-exact with digital "
      f"conv reference: {bitexact}, rel err vs fakequant: {rel_fq:.2e}, "
      f"modeled {rep['tops_per_w']:.1f} TOPS/W over "
      f"{rep['macro_evals']} planned macro tiles")
