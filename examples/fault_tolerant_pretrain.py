"""Fault-tolerant ~100M-param pretraining run: trains an OLMo-style model
for a few hundred steps on the synthetic LM stream with checkpoint/restart,
then kills itself twice mid-run to prove recovery (deliverable (b): the
end-to-end train driver).

  PYTHONPATH=src python examples/fault_tolerant_pretrain.py \
      [--steps 300] [--d-model 512 --layers 8]
"""
import argparse
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.cim_layers import CIMConfig
from repro.data.lm_data import LMDataConfig, SyntheticLM
from repro.launch.steps import init_train_state, make_train_step
from repro.optim import AdamWConfig
from repro.runtime.fault_tolerance import (FTConfig, TrainDriver,
                                           make_fault_injector)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ft_pretrain")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    cfg = get_config("olmo_1b").replace(
        n_layers=args.layers, d_model=args.d_model,
        n_heads=8, n_kv_heads=8, d_ff=4 * args.d_model,
        vocab_size=8192, cim=CIMConfig(mode="bypass"), remat=False)
    data = SyntheticLM(LMDataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq_len,
                                    global_batch=args.batch))

    def batch_fn(step):
        toks, labels = data.batch_at(step)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-4),
                                      total_steps=args.steps, warmup=30),
                      donate_argnums=(0,))
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree.leaves(state["params"]))
    print(f"model: {n_params/1e6:.0f}M params "
          f"({args.layers}L x {args.d_model})")

    driver = TrainDriver(
        FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50, max_restarts=5),
        step_fn, batch_fn, state_template=state)
    injector = make_fault_injector({args.steps // 3: 1,
                                    2 * args.steps // 3: 1})
    state, hist = driver.run(state, args.steps, fault_injector=injector)

    first = np.mean([h.loss for h in hist[:20]])
    last = np.mean([h.loss for h in hist[-20:]])
    print(f"steps={len(hist)} restarts={driver.restarts} "
          f"loss {first:.3f} -> {last:.3f}")
    assert driver.restarts == 2 and last < first
    print("fault-tolerant pretraining: OK")


if __name__ == "__main__":
    main()
