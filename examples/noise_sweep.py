"""Seeded Monte-Carlo accuracy-vs-noise sweep through the engine fast path.

The paper's accuracy claims rest on carrying the post-silicon equivalent
noise model through the CNN evaluation (Sec. III.E, V.A).  This demo
briefly trains a LeNet on pseudo-MNIST, then runs the whole noise model —
thermal kT/C, per-physical-column SA offsets with 7b calibration residue,
DPL settling INL, MBIW charge injection, leakage droop — through the
*deployed* Pallas engine schedule, so a Monte-Carlo accuracy-vs-noise
sweep costs kernel dispatches instead of behavioural-sim walltime:

  PYTHONPATH=src python examples/noise_sweep.py

Every trial is seeded: rerunning this script reproduces every number.
"""
import jax
import jax.numpy as jnp

from repro.core.cim_layers import CIMConfig
from repro.core.noise_model import NoiseConfig
from repro.data.pseudo_mnist import make_dataset
from repro.models.cnn import init_lenet, lenet_engine, lenet_params_list
from repro.models.cnn import lenet_forward
from repro.optim import AdamWConfig, adamw_init, adamw_update

BATCH, TRIALS, TRAIN_STEPS = 64, 8, 120

xtr, ytr, xte, yte = make_dataset(n_train=2048, n_test=BATCH)
xtr, imgs = jnp.asarray(xtr)[..., None], jnp.asarray(xte)[..., None]
ytr, labels = jnp.asarray(ytr), jnp.asarray(yte)

# quick warm-up so the noise sweep degrades something real (full CIM-aware
# training is examples/train_lenet_cim.py; bypass keeps this demo fast).
# max_gamma is capped below the 32x ladder ceiling: the ABN zoom amplifies
# the input-referred thermal/offset noise along with the signal (Fig. 18),
# so an aggressive untrained gamma drowns in noise — the knob a CIM-aware
# training run would learn to balance.
CIM_EVAL = dict(r_in=4, r_w=2, max_gamma=8.0)
cim_train = CIMConfig(mode="bypass")
params = init_lenet(jax.random.PRNGKey(0), cim=CIMConfig(**CIM_EVAL))
opt, ocfg = adamw_init(params), AdamWConfig(lr=2e-3, weight_decay=0.0)


@jax.jit
def step(params, opt, xb, yb):
    def loss(p):
        lp = jax.nn.log_softmax(lenet_forward(p, xb, cim_train))
        return -jnp.mean(jnp.take_along_axis(lp, yb[:, None], 1))
    l, g = jax.value_and_grad(loss)(params)
    params, opt, _ = adamw_update(params, g, opt, ocfg)
    return params, opt, l


for i in range(TRAIN_STEPS):
    s = (i * 128) % (len(xtr) - 128)
    params, opt, l = step(params, opt, xtr[s:s + 128], ytr[s:s + 128])

base = NoiseConfig()                                     # measured defaults
print(f"LeNet-on-pseudo-MNIST (warm-up loss {float(l):.3f}), 4b engine, "
      f"{TRIALS} seeded trials/point")
print("noise_scale  acc_mean  acc_std   logit_rms_dev")
# ONE noise-enabled engine for every operating point: the sigma/offset
# terms are traced operands (noise= override), so the whole sweep shares a
# single compiled schedule instead of recompiling per point.
plist = lenet_params_list(params)
eng_noisy = lenet_engine(BATCH, cim=CIMConfig(mode="engine", noise=base,
                                              **CIM_EVAL))
eng_clean = lenet_engine(BATCH, cim=CIMConfig(mode="engine",
                                              noise=NoiseConfig.none(),
                                              **CIM_EVAL))
clean = eng_clean(plist, imgs)
for scale in (0.0, 0.1, 0.25, 0.5, 1.0):
    if scale > 0:
        point = base.replace(thermal_rms_lsb8=base.thermal_rms_lsb8 * scale,
                             sa_sigma_v=base.sa_sigma_v * scale)
        logits = eng_noisy.monte_carlo(plist, imgs, jax.random.PRNGKey(1),
                                       TRIALS, noise=point)
    else:
        logits = clean[None]                             # deterministic
    accs = jnp.mean(jnp.argmax(logits, -1) == labels[None, :], axis=-1)
    rms = float(jnp.sqrt(jnp.mean((logits - clean[None]) ** 2)))
    print(f"  x{scale:<9g} {float(jnp.mean(accs)):8.3f} "
          f"{float(jnp.std(accs)):8.3f} {rms:12.4f}")

# the perf report echoes the noise operating point next to the energy model
rep = lenet_engine(BATCH, cim=CIMConfig(mode="engine", noise=base,
                                        **CIM_EVAL)).perf_report()
print(f"\nperf_report noise echo: enabled={rep['noise']['enabled']}, "
      f"thermal={rep['noise']['thermal_rms_lsb8']} LSB8, "
      f"sa_sigma={rep['noise']['sa_sigma_v'] * 1e3:.0f} mV "
      f"(x{rep['noise']['sa_postlayout_mult']} post-layout), "
      f"modeled {rep['total']['tops_per_w']:.1f} TOPS/W")
