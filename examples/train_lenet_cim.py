"""End-to-end driver: train a CIM-quantized LeNet on pseudo-MNIST with the
full CIM-aware training loop (noise injection + learned ABN), then evaluate
under the voltage-domain behavioural macro — the paper's co-design flow.

  PYTHONPATH=src python examples/train_lenet_cim.py [--epochs 4]
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.core.cim_layers import CIMConfig
from repro.core.noise_model import NoiseConfig
from repro.data.pseudo_mnist import make_dataset
from repro.models.cnn import init_lenet, lenet_forward
from repro.optim import AdamWConfig, adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--batch", type=int, default=128)
    args = ap.parse_args()

    # CIM-aware training: fakequant + post-silicon noise (Sec. III.E)
    cim_train = CIMConfig(mode="fakequant", noise=NoiseConfig())
    cim_eval = CIMConfig(mode="fakequant")

    xtr, ytr, xte, yte = make_dataset(n_train=4096, n_test=1024)
    xtr = jnp.asarray(xtr)[..., None]
    xte = jnp.asarray(xte)[..., None]
    ytr, yte = jnp.asarray(ytr), jnp.asarray(yte)

    params = init_lenet(jax.random.PRNGKey(0), cim=cim_train)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-3, weight_decay=0.0)

    @jax.jit
    def step(params, opt, xb, yb, key):
        def loss(p):
            logits = lenet_forward(p, xb, cim_train, key=key)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, yb[:, None], 1))
        l, g = jax.value_and_grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, ocfg)
        return params, opt, l

    @functools.partial(jax.jit, static_argnames=("cim",))
    def accuracy(params, cim):
        logits = lenet_forward(params, xte, cim)
        return jnp.mean(jnp.argmax(logits, -1) == yte)

    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for epoch in range(args.epochs):
        for i in range(0, len(xtr), args.batch):
            key, sub = jax.random.split(key)
            params, opt, l = step(params, opt, xtr[i:i + args.batch],
                                  ytr[i:i + args.batch], sub)
        acc = float(accuracy(params, cim_eval))
        print(f"epoch {epoch}: loss={float(l):.3f} "
              f"test_acc={acc:.3f} ({time.time()-t0:.0f}s)")

    # deployment check: run the first 128 test images through the
    # voltage-domain macro simulation (Sec. III fidelity)
    logits_sim = lenet_forward(params, xte[:128], cim_eval.replace(mode="sim"))
    acc_sim = float(jnp.mean(jnp.argmax(logits_sim, -1) == yte[:128]))
    print(f"voltage-domain macro eval (128 imgs): acc={acc_sim:.3f}")

    # inference-runtime check: the same images through the conv front-end of
    # the precision-scalable engine (im2col streaming -> Pallas kernels)
    logits_eng = lenet_forward(params, xte[:128],
                               cim_eval.replace(mode="engine"))
    acc_eng = float(jnp.mean(jnp.argmax(logits_eng, -1) == yte[:128]))
    logits_fq = lenet_forward(params, xte[:128], cim_eval)
    agree = float(jnp.mean(jnp.argmax(logits_eng, -1)
                           == jnp.argmax(logits_fq, -1)))
    from repro.models.cnn import lenet_engine
    rep = lenet_engine(128, cim=cim_eval).perf_report()["total"]
    print(f"engine eval (128 imgs): acc={acc_eng:.3f}, top-1 agreement with "
          f"fakequant={agree:.3f}, modeled {rep['tops_per_w']:.1f} TOPS/W")


if __name__ == "__main__":
    main()
