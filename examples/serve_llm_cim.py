"""Serve a smoke-scale LLM with CIM-quantized weights: batched prefill +
decode through the KV cache, bypass-vs-CIM agreement report.

  PYTHONPATH=src python examples/serve_llm_cim.py --arch granite-8b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.cim_layers import CIMConfig
from repro.launch.steps import make_serve_step
from repro.models import transformer as tf


def generate(cfg, params, prompt, gen_len):
    cache = tf.init_cache(cfg, prompt.shape[0],
                          max_len=prompt.shape[1] + gen_len + 8)
    logits, cache, _ = tf.forward(cfg, params, prompt, cache=cache)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    out = [tok]
    for _ in range(gen_len):
        tok, cache = step(params, cache, tok)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    base = get_smoke_config(args.arch)
    params = tf.init_params(base, key)
    prompt = jax.random.randint(key, (args.batch, 16), 0, base.vocab_size)

    # "engine" decodes through the compiled-program runtime: the first step
    # builds the persistent program set (runtime/program.py), every later
    # step is a pure cache hit — zero re-planning / re-tracing
    for mode in ("bypass", "fakequant", "engine"):
        cfg = base.replace(cim=CIMConfig(mode=mode, max_gamma=2.0**16))
        t0 = time.time()
        gen = generate(cfg, params, prompt, args.gen_len)
        dt = time.time() - t0
        print(f"{mode:10s}: {args.gen_len * args.batch / dt:7.1f} tok/s   "
              f"sample={gen[0, :10].tolist()}")
    from repro.runtime import program_cache_stats
    print(f"engine program cache: {program_cache_stats()}")


if __name__ == "__main__":
    main()
